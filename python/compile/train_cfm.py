"""Build-time Conditional Flow Matching training of the MLP velocity model
(paper eq. 81) — the stand-in for the paper's multi-thousand-GPU-day U-Net
pre-training runs.

    L_CFM = E_{t, x0, x1} || v(x_t, t) - (sigma'_t x0 + alpha'_t x1) ||^2
    x_t = sigma_t x0 + alpha_t x1,   x0 ~ N(0, I),   x1 ~ smoothed dataset.

Hand-rolled Adam (no optax in the image).  Runs once inside `make
artifacts`; the trained weights are cached under artifacts/ and baked into
the exported HLO as constants.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model, schedulers


def _adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mh = new_m[k] / (1 - b1**step)
        vh = new_v[k] / (1 - b2**step)
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_p, new_m, new_v


def train(
    spec: model.ModelSpec,
    *,
    batch: int = 512,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 500,
) -> dict:
    """Train the CFM MLP for spec; returns numpy params."""
    assert spec.kind == "mlp"
    sched = schedulers.get(spec.sched)
    data = jnp.asarray(datasets.get(spec.dataset))  # [K, d]
    d = data.shape[1]
    params = model.init_mlp_params(d, spec.mlp_hidden, spec.mlp_layers, seed=seed)
    params = {k: jnp.asarray(v) for k, v in params.items()}

    def loss_fn(p, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        t = jax.random.uniform(k1, (batch, 1))
        x0 = jax.random.normal(k2, (batch, d))
        idx = jax.random.randint(k3, (batch,), 0, data.shape[0])
        x1 = data[idx] + spec.gamma * jax.random.normal(k4, (batch, d))
        a, s = sched.alpha(t), sched.sigma(t)
        da, ds = sched.d_alpha(t), sched.d_sigma(t)
        xt = s * x0 + a * x1
        target = ds * x0 + da * x1
        # Per-sample t: vmap the scalar-t velocity over the batch.
        v = jax.vmap(
            lambda xb, tb: model.mlp_velocity(p, xb[None, :], tb, use_kernel=False)[0]
        )(xt, t[:, 0])
        return jnp.mean(jnp.sum((v - target) ** 2, axis=-1))

    @jax.jit
    def train_step(p, m, v, step, key):
        loss, grads = jax.value_and_grad(loss_fn)(p, key)
        p, m, v = _adam_update(p, grads, m, v, step, lr)
        return p, m, v, loss

    m = {k: jnp.zeros_like(val) for k, val in params.items()}
    v = {k: jnp.zeros_like(val) for k, val in params.items()}
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    for it in range(1, spec.train_iters + 1):
        key, sub = jax.random.split(key)
        params, m, v, loss = train_step(params, m, v, it, sub)
        if it % log_every == 0 or it == 1:
            print(f"  [cfm {spec.name}] iter {it:5d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    return {k: np.asarray(val) for k, val in params.items()}


@functools.lru_cache(maxsize=None)
def load_or_train(spec_name: str, cache_dir: str) -> dict:
    """Load cached weights or train; cache as npz under cache_dir."""
    import os

    spec = model.MODELS[spec_name]
    path = os.path.join(cache_dir, f"weights_{spec.name}.npz")
    if os.path.exists(path):
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    params = train(spec)
    os.makedirs(cache_dir, exist_ok=True)
    np.savez(path, **params)
    return params
