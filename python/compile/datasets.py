"""Seeded synthetic target datasets shared between python (authoring) and
rust (analytic oracle + Frechet-vs-data metric).

Each dataset is a fixed set of K support points mu_k in R^d; the target
distribution is the gamma-smoothed empirical distribution
q = (1/K) sum_k N(mu_k, gamma^2 I).  The ideal flow velocity field for such a
target is available in closed form (see model.py), which is what stands in
for the paper's pre-trained U-Nets (see DESIGN.md §2).

Generators are deterministic given the seed so the manifest only needs to
record (name, K, d, seed); the raw points are additionally dumped as
little-endian f32 binaries for the rust side.
"""

from __future__ import annotations

import numpy as np


def checkerboard(n: int = 512, seed: int = 0) -> np.ndarray:
    """2D checkerboard over [-2, 2]^2 (4x4 board, alternating cells)."""
    rng = np.random.default_rng(seed)
    pts = []
    while len(pts) < n:
        xy = rng.uniform(-2.0, 2.0, size=(4 * n, 2))
        ij = np.floor(xy + 2.0).astype(int)  # cell indices in [0, 4)
        keep = (ij.sum(axis=1) % 2) == 0
        pts.extend(xy[keep].tolist())
    return np.asarray(pts[:n], dtype=np.float32)


def moons(n: int = 512, seed: int = 0, noise: float = 0.06) -> np.ndarray:
    """Two interleaved half-moons in roughly [-1.5, 2.5] x [-1, 1.5]."""
    rng = np.random.default_rng(seed)
    n1 = n // 2
    n2 = n - n1
    th1 = rng.uniform(0.0, np.pi, size=n1)
    th2 = rng.uniform(0.0, np.pi, size=n2)
    a = np.stack([np.cos(th1), np.sin(th1)], axis=1)
    b = np.stack([1.0 - np.cos(th2), 0.5 - np.sin(th2)], axis=1)
    pts = np.concatenate([a, b], axis=0) + rng.normal(0.0, noise, size=(n, 2))
    return pts.astype(np.float32)


def textures(n: int, side: int, seed: int = 0, max_freq: int = 3) -> np.ndarray:
    """Synthetic low-frequency 'texture' images in [-1, 1]^(side*side).

    Each image is a random superposition of 2D cosine basis functions with
    frequencies <= max_freq — a stand-in for natural-image datasets
    (ImageNet-64/128, AFHQ-256 analogs) that keeps the target manifold
    smooth and low-dimensional, as natural images are locally.
    """
    rng = np.random.default_rng(seed)
    ys, xs = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    basis = []
    for fy in range(max_freq + 1):
        for fx in range(max_freq + 1):
            phase_y = np.pi * fy * (ys + 0.5) / side
            phase_x = np.pi * fx * (xs + 0.5) / side
            basis.append(np.cos(phase_y) * np.cos(phase_x))
    basis = np.stack(basis, axis=0)  # [n_basis, side, side]
    nb = basis.shape[0]
    # 1/f-ish spectrum: lower frequencies dominate.
    fy, fx = np.meshgrid(np.arange(max_freq + 1), np.arange(max_freq + 1), indexing="ij")
    decay = 1.0 / (1.0 + fy + fx).reshape(nb)
    coefs = rng.normal(0.0, 1.0, size=(n, nb)) * decay[None, :]
    imgs = np.einsum("nb,bhw->nhw", coefs, basis)
    # Normalize each image into [-1, 1].
    amax = np.abs(imgs).max(axis=(1, 2), keepdims=True) + 1e-8
    imgs = imgs / amax
    return imgs.reshape(n, side * side).astype(np.float32)


DATASETS = {
    "checker2": lambda: checkerboard(512, seed=0),
    "moons2": lambda: moons(512, seed=1),
    "tex8": lambda: textures(256, 8, seed=2),
    "tex16": lambda: textures(256, 16, seed=3),
}


def get(name: str) -> np.ndarray:
    return DATASETS[name]()
