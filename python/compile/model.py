"""L2 — velocity-field models (the stand-ins for the paper's pre-trained
flow models) and the registry of exported model specs.

Two families (DESIGN.md §2):

* ``ideal``:  the closed-form *ideal* velocity field (paper eq. 23) of a
  gamma-smoothed K-point empirical target.  This is the exact zero-loss
  Flow-Matching solution, so Theorem 2.3 (scheduler equivalence) holds
  exactly — the property the paper's experiments probe.  The hot spot is the
  posterior-attention Pallas kernel (kernels/ideal_vf.py).

* ``mlp``:  a time-conditioned MLP trained at build time with the CFM loss
  (paper eq. 81) — exercises the "imperfect trained network" path.  The hot
  blocks are the fused dense+GELU Pallas kernels (kernels/mlp.py).

Both are pure functions ``u(x[B, d], t[]) -> u[B, d]`` that the AOT step
(aot.py) lowers to HLO text; the Rust coordinator only ever sees the HLO.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import datasets, schedulers
from .kernels import ideal_vf as ideal_vf_kernel
from .kernels import mlp as mlp_kernel
from .kernels import ref as kref

# Numerical floor used inside scheduler-derived coefficients (VP's sigma -> 0
# at t = 1 makes d_sigma blow up; products stay finite, see model-coefficient
# derivation in DESIGN.md §2).
_EPS = 1e-12


def ideal_coefs(sched: schedulers.Scheduler, t, gamma: float):
    """Coefficients of the ideal VF  u_t(x) = a_t x + b_t m_t(x).

    With v_t = sigma^2 + alpha^2 gamma^2 (the marginal per-component
    variance):

        a_t = (sigma' sigma + alpha' alpha gamma^2) / v_t
        b_t = sigma (alpha' sigma - sigma' alpha) / v_t

    and the posterior-attention logit coefficients

        coef_g = alpha / v_t,   coef_b = -alpha^2 / (2 v_t).

    Derivation: u_t(x) = (s'/s) x + (a' - s' a/s) E[x1|x] with
    E[x1|x] = c x + (1 - c a) m(x), c = a gamma^2 / v — substituting and
    simplifying removes every 1/sigma singularity.
    """
    a = sched.alpha(t)
    s = sched.sigma(t)
    da = sched.d_alpha(t)
    ds = sched.d_sigma(t)
    v = s * s + a * a * gamma * gamma + _EPS
    a_t = (ds * s + da * a * gamma * gamma) / v
    b_t = s * (da * s - ds * a) / v
    coef_g = a / v
    coef_b = -0.5 * a * a / v
    return a_t, b_t, coef_g, coef_b


def ideal_velocity(x, t, mu, sched: schedulers.Scheduler, gamma: float, *, use_kernel: bool = True):
    """Ideal velocity field u_t(x) for the smoothed empirical target mu.

    use_kernel=True routes the posterior mean through the Pallas kernel
    (forward/serving artifacts); False uses the pure-jnp oracle, which is the
    differentiable path used inside the AOT'd Bespoke loss (Pallas
    interpret-mode defines no VJP).  pytest asserts the two agree.
    """
    t = jnp.asarray(t)  # dtype-preserving: float64 grad checks need full precision
    a_t, b_t, coef_g, coef_b = ideal_coefs(sched, t, gamma)
    pm = ideal_vf_kernel.posterior_mean if use_kernel else kref.posterior_mean_ref
    m = pm(x, mu, coef_g, coef_b)
    return a_t * x + b_t * m


# ---------------------------------------------------------------------------
# Trained MLP velocity field (CFM, paper eq. 81)
# ---------------------------------------------------------------------------

N_FREQS = 8  # Fourier time features: sin/cos(2^j pi t), j = 0..7


def time_features(t):
    """[2 * N_FREQS] Fourier features of scalar time t."""
    freqs = 2.0 ** jnp.arange(N_FREQS)
    ang = math.pi * freqs * t
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def init_mlp_params(d: int, hidden: int, n_hidden: int, seed: int = 0) -> dict:
    """He-style init for the time-conditioned MLP v(x, t)."""
    rng = np.random.default_rng(seed)
    dims = [d + 2 * N_FREQS] + [hidden] * n_hidden + [d]
    params = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = (rng.normal(size=(din, dout)) * np.sqrt(2.0 / din)).astype(np.float32)
        params[f"b{i}"] = np.zeros((dout,), np.float32)
    return params


def mlp_n_layers(params: dict) -> int:
    """Layer count inferred from the weight keys (keeps params all-float so
    jax.grad can treat the whole dict as differentiable)."""
    return sum(1 for k in params if k.startswith("w"))


def mlp_velocity(params: dict, x, t, *, use_kernel: bool = True):
    """Time-conditioned MLP velocity field v(x, t) -> [B, d]."""
    t = jnp.asarray(t)
    B = x.shape[0]
    feats = jnp.broadcast_to(time_features(t)[None, :], (B, 2 * N_FREQS))
    h = jnp.concatenate([x, feats], axis=-1)
    n_layers = mlp_n_layers(params)
    layer = mlp_kernel.dense_gelu if use_kernel else kref.dense_gelu_ref
    for i in range(n_layers - 1):
        h = layer(h, jnp.asarray(params[f"w{i}"]), jnp.asarray(params[f"b{i}"]))
    # Final projection is a plain linear layer.
    i = n_layers - 1
    return h @ jnp.asarray(params[f"w{i}"]) + jnp.asarray(params[f"b{i}"])[None, :]


# ---------------------------------------------------------------------------
# Exported model registry (mirrored into artifacts/manifest.json)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSpec:
    """One exported flow model: (dataset, scheduler, kind) at a fixed batch."""

    name: str
    dataset: str
    sched: str
    batch: int
    gamma: float = 0.05
    kind: str = "ideal"  # "ideal" | "mlp"
    mlp_hidden: int = 128
    mlp_layers: int = 3
    train_iters: int = 3000
    # Bespoke loss-grad artifacts to export: (base, n) pairs.
    lossgrads: tuple = field(default=())


_NS = (4, 5, 8, 10)
_RK2 = tuple(("rk2", n) for n in _NS)
# RK1-Bespoke comparisons (paper Figs. 3/9/10) run at NFE = n, so RK1
# needs the larger n grid to cover the same NFE budgets as RK2.
_RK12 = _RK2 + tuple(("rk1", n) for n in (4, 5, 8, 10, 16, 20))

MODELS = {
    s.name: s
    for s in [
        # CIFAR-10 analogs: 2D checkerboard, three parameterizations.
        ModelSpec("checker2-ot", "checker2", "ot", 256, lossgrads=_RK12),
        ModelSpec("checker2-cs", "checker2", "cs", 256, lossgrads=_RK2),
        ModelSpec("checker2-vp", "checker2", "vp", 256, lossgrads=_RK2),
        # ImageNet-64 analogs: 8x8 textures (d = 64), three parameterizations.
        ModelSpec("tex8-ot", "tex8", "ot", 64, gamma=0.08, lossgrads=_RK12),
        ModelSpec("tex8-cs", "tex8", "cs", 64, gamma=0.08, lossgrads=_RK2),
        ModelSpec("tex8-vp", "tex8", "vp", 64, gamma=0.08, lossgrads=_RK12),
        # ImageNet-128 / AFHQ analog: 16x16 textures (d = 256).
        ModelSpec("tex16-ot", "tex16", "ot", 32, gamma=0.08, lossgrads=_RK2),
        # Trained CFM MLP on the checkerboard (imperfect-model path).
        ModelSpec("mlp2-ot", "checker2", "ot", 256, kind="mlp", lossgrads=_RK2),
    ]
}


def make_velocity_fn(spec: ModelSpec, mlp_params: dict | None = None, *, use_kernel: bool = True):
    """Closure u(x, t) -> u for a model spec (weights/dataset baked in)."""
    sched = schedulers.get(spec.sched)
    if spec.kind == "ideal":
        mu = jnp.asarray(datasets.get(spec.dataset))

        def u(x, t):
            return ideal_velocity(x, t, mu, sched, spec.gamma, use_kernel=use_kernel)

        return u
    if spec.kind == "mlp":
        assert mlp_params is not None, "mlp model requires trained params"

        def u(x, t):
            return mlp_velocity(mlp_params, x, t, use_kernel=use_kernel)

        return u
    raise ValueError(f"unknown model kind {spec.kind!r}")
