"""Pallas fused dense+GELU kernel — the hot block of the trained CFM MLP
velocity field (L1).

y = gelu(x @ W + b), tiled (B_tile x dout_tile) with the full reduction
dimension din resident in VMEM (din <= a few hundred here).  The matmul is
the MXU term; bias add + tanh-GELU fuse into the same VMEM-resident block on
the VPU, so the activation never round-trips to HBM — the fusion the paper's
serving stack would get from a hand-written CUDA kernel, rethought as a
BlockSpec schedule (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_GELU_C = math.sqrt(2.0 / math.pi)


def _pick_tile(n: int, target: int) -> int:
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _kernel(x_ref, w_ref, b_ref, o_ref):
    h = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :]
    o_ref[...] = 0.5 * h * (1.0 + jnp.tanh(_GELU_C * (h + 0.044715 * h * h * h)))


def dense_gelu(x, w, b, *, b_tile: int = 128, o_tile: int = 128):
    """Fused gelu(x @ w + b); semantics of ref.dense_gelu_ref.

    Args:
        x: [B, din], w: [din, dout], b: [dout].
    Returns:
        [B, dout]
    """
    B, din = x.shape
    din2, dout = w.shape
    assert din == din2, (din, din2)
    bt = _pick_tile(B, b_tile)
    ot = _pick_tile(dout, o_tile)

    return pl.pallas_call(
        _kernel,
        grid=(B // bt, dout // ot),
        in_specs=[
            pl.BlockSpec((bt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, ot), lambda i, j: (0, j)),
            pl.BlockSpec((ot,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, ot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, dout), jnp.float32),
        interpret=True,  # CPU-PJRT execution path
    )(x, w, b)
