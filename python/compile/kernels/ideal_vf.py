"""Pallas "posterior attention" kernel — the compute hot spot of the ideal
velocity field (L1 of the stack).

Computes, FlashAttention-style, the softmax-posterior mean

    m[b] = sum_k softmax_k(coef_g <x_b, mu_k> + coef_b ||mu_k||^2) mu_k

with an online-softmax accumulator carried across K tiles, so the
HBM<->VMEM schedule is: a (B_tile x d) query block stays resident while
(K_tile x d) dataset tiles stream through VMEM; each (B_tile x K_tile)
score block is one MXU matmul (x @ mu^T); the (running max, running
denominator, running weighted-sum) carry lives in registers/VMEM.  The
dataset points play the role of both keys and values.

Implementation note: the K-tile loop runs *inside* the kernel body
(`lax.fori_loop` + `dynamic_slice`) rather than as a second grid dimension
with revisited output blocks.  Both forms are valid Pallas; the in-kernel
loop produces straight-line HLO (each output block written exactly once)
which survives the HLO-text round-trip into xla_extension 0.5.1 — the
grid-carried-accumulator form miscompiles there (each program instance saw
zero-initialized carries).  See DESIGN.md §Hardware-Adaptation.

TPU adaptation notes: interpret=True is mandatory here — real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.  VMEM
footprint per program instance is B_t*d (queries) + K_t*d (streamed tile)
+ B_t*K_t (score block) + B_t*(d+2) (carry) floats; with the default tiles
(128, 128) and d <= 256 that is < 0.5 MB, far under the ~16 MB VMEM budget,
leaving room to double-buffer the K-tile stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (shapes here are powers of 2)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _kernel(coef_ref, x_ref, mu_ref, out_ref, *, kt: int, nk: int):
    xb = x_ref[...]  # [bt, d] — resident for the whole K sweep
    bt, d = xb.shape
    coef_g = coef_ref[0]
    coef_b = coef_ref[1]

    def body(c, carry):
        m_run, l_run, acc = carry
        mub = jax.lax.dynamic_slice(mu_ref[...], (c * kt, 0), (kt, d))  # stream K tile
        # Score block on the MXU: logits = coef_g * x mu^T + coef_b * ||mu||^2.
        scores = jnp.dot(xb, mub.T)  # [bt, kt]
        msq = jnp.sum(mub * mub, axis=-1)  # [kt]
        logits = coef_g * scores + coef_b * msq[None, :]
        # Online softmax update.
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1, keepdims=True))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new)  # [bt, kt]
        l_new = l_run * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p, mub)
        return m_new, l_new, acc_new

    init = (
        jnp.full((bt, 1), NEG_INF, jnp.float32),
        jnp.zeros((bt, 1), jnp.float32),
        jnp.zeros((bt, d), jnp.float32),
    )
    _, l_fin, acc_fin = jax.lax.fori_loop(0, nk, body, init)
    out_ref[...] = acc_fin / l_fin


def posterior_mean(x, mu, coef_g, coef_b, *, b_tile: int = 128, k_tile: int = 256):
    """Pallas posterior-attention; semantics of ref.posterior_mean_ref.

    Args:
        x: [B, d] queries.
        mu: [K, d] dataset points (keys == values).
        coef_g, coef_b: scalar logit coefficients (traced OK).
    Returns:
        m: [B, d]
    """
    B, d = x.shape
    K, d2 = mu.shape
    assert d == d2, (d, d2)
    bt = _pick_tile(B, b_tile)
    kt = _pick_tile(K, k_tile)
    nb, nk = B // bt, K // kt
    coefs = jnp.stack([jnp.asarray(coef_g, jnp.float32), jnp.asarray(coef_b, jnp.float32)])

    kernel = functools.partial(_kernel, kt=kt, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # coefs: replicated
            pl.BlockSpec((bt, d), lambda i: (i, 0)),  # x: one B tile per instance
            pl.BlockSpec((K, d), lambda i: (0, 0)),  # mu: full, tiled in-kernel
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(coefs, x, mu)
