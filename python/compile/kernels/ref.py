"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: pytest asserts the Pallas kernels
(interpret=True) match these to tight tolerance across hypothesis-generated
shape/parameter sweeps, and the AOT'd loss-grad artifacts differentiate
through these (Pallas interpret-mode has no VJP rule; the forward artifacts
use the Pallas kernels, and equality of the two paths is itself a test).
"""

from __future__ import annotations

import jax.numpy as jnp


def posterior_mean_ref(x, mu, coef_g, coef_b):
    """Softmax-posterior mean of the dataset points ("posterior attention").

    logits[b, k] = coef_g * <x_b, mu_k> + coef_b * ||mu_k||^2
    m[b]         = sum_k softmax(logits[b])_k * mu_k

    With coef_g = 2 alpha / (2 v_t) and coef_b = -alpha^2 / (2 v_t) this is
    exactly softmax_k(-||x - alpha mu_k||^2 / (2 v_t)) (the row-constant
    ||x||^2 term cancels inside the softmax), i.e. the Bayes posterior mean
    E[mu | x] of the gamma-smoothed empirical target (DESIGN.md §2).

    Args:
        x: [B, d] query points.
        mu: [K, d] dataset support points.
        coef_g, coef_b: scalars (may be traced).
    Returns:
        m: [B, d] posterior means.
    """
    logits = coef_g * (x @ mu.T) + coef_b * jnp.sum(mu * mu, axis=-1)[None, :]
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w @ mu


def dense_gelu_ref(x, w, b):
    """Fused dense + tanh-GELU: gelu(x @ w + b).

    Args:
        x: [B, din], w: [din, dout], b: [dout].
    Returns:
        [B, dout]
    """
    h = x @ w + b[None, :]
    c = jnp.sqrt(2.0 / jnp.pi)
    return 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * h**3)))
