"""Gaussian-path schedulers (paper eq. 22, 82, 83, 85).

A scheduler is the pair (alpha_t, sigma_t) with alpha_0 ~ 0, sigma_0 ~ 1,
alpha_1 = 1, sigma_1 ~ 0 and strictly monotone snr(t) = alpha_t / sigma_t.
Convention follows the paper: noise at t = 0, data at t = 1.

These are mirrored bit-for-bit by ``rust/src/schedulers`` — the pytest suite
and the Rust integration tests cross-check the two implementations through
the AOT'd HLO artifacts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp

# VP schedule constants (Song et al. 2020b; paper eq. 85).
VP_BETA_MAX = 20.0
VP_BETA_MIN = 0.1


@dataclass(frozen=True)
class Scheduler:
    """A named (alpha, sigma) scheduler with analytic derivatives."""

    name: str

    def alpha(self, t):
        raise NotImplementedError

    def sigma(self, t):
        raise NotImplementedError

    def d_alpha(self, t):
        raise NotImplementedError

    def d_sigma(self, t):
        raise NotImplementedError

    def snr(self, t):
        return self.alpha(t) / self.sigma(t)

    def log_snr(self, t):
        return jnp.log(self.alpha(t)) - jnp.log(self.sigma(t))


@dataclass(frozen=True)
class CondOT(Scheduler):
    """Flow-Matching conditional-OT scheduler: alpha = t, sigma = 1 - t."""

    name: str = "ot"

    def alpha(self, t):
        return t

    def sigma(self, t):
        return 1.0 - t

    def d_alpha(self, t):
        return jnp.ones_like(t)

    def d_sigma(self, t):
        return -jnp.ones_like(t)


@dataclass(frozen=True)
class Cosine(Scheduler):
    """FM/v cosine scheduler: alpha = sin(pi t / 2), sigma = cos(pi t / 2)."""

    name: str = "cs"

    def alpha(self, t):
        return jnp.sin(0.5 * math.pi * t)

    def sigma(self, t):
        return jnp.cos(0.5 * math.pi * t)

    def d_alpha(self, t):
        return 0.5 * math.pi * jnp.cos(0.5 * math.pi * t)

    def d_sigma(self, t):
        return -0.5 * math.pi * jnp.sin(0.5 * math.pi * t)


@dataclass(frozen=True)
class VarPres(Scheduler):
    """Variance-preserving scheduler (paper eq. 85).

    alpha_t = xi(1 - t), sigma_t = sqrt(1 - alpha_t^2),
    xi(s) = exp(-s^2 (B - b) / 4 - s b / 2), B = 20, b = 0.1.
    """

    name: str = "vp"

    @staticmethod
    def _xi(s):
        return jnp.exp(-0.25 * s * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * s * VP_BETA_MIN)

    @staticmethod
    def _d_xi(s):
        # d/ds xi(s) = xi(s) * (-s (B - b)/2 - b/2)
        return VarPres._xi(s) * (-0.5 * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * VP_BETA_MIN)

    def alpha(self, t):
        return self._xi(1.0 - t)

    def sigma(self, t):
        a = self.alpha(t)
        return jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    def d_alpha(self, t):
        # alpha(t) = xi(1 - t)  =>  d/dt = -xi'(1 - t)
        return -self._d_xi(1.0 - t)

    def d_sigma(self, t):
        # sigma = sqrt(1 - alpha^2)  =>  sigma' = -alpha alpha' / sigma
        a = self.alpha(t)
        return -a * self.d_alpha(t) / self.sigma(t)


SCHEDULERS = {
    "ot": CondOT(),
    "cs": Cosine(),
    "vp": VarPres(),
}


def get(name: str) -> Scheduler:
    try:
        return SCHEDULERS[name]
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; available: {sorted(SCHEDULERS)}")
