"""Raw-theta parameterization of the Bespoke scale-time transform
(paper eq. 74 / 76, Appendix F).  Mirrored bit-for-bit by
``rust/src/solvers/theta.rs`` (the Rust side decodes the same raw vector at
sampling time; the JAX side decodes it inside the AOT'd loss-grad graph).

Grid convention: a base-RK1 n-step solver uses grid points i = 0..n
(g = n + 1 points); base-RK2 uses i = 0, 1/2, 1, ..., n (g = 2n + 1 points).
Raw layout (all float32, p = 4 * (g - 1)):

    [ dt_raw (g-1) | tdot_raw (g-1) | log_s (g-1) | sdot (g-1) ]

Decode (identity-init values in parentheses):

    t_0 = 0, t_j = cumsum(|dt_raw| + eps) / total           (dt_raw = 1)
    tdot_j = |tdot_raw_j| + eps   for j = 0..g-2            (tdot_raw = 1)
    s_0 = 1, s_j = exp(log_s_j)   for j = 1..g-1            (log_s = 0)
    sdot_j  (free)                for j = 0..g-2            (sdot = 0)

The paper counts 8n - 1 / 4n - 1 parameters; our 8n / 4n layout keeps the
one normalization redundancy (the overall scale of dt_raw) instead of
pinning it — functionally identical (see DESIGN.md §5).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_EPS = 1e-6


def grid_points(base: str, n: int) -> int:
    """Number of grid points g for an n-step solver with the given base."""
    if base == "rk1":
        return n + 1
    if base == "rk2":
        return 2 * n + 1
    raise ValueError(f"unknown base {base!r}")


def n_params(base: str, n: int) -> int:
    return 4 * (grid_points(base, n) - 1)


def identity_init(base: str, n: int) -> np.ndarray:
    """Raw theta whose decode is the identity transform (paper eq. 77-80)."""
    g = grid_points(base, n)
    m = g - 1
    return np.concatenate(
        [
            np.ones(m, np.float32),  # dt_raw  -> uniform grid
            np.ones(m, np.float32) / m,  # tdot_raw -> dt/dr = 1 (r-grid spacing h_r: see note)
            np.zeros(m, np.float32),  # log_s -> s = 1
            np.zeros(m, np.float32),  # sdot  -> 0
        ]
    )


def decode(theta_raw, base: str, n: int):
    """Decode raw theta -> dict of grid sequences (jnp, differentiable).

    Returns dict with:
        t    [g]    grid times, t[0] = 0, t[-1] = 1
        tdot [g-1]  dt/dr at grid points 0..g-2 (strictly positive)
        s    [g]    scales, s[0] = 1 (strictly positive)
        sdot [g-1]  ds/dr at grid points 0..g-2 (unconstrained)

    NOTE on tdot units: r-space grid spacing between *consecutive grid
    points* is h_g = 1 / (g - 1) (for RK2 this is h/2 with h = 1/n).  The
    identity transform t_r = r has dt/dr = 1; our identity_init sets
    tdot_raw = 1/m with decode tdot = |tdot_raw| * m so that decoded
    tdot = 1.  Keeping raw values O(1/m) gives all four blocks comparable
    Adam step sizes.
    """
    g = grid_points(base, n)
    m = g - 1
    theta_raw = jnp.asarray(theta_raw)
    assert theta_raw.shape == (4 * m,), (theta_raw.shape, 4 * m)
    dt_raw, tdot_raw, log_s, sdot = jnp.split(theta_raw, 4)

    inc = jnp.abs(dt_raw) + _EPS
    csum = jnp.cumsum(inc)
    t = jnp.concatenate([jnp.zeros(1), csum / csum[-1]])

    tdot = (jnp.abs(tdot_raw) + _EPS) * m
    s = jnp.concatenate([jnp.ones(1), jnp.exp(log_s)])
    return {"t": t, "tdot": tdot, "s": s, "sdot": sdot}


def ablation_mask(base: str, n: int, mode: str) -> np.ndarray:
    """Gradient mask implementing the paper's Fig. 15 ablations.

    mode = "full"       -> all ones
    mode = "time-only"  -> zero the scale blocks (s stays identically 1)
    mode = "scale-only" -> zero the time blocks (t_r stays r)

    With identity init, masking gradients exactly pins the frozen half of
    the transform to its identity value.
    """
    g = grid_points(base, n)
    m = g - 1
    mask = np.ones(4 * m, np.float32)
    if mode == "time-only":
        mask[2 * m :] = 0.0
    elif mode == "scale-only":
        mask[: 2 * m] = 0.0
    elif mode != "full":
        raise ValueError(f"unknown ablation mode {mode!r}")
    return mask
