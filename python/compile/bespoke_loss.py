"""The differentiable RMSE-Bespoke loss  L_bes(theta)  (paper §2.3) and its
AOT-exported gradient.

The Rust trainer (L3) owns the optimization loop; at every iteration it

  1. samples a noise batch and solves the GT path with DOPRI5 (dense output),
  2. decodes the *current* theta to grid times t_i, extracts snapshots
     x(t_i) and u(x(t_i), t_i)  (stop-gradient constants, paper eq. 28),
  3. calls the HLO artifact exported here:
         (theta[p], x_snap[B, n+1, d], u_snap[B, n+1, d], t_snap[n+1])
             -> (loss[], grad[p])
  4. applies an Adam update (optionally through an ablation gradient mask).

Inside this graph the snapshots enter only through the linearization
    x_aux_i(t) = x_snap_i + u_snap_i * (t - t_snap_i),
so d x_aux_i / d theta^t is exactly the ODE derivative — the paper's
stop-gradient trick, realized here by the AOT interface itself (snapshots
are runtime inputs, hence constants to jax.grad).

Gradients flow through: the grid times t_i (via u's time argument and
x_aux), the scales s_i / derivatives, and the Lipschitz products M_i
(lemmas D.2/D.3, L_tau = 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import theta as theta_mod

L_TAU = 1.0  # paper's hyper-parameter choice (used in all experiments)


def _rms(err):
    """Per-sample RMS norm ||e|| = sqrt(mean_i e_i^2), averaged over batch."""
    return jnp.mean(jnp.sqrt(jnp.mean(err * err, axis=-1) + 1e-20))


def _l_ubar(dec, j):
    """Lipschitz bound of the transformed field at grid point j (lemma D.1)."""
    return jnp.abs(dec["sdot"][j]) / dec["s"][j] + dec["tdot"][j] * L_TAU


def step_rk1(u_fn, x, i, dec, n):
    """Bespoke-RK1 update (paper eq. 17). Grid index = step index."""
    h = 1.0 / n
    s_i, s_ip = dec["s"][i], dec["s"][i + 1]
    return ((s_i + h * dec["sdot"][i]) / s_ip) * x + (
        h * dec["tdot"][i] * s_i / s_ip
    ) * u_fn(x, dec["t"][i])


def step_rk2(u_fn, x, i, dec, n):
    """Bespoke-RK2 (midpoint) update (paper eq. 19-20). Grid index = 2i."""
    h = 1.0 / n
    j = 2 * i
    t_i, t_h = dec["t"][j], dec["t"][j + 1]
    s_i, s_h, s_ip = dec["s"][j], dec["s"][j + 1], dec["s"][j + 2]
    td_i, td_h = dec["tdot"][j], dec["tdot"][j + 1]
    sd_i, sd_h = dec["sdot"][j], dec["sdot"][j + 1]
    z = (s_i + 0.5 * h * sd_i) * x + (0.5 * h * s_i * td_i) * u_fn(x, t_i)
    return (s_i / s_ip) * x + (h / s_ip) * (
        (sd_h / s_h) * z + td_h * s_h * u_fn(z / s_h, t_h)
    )


def lipschitz_step(dec, base: str, i: int, n: int):
    """L_i^theta of step i (lemmas D.2 / D.3)."""
    h = 1.0 / n
    if base == "rk1":
        return (dec["s"][i] / dec["s"][i + 1]) * (1.0 + h * _l_ubar(dec, i))
    j = 2 * i
    lu_i = _l_ubar(dec, j)
    lu_h = _l_ubar(dec, j + 1)
    return (dec["s"][j] / dec["s"][j + 2]) * (1.0 + h * lu_h * (1.0 + 0.5 * h * lu_i))


def bespoke_loss(theta_raw, x_snap, u_snap, t_snap, *, u_fn, base: str, n: int):
    """L_bes(theta) (paper eq. 26) from GT snapshots; fully differentiable.

    Args:
        theta_raw: [p] raw parameters (theta.py layout).
        x_snap/u_snap: [B, n+1, d] GT positions / velocities at the current
            grid times (stop-gradient constants).
        t_snap: [n+1] the times at which the snapshots were taken (== the
            decoded t_i of the theta used to extract them).
    """
    dec = theta_mod.decode(theta_raw, base, n)
    # Grid indices of the integer step times in the decoded t vector.
    stride = 1 if base == "rk1" else 2

    def x_aux(i):
        ti = dec["t"][stride * i]
        return x_snap[:, i, :] + u_snap[:, i, :] * (ti - t_snap[i])

    step = step_rk1 if base == "rk1" else step_rk2
    l_steps = [lipschitz_step(dec, base, i, n) for i in range(n)]
    # M for step k weights d_{k+1}: product of L over steps k+1 .. n-1.
    m = [None] * n
    acc = jnp.asarray(1.0)
    for k in range(n - 1, -1, -1):
        m[k] = acc
        acc = acc * l_steps[k]

    loss = 0.0
    for k in range(n):
        pred = step(u_fn, x_aux(k), k, dec, n)
        d_k = _rms(x_aux(k + 1) - pred)
        loss = loss + m[k] * d_k
    return loss


def make_loss_and_grad(u_fn, base: str, n: int):
    """(theta, x_snap, u_snap, t_snap) -> (loss, grad) — the AOT export."""

    def f(theta_raw, x_snap, u_snap, t_snap):
        return bespoke_loss(theta_raw, x_snap, u_snap, t_snap, u_fn=u_fn, base=base, n=n)

    return jax.value_and_grad(f)
