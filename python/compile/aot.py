"""AOT export — lowers every model and loss-grad graph to HLO **text** and
writes artifacts/manifest.json.  Runs once via `make artifacts`; python is
never on the request path.

HLO text (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` 0.1.6 rust crate binds) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts:
    u_<model>.hlo.txt                       (x[B,d], t[]) -> (u[B,d],)
    lossgrad_<model>_<base>_n<n>.hlo.txt    (theta[p], x_snap[B,n+1,d],
                                             u_snap[B,n+1,d], t_snap[n+1])
                                            -> (loss[], grad[p])
    data_<dataset>.f32                      raw little-endian f32 [K*d]
    weights_mlp2-ot.npz                     cached CFM weights
    manifest.json                           index of all of the above
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bespoke_loss, datasets, model, theta as theta_mod, train_cfm


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    print_large_constants=True is load-bearing: the default printer elides
    big literals as `constant({...})`, which xla_extension 0.5.1's text
    parser silently reads back as ZEROS — the baked datasets / MLP weights
    would vanish from the compiled executable.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)


def export_model_u(spec: model.ModelSpec, out_dir: str, use_kernel: bool = True) -> str:
    """Lower the velocity field u(x[B,d], t[]) for one model spec."""
    mlp_params = None
    if spec.kind == "mlp":
        mlp_params = train_cfm.load_or_train(spec.name, out_dir)
    u_fn = model.make_velocity_fn(spec, mlp_params, use_kernel=use_kernel)
    d = datasets.get(spec.dataset).shape[1]
    x_spec = jax.ShapeDtypeStruct((spec.batch, d), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(lambda x, t: (u_fn(x, t),)).lower(x_spec, t_spec)
    name = f"u_{spec.name}.hlo.txt"
    _write(os.path.join(out_dir, name), to_hlo_text(lowered))
    return name


def export_lossgrad(spec: model.ModelSpec, base: str, n: int, out_dir: str) -> str:
    """Lower (loss, grad) of the n-step Bespoke loss for one model spec.

    Uses the ref (pure-jnp) velocity path: Pallas interpret-mode defines no
    VJP; pytest asserts ref == kernel so the two artifacts agree.
    """
    mlp_params = None
    if spec.kind == "mlp":
        mlp_params = train_cfm.load_or_train(spec.name, out_dir)
    u_fn = model.make_velocity_fn(spec, mlp_params, use_kernel=False)
    d = datasets.get(spec.dataset).shape[1]
    p = theta_mod.n_params(base, n)
    specs = (
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((spec.batch, n + 1, d), jnp.float32),
        jax.ShapeDtypeStruct((spec.batch, n + 1, d), jnp.float32),
        jax.ShapeDtypeStruct((n + 1,), jnp.float32),
    )
    lg = bespoke_loss.make_loss_and_grad(u_fn, base, n)
    lowered = jax.jit(lambda *a: tuple(jax.tree_util.tree_leaves(lg(*a)))).lower(*specs)
    name = f"lossgrad_{spec.name}_{base}_n{n}.hlo.txt"
    _write(os.path.join(out_dir, name), to_hlo_text(lowered))
    return name


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--models", default="", help="comma-separated subset of model names")
    ap.add_argument("--skip-lossgrad", action="store_true")
    ap.add_argument("--no-pallas", action="store_true", help="lower u with the ref path")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    names = [s for s in args.models.split(",") if s] or list(model.MODELS)
    manifest = {"models": {}, "datasets": {}, "lossgrads": {}}

    # Datasets: raw f32 dumps for the rust analytic oracle + metrics.
    needed = {model.MODELS[n].dataset for n in names}
    for ds in sorted(needed):
        pts = datasets.get(ds)
        fname = f"data_{ds}.f32"
        pts.astype("<f4").tofile(os.path.join(out_dir, fname))
        manifest["datasets"][ds] = {"file": fname, "k": int(pts.shape[0]), "d": int(pts.shape[1])}

    for mname in names:
        spec = model.MODELS[mname]
        t0 = time.time()
        u_file = export_model_u(spec, out_dir, use_kernel=not args.no_pallas)
        print(f"[aot] {u_file} ({time.time()-t0:.1f}s)")
        d = manifest["datasets"][spec.dataset]["d"]
        manifest["models"][mname] = {
            "u_hlo": u_file,
            "dataset": spec.dataset,
            "sched": spec.sched,
            "kind": spec.kind,
            "batch": spec.batch,
            "d": d,
            "gamma": spec.gamma,
            "lossgrads": {},
        }
        if args.skip_lossgrad:
            continue
        for base, n in spec.lossgrads:
            t0 = time.time()
            lg_file = export_lossgrad(spec, base, n, out_dir)
            print(f"[aot] {lg_file} ({time.time()-t0:.1f}s)")
            manifest["models"][mname]["lossgrads"][f"{base}_n{n}"] = {
                "file": lg_file,
                "base": base,
                "n": n,
                "p": theta_mod.n_params(base, n),
            }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
