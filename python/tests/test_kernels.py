"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and coefficients; this is the core correctness
signal for the kernels that end up inside the serving HLO artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ideal_vf import posterior_mean
from compile.kernels.mlp import dense_gelu
from compile.kernels.ref import dense_gelu_ref, posterior_mean_ref

SIZES = st.sampled_from([1, 2, 3, 8, 17, 32, 96, 128, 160, 256])
DIMS = st.sampled_from([1, 2, 5, 16, 64])


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=SIZES,
    k=SIZES,
    d=DIMS,
    coef_g=st.floats(-10.0, 10.0),
    coef_b=st.floats(-10.0, 0.0),
    seed=st.integers(0, 2**16),
)
def test_posterior_mean_matches_ref(b, k, d, coef_g, coef_b, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    mu = _rand(rng, k, d)
    got = posterior_mean(x, mu, coef_g, coef_b)
    want = posterior_mean_ref(x, mu, coef_g, coef_b)
    # 1e-4: accumulation-order differences under saturated softmax (large
    # coef_g * dot products at d = 64) legitimately reach a few 1e-5.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=SIZES,
    din=DIMS,
    dout=st.sampled_from([1, 2, 16, 128, 160]),
    seed=st.integers(0, 2**16),
)
def test_dense_gelu_matches_ref(b, din, dout, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, din)
    w = _rand(rng, din, dout)
    bias = _rand(rng, dout)
    got = dense_gelu(x, w, bias)
    want = dense_gelu_ref(x, w, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_posterior_mean_saturated_softmax_is_stable():
    """Extreme logits: online softmax must not produce NaN/Inf."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 16, 4) * 10.0
    mu = _rand(rng, 256, 4) * 10.0
    got = posterior_mean(x, mu, 400.0, -200.0)
    assert np.isfinite(np.asarray(got)).all()
    want = posterior_mean_ref(x, mu, 400.0, -200.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_posterior_mean_uniform_limit():
    """coef -> 0 gives the plain dataset mean for every query."""
    rng = np.random.default_rng(1)
    x = _rand(rng, 8, 3)
    mu = _rand(rng, 64, 3)
    got = np.asarray(posterior_mean(x, mu, 0.0, 0.0))
    want = np.broadcast_to(np.asarray(mu).mean(axis=0), got.shape)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_posterior_mean_is_convex_combination():
    """Output must lie in the convex hull of the dataset (coordinatewise bounds)."""
    rng = np.random.default_rng(2)
    x = _rand(rng, 32, 2)
    mu = _rand(rng, 128, 2)
    got = np.asarray(posterior_mean(x, mu, 5.0, -2.0))
    lo, hi = np.asarray(mu).min(axis=0), np.asarray(mu).max(axis=0)
    assert (got >= lo - 1e-5).all() and (got <= hi + 1e-5).all()


@pytest.mark.parametrize("b_tile,k_tile", [(32, 32), (64, 128), (128, 64)])
def test_posterior_mean_tile_invariance(b_tile, k_tile):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(3)
    x = _rand(rng, 128, 8)
    mu = _rand(rng, 256, 8)
    got = posterior_mean(x, mu, 3.0, -1.0, b_tile=b_tile, k_tile=k_tile)
    want = posterior_mean_ref(x, mu, 3.0, -1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
