"""Bespoke loss correctness: identity-theta reduces to the plain base solver,
Lipschitz weights reduce to 1, gradients match finite differences, and the
loss is exactly the weighted sum of local truncation errors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bespoke_loss as bl
from compile import model, theta as tm


def u_linear(x, t):
    """Analytically solvable field: x' = -x + t (for exact-step tests)."""
    return -x + t


def _identity_dec(base, n):
    return tm.decode(tm.identity_init(base, n), base, n)


def test_identity_theta_rk1_step_is_euler():
    n = 5
    dec = _identity_dec("rk1", n)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)))
    for i in range(n):
        got = bl.step_rk1(u_linear, x, i, dec, n)
        t_i = i / n
        want = x + (1.0 / n) * u_linear(x, t_i)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_identity_theta_rk2_step_is_midpoint():
    n = 4
    dec = _identity_dec("rk2", n)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 3)))
    h = 1.0 / n
    for i in range(n):
        got = bl.step_rk2(u_linear, x, i, dec, n)
        t_i = i * h
        z = x + 0.5 * h * u_linear(x, t_i)
        want = x + h * u_linear(z, t_i + 0.5 * h)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("base,n", [("rk1", 4), ("rk2", 6)])
def test_identity_theta_lipschitz_weights(base, n):
    """At identity theta: L_ubar = L_tau = 1, so L_i = 1 + h (RK1) or
    1 + h(1 + h/2) (RK2) exactly (lemmas D.2/D.3)."""
    dec = _identity_dec(base, n)
    h = 1.0 / n
    want = 1.0 + h if base == "rk1" else 1.0 + h * (1.0 + 0.5 * h)
    for i in range(n):
        got = float(bl.lipschitz_step(dec, base, i, n))
        assert got == pytest.approx(want, rel=1e-4)


@pytest.mark.parametrize("base,n", [("rk1", 5), ("rk2", 5)])
def test_gradient_matches_finite_differences(base, n, x64):
    spec = model.MODELS["checker2-ot"]
    u_fn = model.make_velocity_fn(spec, use_kernel=False)
    p = tm.n_params(base, n)
    rng = np.random.default_rng(0)
    th = jnp.asarray(tm.identity_init(base, n), jnp.float64) + 0.02 * rng.normal(size=p)
    B, d = 16, 2
    xs = jnp.asarray(rng.normal(size=(B, n + 1, d)))
    us = jnp.asarray(rng.normal(size=(B, n + 1, d)))
    ts = jnp.linspace(0, 1, n + 1)
    lg = bl.make_loss_and_grad(u_fn, base, n)
    _, grad = lg(th, xs, us, ts)

    def f(v):
        return float(lg(jnp.asarray(v), xs, us, ts)[0])

    eps = 1e-6
    for i in range(0, p, max(1, p // 12)):
        tp, tmm = np.array(th), np.array(th)
        tp[i] += eps
        tmm[i] -= eps
        fd = (f(tp) - f(tmm)) / (2 * eps)
        assert fd == pytest.approx(float(grad[i]), rel=1e-3, abs=1e-5), f"param {i}"


def test_loss_zero_for_exact_snapshots_of_linear_field():
    """A globally-linear trajectory is reproduced exactly by RK2 (order 2
    exact on linear-in-t solutions); loss must be ~0 at identity theta."""

    def u_const(x, t):
        return jnp.ones_like(x) * 0.7

    n, B, d = 4, 3, 2
    ts = jnp.linspace(0, 1, n + 1)
    x0 = jnp.asarray(np.random.default_rng(2).normal(size=(B, d)))
    xs = jnp.stack([x0 + 0.7 * t for t in ts], axis=1)
    us = jnp.full((B, n + 1, d), 0.7)
    th = jnp.asarray(tm.identity_init("rk2", n))
    loss = bl.bespoke_loss(th, xs, us, ts, u_fn=u_const, base="rk2", n=n)
    # Not exactly 0: the decode's positivity eps (1e-6) perturbs tdot by
    # ~m*eps at identity; anything below 1e-4 is the exact-solver regime.
    assert float(loss) < 1e-4


def test_loss_is_positive_and_finite():
    spec = model.MODELS["checker2-ot"]
    u_fn = model.make_velocity_fn(spec, use_kernel=False)
    n = 4
    rng = np.random.default_rng(3)
    th = jnp.asarray(tm.identity_init("rk2", n))
    xs = jnp.asarray(rng.normal(size=(8, n + 1, 2)))
    us = jnp.asarray(rng.normal(size=(8, n + 1, 2)))
    ts = jnp.linspace(0, 1, n + 1)
    loss = bl.bespoke_loss(th, xs, us, ts, u_fn=u_fn, base="rk2", n=n)
    assert np.isfinite(float(loss)) and float(loss) > 0
