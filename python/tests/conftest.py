"""Shared fixtures: scoped float64 mode for finite-difference checks.

jax_enable_x64 must not leak across test modules — the Pallas interpret
kernels and the AOT path are float32-only — so tests that need float64
request the ``x64`` fixture instead of flipping the global config at import.
"""

import jax
import pytest


@pytest.fixture
def x64():
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", False)
