"""L2 model correctness: kernel path == ref path, finiteness at the time
boundaries, flow actually transports noise to the target, Theorem 2.3
coupling invariance across schedulers."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model, schedulers


@pytest.mark.parametrize("name", ["checker2-ot", "checker2-vp", "tex8-cs"])
def test_kernel_path_matches_ref_path(name):
    spec = model.MODELS[name]
    u_k = model.make_velocity_fn(spec, use_kernel=True)
    u_r = model.make_velocity_fn(spec, use_kernel=False)
    rng = np.random.default_rng(0)
    d = datasets.get(spec.dataset).shape[1]
    x = jnp.asarray(rng.normal(size=(spec.batch, d)), jnp.float32)
    for t in [0.0, 0.31, 0.77, 1.0]:
        a = np.asarray(u_k(x, jnp.float32(t)))
        b = np.asarray(u_r(x, jnp.float32(t)))
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("name", ["checker2-ot", "checker2-cs", "checker2-vp"])
def test_velocity_finite_on_full_time_range(name):
    spec = model.MODELS[name]
    u = model.make_velocity_fn(spec, use_kernel=False)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 2)) * 3.0, jnp.float32)
    for t in np.linspace(0.0, 1.0, 21):
        out = np.asarray(u(x, jnp.float32(t)))
        assert np.isfinite(out).all(), f"non-finite velocity at t={t}"


def _euler_sample(u, x0, steps=400):
    x = x0
    h = 1.0 / steps
    for i in range(steps):
        x = x + h * u(x, jnp.float32(i * steps**-1))
    return x


def test_flow_transports_noise_to_target():
    """Fine Euler integration of the ideal VF must land near the dataset."""
    spec = model.MODELS["checker2-ot"]
    u = model.make_velocity_fn(spec, use_kernel=False)
    mu = datasets.get("checker2")
    rng = np.random.default_rng(2)
    x0 = jnp.asarray(rng.normal(size=(256, 2)), jnp.float32)
    x1 = np.asarray(_euler_sample(u, x0))
    # Every sample should be within a few gamma of some dataset point.
    d2 = ((x1[:, None, :] - mu[None, :, :]) ** 2).sum(-1).min(axis=1)
    assert np.sqrt(d2).mean() < 5 * spec.gamma


def test_theorem23_same_coupling_across_schedulers():
    """Thm 2.3: all ideal VFs over Gaussian paths define the same noise->data
    coupling; integrating OT and CS fields from the same x0 must agree."""
    x0 = jnp.asarray(np.random.default_rng(3).normal(size=(128, 2)), jnp.float32)
    ends = {}
    for name in ["checker2-ot", "checker2-cs"]:
        u = model.make_velocity_fn(model.MODELS[name], use_kernel=False)
        ends[name] = np.asarray(_euler_sample(u, x0, steps=800))
    err = np.sqrt(((ends["checker2-ot"] - ends["checker2-cs"]) ** 2).mean())
    assert err < 0.1, f"coupling mismatch RMSE={err}"


def test_mlp_velocity_shapes_and_grad():
    params = model.init_mlp_params(2, 32, 2, seed=0)
    x = jnp.zeros((8, 2))
    out = model.mlp_velocity(params, x, jnp.float32(0.5), use_kernel=False)
    assert out.shape == (8, 2)
    out_k = model.mlp_velocity(params, x, jnp.float32(0.5), use_kernel=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_k), rtol=2e-5, atol=2e-5)


def test_ideal_coefs_no_singularity():
    """a_t, b_t stay finite for all schedulers at t in {0, 1} (DESIGN.md §2)."""
    for name in ["ot", "cs", "vp"]:
        s = schedulers.get(name)
        for t in [0.0, 0.5, 1.0]:
            a_t, b_t, cg, cb = model.ideal_coefs(s, jnp.float32(t), 0.05)
            vals = [float(a_t), float(b_t), float(cg), float(cb)]
            assert all(np.isfinite(v) for v in vals), (name, t, vals)


@pytest.mark.parametrize("ds", ["checker2", "tex8", "tex16", "moons2"])
def test_datasets_deterministic_and_bounded(ds):
    a = datasets.get(ds)
    b = datasets.get(ds)
    np.testing.assert_array_equal(a, b)
    assert np.isfinite(a).all()
    assert np.abs(a).max() <= 2.5
