"""AOT export smoke tests: HLO text well-formed, manifest complete, dataset
dumps round-trip."""

import json
import os

import numpy as np
import pytest

from compile import aot, datasets, model, theta as tm


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", out, "--models", "checker2-ot", "--skip-lossgrad"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    return out


def test_u_hlo_text_wellformed(art_dir):
    path = os.path.join(art_dir, "u_checker2-ot.hlo.txt")
    text = open(path).read()
    assert text.startswith("HloModule")
    assert "f32[256,2]" in text  # batch x d entry layout
    # Text format (not proto): the rust loader requires this.
    assert "ENTRY" in text


def test_manifest_contents(art_dir):
    man = json.load(open(os.path.join(art_dir, "manifest.json")))
    m = man["models"]["checker2-ot"]
    assert m["batch"] == 256 and m["d"] == 2 and m["sched"] == "ot"
    ds = man["datasets"]["checker2"]
    assert ds["k"] == 512 and ds["d"] == 2


def test_dataset_dump_roundtrip(art_dir):
    man = json.load(open(os.path.join(art_dir, "manifest.json")))
    ds = man["datasets"]["checker2"]
    raw = np.fromfile(os.path.join(art_dir, ds["file"]), dtype="<f4")
    pts = raw.reshape(ds["k"], ds["d"])
    np.testing.assert_array_equal(pts, datasets.get("checker2"))


def test_lossgrad_export_small(tmp_path):
    """Export one small loss-grad artifact and sanity-check its signature."""
    spec = model.MODELS["checker2-ot"]
    name = aot.export_lossgrad(spec, "rk2", 4, str(tmp_path))
    text = open(os.path.join(str(tmp_path), name)).read()
    p = tm.n_params("rk2", 4)
    assert text.startswith("HloModule")
    assert f"f32[{p}]" in text  # theta / grad
    assert "f32[256,5,2]" in text  # snapshots [B, n+1, d]


def test_model_registry_consistency():
    for name, spec in model.MODELS.items():
        assert spec.name == name
        assert spec.dataset in datasets.DATASETS
        assert spec.sched in ("ot", "cs", "vp")
        for base, n in spec.lossgrads:
            assert base in ("rk1", "rk2") and 2 <= n <= 20
