"""Theta codec invariants (paper eq. 74/76; mirrored by rust solvers/theta.rs)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import theta as tm


@pytest.mark.parametrize("base,n", [("rk1", 4), ("rk1", 10), ("rk2", 5), ("rk2", 8)])
def test_identity_init_decodes_to_identity(base, n):
    dec = tm.decode(tm.identity_init(base, n), base, n)
    g = tm.grid_points(base, n)
    np.testing.assert_allclose(np.asarray(dec["t"]), np.linspace(0, 1, g), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dec["tdot"]), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dec["s"]), 1.0)
    np.testing.assert_allclose(np.asarray(dec["sdot"]), 0.0)


@settings(max_examples=50, deadline=None)
@given(
    base=st.sampled_from(["rk1", "rk2"]),
    n=st.integers(2, 12),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.01, 3.0),
)
def test_decode_invariants_hold_for_any_raw_theta(base, n, seed, scale):
    """Constraints of eq. 18/21 hold for arbitrary raw vectors."""
    p = tm.n_params(base, n)
    raw = np.random.default_rng(seed).normal(size=p).astype(np.float32) * scale
    dec = tm.decode(raw, base, n)
    t = np.asarray(dec["t"])
    assert t[0] == 0.0 and abs(t[-1] - 1.0) < 1e-6
    assert (np.diff(t) > 0).all(), "t grid must be strictly increasing"
    assert (np.asarray(dec["tdot"]) > 0).all()
    s = np.asarray(dec["s"])
    assert s[0] == 1.0 and (s > 0).all()
    assert dec["sdot"].shape == (tm.grid_points(base, n) - 1,)


def test_n_params_counts():
    assert tm.n_params("rk1", 5) == 20  # 4n
    assert tm.n_params("rk2", 5) == 40  # 8n
    assert tm.n_params("rk2", 10) == 80  # the paper's "80 learnable parameters"


@pytest.mark.parametrize("mode", ["full", "time-only", "scale-only"])
def test_ablation_masks(mode):
    mask = tm.ablation_mask("rk2", 5, mode)
    p = tm.n_params("rk2", 5)
    assert mask.shape == (p,)
    half = p // 2
    if mode == "full":
        assert mask.sum() == p
    elif mode == "time-only":
        assert mask[:half].sum() == half and mask[half:].sum() == 0
    else:
        assert mask[:half].sum() == 0 and mask[half:].sum() == half


def test_ablation_mask_rejects_unknown_mode():
    with pytest.raises(ValueError):
        tm.ablation_mask("rk2", 5, "bogus")
