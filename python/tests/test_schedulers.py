"""Scheduler invariants (paper eq. 22) and analytic-derivative checks."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import schedulers

ALL = list(schedulers.SCHEDULERS)


@pytest.mark.parametrize("name", ALL)
def test_boundary_conditions(name):
    s = schedulers.get(name)
    # alpha_0 ~ 0, alpha_1 = 1, sigma_0 ~ 1, sigma_1 ~ 0 (VP reaches the
    # boundaries only approximately by construction, eq. 85).
    assert float(s.alpha(jnp.asarray(0.0))) == pytest.approx(0.0, abs=7e-3)
    assert float(s.alpha(jnp.asarray(1.0))) == pytest.approx(1.0, abs=1e-6)
    assert float(s.sigma(jnp.asarray(0.0))) == pytest.approx(1.0, abs=1e-4)
    assert float(s.sigma(jnp.asarray(1.0))) == pytest.approx(0.0, abs=1e-5)


@pytest.mark.parametrize("name", ALL)
def test_snr_strictly_monotone(name):
    s = schedulers.get(name)
    t = jnp.linspace(1e-3, 1.0 - 1e-3, 513)
    snr = np.asarray(s.snr(t))
    assert (np.diff(snr) > 0).all(), "snr must be strictly increasing"


@pytest.mark.parametrize("name", ALL)
def test_derivatives_match_finite_differences(name, x64):
    s = schedulers.get(name)
    t = jnp.linspace(0.01, 0.99, 197)
    eps = 1e-7
    fd_a = (s.alpha(t + eps) - s.alpha(t - eps)) / (2 * eps)
    fd_s = (s.sigma(t + eps) - s.sigma(t - eps)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(s.d_alpha(t)), np.asarray(fd_a), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s.d_sigma(t)), np.asarray(fd_s), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ALL)
def test_alpha_sigma_in_unit_interval(name):
    s = schedulers.get(name)
    t = jnp.linspace(0.0, 1.0, 257)
    a, sg = np.asarray(s.alpha(t)), np.asarray(s.sigma(t))
    # float32 rounding at the endpoints (cos(pi/2) ~ -4.4e-8) is fine.
    assert (a >= -1e-6).all() and (a <= 1 + 1e-6).all()
    assert (sg >= -1e-6).all() and (sg <= 1 + 1e-6).all()


def test_vp_variance_preserving():
    s = schedulers.get("vp")
    t = jnp.linspace(0.0, 1.0, 101)
    np.testing.assert_allclose(
        np.asarray(s.alpha(t)) ** 2 + np.asarray(s.sigma(t)) ** 2, 1.0, atol=1e-6
    )
