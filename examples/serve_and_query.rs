//! End-to-end serving driver: starts the JSONL sampling + training server
//! in-process, fires concurrent client workloads at it over real TCP, and
//! reports latency / throughput / batching metrics — then exercises the
//! registry plane: submit an in-server training job, poll it to
//! completion, and sample through the freshly registered artifact with a
//! `bespoke:model=...` spec (hot-swap; no restart). The repo's
//! serving-paper "load a model and serve batched requests" proof point
//! (EXPERIMENTS.md §Serving).
//!
//!   cargo run --release --example serve_and_query -- [n_clients] [reqs_per_client]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use bespoke_flow::config::{EvalConfig, QualityConfig, ServeConfig, TrainConfig};
use bespoke_flow::coordinator::{serve, Coordinator, ServerState};
use bespoke_flow::json::Value;
use bespoke_flow::models::Zoo;
use bespoke_flow::quality::{EvalRunner, EvalRunnerDyn};
use bespoke_flow::registry::{JobManager, Registry, TrainJobManager, ZooRunner};
use bespoke_flow::util::Histogram;
use bespoke_flow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_clients: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let reqs: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(20);
    let addr = "127.0.0.1:7091";

    // --- server -----------------------------------------------------------
    let zoo = Arc::new(Zoo::open_default()?);
    let cfg =
        ServeConfig { addr: addr.into(), max_batch: 256, fuse_window_us: 3_000, ..ServeConfig::default() };
    let registry_root = std::env::temp_dir().join(format!("serve_demo_reg_{}", std::process::id()));
    let registry = Arc::new(Registry::open(&registry_root)?);
    let coord = Arc::new(Coordinator::with_registry(zoo.clone(), cfg, registry.clone()));
    // In-server training jobs: short runs so the demo finishes quickly.
    let train_cfg = TrainConfig {
        iters: 40,
        pool_batches: 2,
        val_batches: 1,
        val_every: 10,
        ..TrainConfig::default()
    };
    let jobs = Arc::new(TrainJobManager::new(
        registry.clone(),
        Arc::new(ZooRunner::new(zoo.clone(), train_cfg)),
        1,
        Some(coord.metrics.clone()),
    )?);
    // Quality plane: eval jobs measure scorecards the Pareto frontier (and
    // budget-aware requests) are built from. Small eval batches keep the
    // demo fast.
    let eval_runner = Arc::new(EvalRunner::new(
        zoo,
        registry.clone(),
        EvalConfig { gt_tol: 1e-4, ..EvalConfig::default() },
        QualityConfig { eval_batches: 2, ..QualityConfig::default() },
    ));
    let eval_jobs = Arc::new(JobManager::new(
        registry,
        eval_runner as Arc<EvalRunnerDyn>,
        1,
        Some(coord.metrics.clone()),
    )?);
    let metrics = coord.metrics.clone();
    {
        let state = ServerState::with_jobs(coord.clone(), jobs).with_eval_jobs(eval_jobs);
        std::thread::spawn(move || serve(state, addr).expect("server"));
    }
    std::thread::sleep(std::time::Duration::from_millis(200));

    // --- clients ----------------------------------------------------------
    let started = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut lat = Vec::new();
            for r in 0..reqs {
                let req = format!(
                    "{{\"cmd\":\"sample\",\"model\":\"checker2-ot\",\"solver\":\"rk2:n=5\",\
                     \"n_samples\":32,\"seed\":{}}}\n",
                    c * 1000 + r
                );
                let t0 = std::time::Instant::now();
                writer.write_all(req.as_bytes())?;
                writer.flush()?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let v = Value::parse(&line)?;
                assert!(v.get("ok")?.as_bool()?, "server error: {line}");
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lat)
        }));
    }

    let mut all = Histogram::new();
    for h in handles {
        for l in h.join().unwrap()? {
            all.record_ms(l);
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let total_samples = n_clients * reqs * 32;
    println!("=== serving workload: {n_clients} clients x {reqs} requests x 32 samples ===");
    println!(
        "throughput: {:.0} samples/s ({:.1} req/s)",
        total_samples as f64 / wall,
        (n_clients * reqs) as f64 / wall
    );
    println!(
        "client latency: p50={:.1}ms p90={:.1}ms p99={:.1}ms mean={:.1}ms",
        all.quantile_ms(0.5),
        all.quantile_ms(0.9),
        all.quantile_ms(0.99),
        all.mean_ms()
    );
    // --- streaming trajectory ---------------------------------------------
    // The sample_traj command emits one JSONL event per solver step with the
    // intermediate states, then a final "done" summary line.
    {
        let stream = TcpStream::connect(addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        writer.write_all(
            b"{\"cmd\":\"sample_traj\",\"model\":\"checker2-ot\",\"solver\":\"rk2:n=5\",\
              \"n_samples\":2,\"seed\":1,\"every\":2}\n",
        )?;
        writer.flush()?;
        let mut events = 0usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let v = Value::parse(&line)?;
            assert!(v.get("ok")?.as_bool()?, "server error: {line}");
            if v.get("event")?.as_str()? == "done" {
                println!(
                    "sample_traj: {events} step events streamed, nfe={}",
                    v.get("nfe")?.as_usize()?
                );
                break;
            }
            events += 1;
        }
    }

    // --- train -> poll -> sample from the registry -------------------------
    // The training plane shares the socket: submit a job, poll job_status,
    // then a bespoke:model=... spec resolves the freshly registered
    // artifact — no restart, no path in the request.
    {
        let stream = TcpStream::connect(addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut ask = |line: &str| -> Result<Value> {
            writer.write_all(line.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            let mut out = String::new();
            reader.read_line(&mut out)?;
            Ok(Value::parse(&out)?)
        };

        let v = ask(r#"{"cmd":"train","model":"checker2-ot","base":"rk2","n":4}"#)?;
        assert!(v.get("ok")?.as_bool()?, "train rejected: {v:?}");
        let job_id = v.get("job_id")?.as_usize()?;
        println!("train job {job_id} submitted; polling...");
        loop {
            let s = ask(&format!(r#"{{"cmd":"job_status","job_id":{job_id}}}"#))?;
            assert!(s.get("ok")?.as_bool()?, "job_status: {s:?}");
            let state = s.get("state")?.as_str()?.to_string();
            println!(
                "  job {job_id}: {state} ({}/{} iters)",
                s.get("iters_done")?.as_usize()?,
                s.get("iters_total")?.as_usize()?
            );
            match state.as_str() {
                "done" => {
                    let art = s.get("artifact")?;
                    println!(
                        "  registered v{} val_rmse={}",
                        art.get("version")?.as_usize()?,
                        art.get("val_rmse")?.as_f64()?
                    );
                    break;
                }
                "failed" => panic!("training failed: {s:?}"),
                _ => std::thread::sleep(std::time::Duration::from_millis(500)),
            }
        }

        let v = ask(
            r#"{"cmd":"sample","model":"checker2-ot","solver":"bespoke:model=checker2-ot:n=4","n_samples":8,"seed":1}"#,
        )?;
        assert!(v.get("ok")?.as_bool()?, "registry sample: {v:?}");
        println!(
            "sample via bespoke:model=checker2-ot:n=4 -> nfe={} latency={:.1}ms",
            v.get("nfe")?.as_usize()?,
            v.get("latency_ms")?.as_f64()?
        );

        // --- evaluate -> frontier -> budget-routed sampling ---------------
        // Measure the freshly trained artifact into a scorecard, then let
        // the server pick the solver: the request states a budget
        // (nfe_max / latency_ms / quality) and the coordinator resolves it
        // against the Pareto frontier.
        let v = ask(
            r#"{"cmd":"evaluate","model":"checker2-ot","solver":"bespoke:model=checker2-ot:n=4"}"#,
        )?;
        assert!(v.get("ok")?.as_bool()?, "evaluate rejected: {v:?}");
        let eval_id = v.get("job_id")?.as_usize()?;
        loop {
            let s = ask(&format!(r#"{{"cmd":"eval_status","job_id":{eval_id}}}"#))?;
            assert!(s.get("ok")?.as_bool()?, "eval_status: {s:?}");
            match s.get("state")?.as_str()? {
                "done" => break,
                "failed" => panic!("eval job failed: {s:?}"),
                _ => std::thread::sleep(std::time::Duration::from_millis(200)),
            }
        }
        let f = ask(r#"{"cmd":"frontier","model":"checker2-ot"}"#)?;
        println!(
            "frontier: {} point(s) over {} measured candidate(s)",
            f.get("points")?.as_arr()?.len(),
            f.get("candidates")?.as_usize()?
        );
        let v = ask(
            r#"{"cmd":"sample","model":"checker2-ot","budget":{"nfe_max":8},"n_samples":8,"seed":1}"#,
        )?;
        assert!(v.get("ok")?.as_bool()?, "budget sample: {v:?}");
        println!(
            "sample via budget nfe_max=8 -> nfe={} latency={:.1}ms",
            v.get("nfe")?.as_usize()?,
            v.get("latency_ms")?.as_f64()?
        );
    }

    println!("--- server metrics ---");
    println!("{}", metrics.snapshot().to_string_pretty());
    std::fs::remove_dir_all(&registry_root).ok();
    Ok(())
}
