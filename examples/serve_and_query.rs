//! End-to-end serving driver: starts the JSONL sampling server in-process,
//! fires concurrent client workloads at it over real TCP, and reports
//! latency / throughput / batching metrics — the repo's serving-paper
//! "load a model and serve batched requests" proof point (EXPERIMENTS.md §Serving).
//!
//!   cargo run --release --example serve_and_query -- [n_clients] [reqs_per_client]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use bespoke_flow::config::ServeConfig;
use bespoke_flow::coordinator::{serve, Coordinator};
use bespoke_flow::json::Value;
use bespoke_flow::models::Zoo;
use bespoke_flow::util::timer::Percentiles;
use bespoke_flow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_clients: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let reqs: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(20);
    let addr = "127.0.0.1:7091";

    // --- server -----------------------------------------------------------
    let zoo = Arc::new(Zoo::open_default()?);
    let cfg =
        ServeConfig { addr: addr.into(), max_batch: 256, max_wait_ms: 3, ..ServeConfig::default() };
    let coord = Arc::new(Coordinator::new(zoo, cfg));
    let metrics = coord.metrics.clone();
    {
        let coord = coord.clone();
        std::thread::spawn(move || serve(coord, addr).expect("server"));
    }
    std::thread::sleep(std::time::Duration::from_millis(200));

    // --- clients ----------------------------------------------------------
    let started = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut lat = Vec::new();
            for r in 0..reqs {
                let req = format!(
                    "{{\"cmd\":\"sample\",\"model\":\"checker2-ot\",\"solver\":\"rk2:n=5\",\
                     \"n_samples\":32,\"seed\":{}}}\n",
                    c * 1000 + r
                );
                let t0 = std::time::Instant::now();
                writer.write_all(req.as_bytes())?;
                writer.flush()?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let v = Value::parse(&line)?;
                assert!(v.get("ok")?.as_bool()?, "server error: {line}");
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lat)
        }));
    }

    let mut all = Percentiles::default();
    for h in handles {
        for l in h.join().unwrap()? {
            all.record(l);
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let total_samples = n_clients * reqs * 32;
    println!("=== serving workload: {n_clients} clients x {reqs} requests x 32 samples ===");
    println!(
        "throughput: {:.0} samples/s ({:.1} req/s)",
        total_samples as f64 / wall,
        (n_clients * reqs) as f64 / wall
    );
    println!(
        "client latency: p50={:.1}ms p90={:.1}ms p99={:.1}ms mean={:.1}ms",
        all.quantile(0.5),
        all.quantile(0.9),
        all.quantile(0.99),
        all.mean()
    );
    // --- streaming trajectory ---------------------------------------------
    // The sample_traj command emits one JSONL event per solver step with the
    // intermediate states, then a final "done" summary line.
    {
        let stream = TcpStream::connect(addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        writer.write_all(
            b"{\"cmd\":\"sample_traj\",\"model\":\"checker2-ot\",\"solver\":\"rk2:n=5\",\
              \"n_samples\":2,\"seed\":1,\"every\":2}\n",
        )?;
        writer.flush()?;
        let mut events = 0usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let v = Value::parse(&line)?;
            assert!(v.get("ok")?.as_bool()?, "server error: {line}");
            if v.get("event")?.as_str()? == "done" {
                println!(
                    "sample_traj: {events} step events streamed, nfe={}",
                    v.get("nfe")?.as_usize()?
                );
                break;
            }
            events += 1;
        }
    }

    println!("--- server metrics ---");
    println!("{}", metrics.snapshot().to_string_pretty());
    Ok(())
}
