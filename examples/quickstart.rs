//! Quickstart: load a pre-trained flow model from the artifact manifest,
//! sample with a baseline solver and a (pre-trained, or identity) Bespoke
//! solver, and print the quality gap vs the ground-truth solver.
//!
//!   make artifacts && cargo run --release --example quickstart

use bespoke_flow::eval::{frechet_distance, rmse};
use bespoke_flow::models::{VelocityModel, Zoo};
use bespoke_flow::solvers::theta::{Base, RawTheta};
use bespoke_flow::solvers::{BespokeSolver, Dopri5, Sampler, SolverSpec};
use bespoke_flow::tensor::Tensor;
use bespoke_flow::util::Rng;
use bespoke_flow::Result;

fn main() -> Result<()> {
    // 1. Open the model zoo (artifacts/ built once by `make artifacts`).
    let zoo = Zoo::open_default()?;
    println!("models: {:?}", zoo.model_names());
    let model = zoo.hlo("checker2-ot")?;
    let (b, d) = (model.batch(), model.dim());

    // 2. Draw a noise batch and compute the GT solution (adaptive DOPRI5).
    let mut rng = Rng::new(42);
    let x0 = Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
    let gt = Dopri5::default().sample(model.as_ref(), &x0)?;

    // 3. A plain RK2 baseline at 16 NFE, via a typed solver spec. Specs
    //    parse strictly, Display canonically, and round-trip through JSON.
    let sched = zoo.scheduler("checker2-ot")?;
    let spec = SolverSpec::parse("rk2:n=8")?;
    let rk2 = spec.build(sched)?;
    let approx = rk2.sample(model.as_ref(), &x0)?;
    println!(
        "{spec}      ({} NFE): RMSE vs GT = {:.5}",
        rk2.nfe(),
        rmse(&approx, &gt)
    );

    // 3b. The same solve, step by step: `begin` opens a SolveSession that
    //     exposes the intermediate state after every Algorithm-1 step —
    //     this is what the server's `sample_traj` command streams.
    let mut session = rk2.begin(&x0)?;
    while !session.is_done() {
        let info = session.step(model.as_ref())?;
        println!(
            "  step {}/{}  t={:.3}  RMSE vs GT so far = {:.5}",
            info.step + 1,
            session.steps_total().unwrap_or(0),
            info.t,
            rmse(session.state(), &gt)
        );
    }
    assert_eq!(session.state().data(), approx.data(), "step-wise == one-shot");

    // 4. A Bespoke solver: use a trained checkpoint when present, otherwise
    //    show the identity-theta consistency anchor (== plain RK2).
    let ckpt = std::path::Path::new("out/thetas/theta_checker2-ot_rk2_n8.json");
    let theta = if ckpt.exists() {
        println!("using trained theta {}", ckpt.display());
        RawTheta::load(ckpt)?
    } else {
        println!("no trained theta found (run `repro exp tab3` or train_bespoke); using identity");
        RawTheta::identity(Base::Rk2, 8)
    };
    let bes = BespokeSolver::new(&theta);
    let bes_out = bes.sample(model.as_ref(), &x0)?;
    println!(
        "{} ({} NFE): RMSE vs GT = {:.5}",
        bes.name(),
        bes.nfe(),
        rmse(&bes_out, &gt)
    );

    // 5. Distribution-level check: Fréchet distance vs the target dataset.
    let data = zoo.manifest().load_dataset("checker2")?;
    println!(
        "FD(data): rk2={:.4}  bespoke={:.4}  gt={:.4}",
        frechet_distance(&approx, &data),
        frechet_distance(&bes_out, &data),
        frechet_distance(&gt, &data),
    );
    Ok(())
}
