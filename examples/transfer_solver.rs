//! Bespoke-solver transfer (paper Fig. 16): a theta trained on the
//! ImageNet-64 analog (tex8-ot) applied unchanged to the ImageNet-128
//! analog (tex16-ot) — theta is pure solver state, independent of data
//! dimension — compared against the native theta and the RK2 baseline.
//!
//!   cargo run --release --example transfer_solver -- [n]

use bespoke_flow::eval::rmse;
use bespoke_flow::models::{VelocityModel, Zoo};
use bespoke_flow::solvers::rk::{BaseRk, FixedGridSolver};
use bespoke_flow::solvers::theta::{Base, RawTheta};
use bespoke_flow::solvers::{BespokeSolver, Dopri5, Sampler};
use bespoke_flow::tensor::Tensor;
use bespoke_flow::util::Rng;
use bespoke_flow::Result;

fn theta_or_identity(path: &str, n: usize) -> RawTheta {
    match RawTheta::load(std::path::Path::new(path)) {
        Ok(t) => {
            println!("loaded {path}");
            t
        }
        Err(_) => {
            println!("({path} not found — run `repro exp fig16` first; using identity)");
            RawTheta::identity(Base::Rk2, n)
        }
    }
}

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let zoo = Zoo::open_default()?;
    let target = zoo.hlo("tex16-ot")?;
    let (b, d) = (target.batch(), target.dim());

    let mut rng = Rng::new(7);
    let x0 = Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
    let gt = Dopri5::default().sample(target.as_ref(), &x0)?;

    let native = theta_or_identity(&format!("out/thetas/theta_tex16-ot_rk2_n{n}.json"), n);
    let donor = theta_or_identity(&format!("out/thetas/theta_tex8-ot_rk2_n{n}.json"), n);

    let rows = [
        ("rk2 (baseline)", FixedGridSolver::uniform(BaseRk::Rk2, n).sample(target.as_ref(), &x0)?),
        ("bespoke (native tex16)", BespokeSolver::new(&native).sample(target.as_ref(), &x0)?),
        ("bespoke (transferred from tex8)", BespokeSolver::new(&donor).sample(target.as_ref(), &x0)?),
    ];
    println!("\ntex16-ot @ {} NFE:", 2 * n);
    for (name, out) in &rows {
        println!("  {:<32} RMSE vs GT = {:.5}", name, rmse(out, &gt));
    }
    println!("\npaper's finding: transferred < native, but still well above the baseline.");
    Ok(())
}
