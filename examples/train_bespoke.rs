//! End-to-end Bespoke training driver (paper Algorithm 2).
//!
//! Trains an n-step Bespoke solver for a pre-trained flow model, then
//! compares validation RMSE against the plain base solver at the same NFE
//! and writes the learned theta to disk.
//!
//! Usage:
//!   cargo run --release --example train_bespoke -- [model] [base] [n] [iters]
//!   (defaults: checker2-ot rk2 8 300)

use bespoke_flow::bespoke;
use bespoke_flow::config::TrainConfig;
use bespoke_flow::eval::rmse;
use bespoke_flow::models::{VelocityModel, Zoo};
use bespoke_flow::runtime::Executable;
use bespoke_flow::solvers::rk::{BaseRk, FixedGridSolver};
use bespoke_flow::solvers::theta::Base;
use bespoke_flow::solvers::{BespokeSolver, Dopri5, Sampler};
use bespoke_flow::tensor::Tensor;
use bespoke_flow::util::Rng;
use bespoke_flow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model_name = args.get(1).map(String::as_str).unwrap_or("checker2-ot");
    let base_name = args.get(2).map(String::as_str).unwrap_or("rk2");
    let n: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let iters: usize = args.get(4).map(|s| s.parse()).transpose()?.unwrap_or(300);

    let zoo = Zoo::open_default()?;
    let model = zoo.hlo(model_name)?;
    let base = Base::parse(base_name)?;
    let lg_meta = zoo.manifest().lossgrad(model_name, base_name, n)?;
    let lossgrad = Executable::load(&zoo.manifest().path(&lg_meta.file))?;

    let cfg = TrainConfig { iters, ..TrainConfig::default() };
    println!("training bespoke-{base_name} n={n} for {model_name} ({iters} iters)...");
    let out = bespoke::train(&model, &lossgrad, base, n, &cfg)?;
    println!(
        "done in {:.1}s; best val RMSE {:.5} (GT-path NFE spent: {})",
        out.wall_secs, out.best_val_rmse, out.gt_nfe
    );

    // Baseline comparison at identical NFE on fresh noise.
    let mut rng = Rng::new(999);
    let b = model.batch();
    let d = model.dim();
    let x0 = Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
    let gt = Dopri5::default().sample(model.as_ref(), &x0)?;
    let base_rk = match base {
        Base::Rk1 => BaseRk::Rk1,
        Base::Rk2 => BaseRk::Rk2,
    };
    let plain = FixedGridSolver::uniform(base_rk, n).sample(model.as_ref(), &x0)?;
    let bes = BespokeSolver::new(&out.best).sample(model.as_ref(), &x0)?;
    println!(
        "fresh-noise RMSE @ {} NFE:  {}={:.5}  bespoke={:.5}  ({:.1}x better)",
        n * base.evals_per_step(),
        base_name,
        rmse(&plain, &gt),
        rmse(&bes, &gt),
        rmse(&plain, &gt) / rmse(&bes, &gt).max(1e-9),
    );

    let out_path = format!("out/theta_{model_name}_{base_name}_n{n}.json");
    std::fs::create_dir_all("out")?;
    out.best.save(std::path::Path::new(&out_path))?;
    println!("saved {out_path}");
    Ok(())
}
