//! Adaptive Dormand–Prince 5(4) with dense output — the ground-truth solver
//! (the paper computes GT paths with adaptive RK45 / DOPRI5 and reads them
//! at arbitrary times via interpolation).
//!
//! Batched semantics: one shared adaptive time grid for the whole [B, d]
//! batch (torchdiffeq-style); the error norm is the max over samples of the
//! per-sample scaled RMS. Dense output is cubic Hermite on the accepted
//! nodes, which matches the O(tol) accuracy we run at (rtol = atol = 1e-5).

use anyhow::{bail, Result};

use super::{Sampler, SessionProbe, SolveSession, StepInfo};
use crate::models::VelocityModel;
use crate::tensor::Tensor;

/// Dormand–Prince coefficients (7 stages, FSAL).
const C: [f64; 7] = [0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
const A: [[f64; 6]; 7] = [
    [0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [
        19372.0 / 6561.0,
        -25360.0 / 2187.0,
        64448.0 / 6561.0,
        -212.0 / 729.0,
        0.0,
        0.0,
    ],
    [
        9017.0 / 3168.0,
        -355.0 / 33.0,
        46732.0 / 5247.0,
        49.0 / 176.0,
        -5103.0 / 18656.0,
        0.0,
    ],
    [
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
    ],
];
/// 5th-order solution weights (== A[6], FSAL).
const B5: [f64; 7] = [
    35.0 / 384.0,
    0.0,
    500.0 / 1113.0,
    125.0 / 192.0,
    -2187.0 / 6784.0,
    11.0 / 84.0,
    0.0,
];
/// Embedded 4th-order weights.
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

#[derive(Clone, Copy, Debug)]
pub struct Dopri5 {
    pub rtol: f64,
    pub atol: f64,
    pub max_steps: usize,
}

impl Default for Dopri5 {
    fn default() -> Self {
        Dopri5 { rtol: 1e-5, atol: 1e-5, max_steps: 10_000 }
    }
}

/// Accepted nodes of one solve: times, states, derivatives. Evaluate
/// anywhere in [0, 1] via cubic Hermite interpolation.
pub struct DenseSolution {
    pub ts: Vec<f32>,
    pub xs: Vec<Tensor>,
    pub fs: Vec<Tensor>,
    pub nfe: usize,
}

impl DenseSolution {
    pub fn final_state(&self) -> &Tensor {
        self.xs.last().unwrap()
    }

    fn segment(&self, t: f32) -> usize {
        // binary search for the segment [ts[k], ts[k+1]] containing t
        match self.ts.binary_search_by(|v| v.partial_cmp(&t).unwrap()) {
            Ok(k) => k.min(self.ts.len() - 2),
            Err(k) => k.saturating_sub(1).min(self.ts.len() - 2),
        }
    }

    /// x(t) by cubic Hermite interpolation on the accepted nodes.
    pub fn eval(&self, t: f32) -> Tensor {
        let t = t.clamp(0.0, 1.0);
        let k = self.segment(t);
        let (t0, t1) = (self.ts[k], self.ts[k + 1]);
        let h = t1 - t0;
        let u = ((t - t0) / h).clamp(0.0, 1.0);
        // Hermite basis
        let u2 = u * u;
        let u3 = u2 * u;
        let h00 = 2.0 * u3 - 3.0 * u2 + 1.0;
        let h10 = u3 - 2.0 * u2 + u;
        let h01 = -2.0 * u3 + 3.0 * u2;
        let h11 = u3 - u2;
        let mut out = self.xs[k].scale(h00);
        out.axpy(h10 * h, &self.fs[k]).unwrap();
        out.axpy(h01, &self.xs[k + 1]).unwrap();
        out.axpy(h11 * h, &self.fs[k + 1]).unwrap();
        out
    }

    /// dx/dt(t) from the same Hermite segment (used for diagnostics only;
    /// the trainer evaluates the model directly for snapshot velocities).
    pub fn eval_deriv(&self, t: f32) -> Tensor {
        let t = t.clamp(0.0, 1.0);
        let k = self.segment(t);
        let (t0, t1) = (self.ts[k], self.ts[k + 1]);
        let h = t1 - t0;
        let u = ((t - t0) / h).clamp(0.0, 1.0);
        let u2 = u * u;
        let d00 = (6.0 * u2 - 6.0 * u) / h;
        let d10 = 3.0 * u2 - 4.0 * u + 1.0;
        let d01 = (-6.0 * u2 + 6.0 * u) / h;
        let d11 = 3.0 * u2 - 2.0 * u;
        let mut out = self.xs[k].scale(d00);
        out.axpy(d10, &self.fs[k]).unwrap();
        out.axpy(d01, &self.xs[k + 1]).unwrap();
        out.axpy(d11, &self.fs[k + 1]).unwrap();
        out
    }
}

/// Step-wise execution of [`Dopri5`]: each [`SolveSession::step`] call
/// produces one *accepted* adaptive step (looping over rejected attempts
/// internally), optionally recording the dense-output node for it. Both
/// [`Dopri5::solve_dense`] and the one-shot `Sampler::sample` default drive
/// this same integrator, so one-shot and step-wise solves are bitwise
/// identical.
pub struct Dopri5Session {
    cfg: Dopri5,
    /// Record accepted nodes for dense output. Off for the streaming /
    /// one-shot sampling paths, which only need the running state — a
    /// tight-tolerance solve would otherwise retain O(steps x B x d)
    /// dead tensors.
    record_dense: bool,
    // accepted dense-output nodes (seeded lazily on the first step, which
    // is the first time a model is available to evaluate f(x0, 0))
    ts: Vec<f32>,
    xs: Vec<Tensor>,
    fs: Vec<Tensor>,
    t: f64,
    h: f64,
    x: Tensor,
    /// Preallocated stage derivatives k1..k7. `stages[0]` doubles as the
    /// FSAL carry f(x, t) once `seeded`; on an accepted step the stage-7
    /// buffer is swapped into slot 0 instead of cloned. All seven live for
    /// the whole session, so the attempt loop allocates nothing (dense
    /// recording, when on, clones nodes it must retain).
    stages: Vec<Tensor>,
    /// Scratch for the stage state x + h * sum a_ij k_j.
    stage_x: Tensor,
    /// 5th-order candidate solution (swapped with `x` on acceptance).
    x5: Tensor,
    /// Embedded 4th/5th error accumulator.
    err: Tensor,
    /// Whether `stages[0]` holds f(x, t) yet.
    seeded: bool,
    /// Accepted steps so far.
    accepted: usize,
    /// Attempted (accepted + rejected) steps, for the max_steps guard.
    attempts: usize,
    nfe: usize,
    /// Scaled error norm of the most recent attempt — flight-recorder
    /// probe data only, never read by the integrator itself.
    last_enorm: Option<f64>,
}

impl Dopri5Session {
    fn new(cfg: Dopri5, x0: &Tensor, record_dense: bool) -> Dopri5Session {
        Dopri5Session {
            cfg,
            record_dense,
            ts: Vec::new(),
            xs: Vec::new(),
            fs: Vec::new(),
            t: 0.0,
            h: 0.05, // initial guess; controller adapts fast
            x: x0.clone(),
            stages: (0..7).map(|_| Tensor::zeros(x0.shape())).collect(),
            stage_x: Tensor::zeros(x0.shape()),
            x5: Tensor::zeros(x0.shape()),
            err: Tensor::zeros(x0.shape()),
            seeded: false,
            accepted: 0,
            attempts: 0,
            nfe: 0,
            last_enorm: None,
        }
    }

    /// Total model evaluations so far (including rejected attempts).
    pub fn nfe_so_far(&self) -> usize {
        self.nfe
    }

    /// Consume the session into the dense solution over its accepted nodes.
    /// Call after driving to completion (the endpoint is pinned at t = 1).
    /// Only meaningful for sessions created by [`Dopri5::solve_dense`],
    /// which record nodes; plain [`Dopri5::session`] sessions keep none.
    pub fn into_dense(self) -> DenseSolution {
        DenseSolution { ts: self.ts, xs: self.xs, fs: self.fs, nfe: self.nfe }
    }

    /// One accepted step of the adaptive integrator against a generic
    /// vector field `f(x, t)`. Convenience wrapper over
    /// [`Dopri5Session::step_field_into`] for clone-returning fields.
    pub fn step_field(
        &mut self,
        f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
    ) -> Result<StepInfo> {
        let mut g = |x: &Tensor, t: f32, out: &mut Tensor| -> Result<()> {
            let r = f(x, t)?;
            out.copy_from(&r)
        };
        self.step_field_into(&mut g)
    }

    /// One accepted step against a write-into vector field `f(x, t, out)`.
    /// All stage/candidate/error storage is preallocated in the session, so
    /// the attempt loop performs zero heap allocation (dense-output
    /// recording, when enabled, clones the nodes it retains). Arithmetic is
    /// element-for-element identical to the clone-per-stage reference
    /// integrator kept in [`reference_solve`].
    pub fn step_field_into(
        &mut self,
        f: &mut dyn FnMut(&Tensor, f32, &mut Tensor) -> Result<()>,
    ) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete (t = {})", self.t);
        }
        let mut nfe_step = 0usize;
        if !self.seeded {
            f(&self.x, 0.0, &mut self.stages[0])?;
            if self.record_dense {
                self.ts.push(0.0);
                self.xs.push(self.x.clone());
                self.fs.push(self.stages[0].clone());
            }
            self.seeded = true;
            self.nfe += 1;
            nfe_step += 1;
        }
        loop {
            if self.attempts >= self.cfg.max_steps {
                bail!("dopri5: exceeded {} steps (tol too tight?)", self.cfg.max_steps);
            }
            self.attempts += 1;
            self.h = self.h.min(1.0 - self.t);
            let (t, h) = (self.t, self.h);

            // stages 2..7 into the preallocated buffers (stages[0] is the
            // FSAL carry f(x, t))
            for s in 1..7 {
                self.stage_x.copy_from(&self.x)?;
                let (prev, rest) = self.stages.split_at_mut(s);
                for (j, kj) in prev.iter().enumerate() {
                    let a = A[s][j];
                    if a != 0.0 {
                        self.stage_x.axpy((a * h) as f32, kj)?;
                    }
                }
                f(&self.stage_x, (t + C[s] * h) as f32, &mut rest[0])?;
                self.nfe += 1;
                nfe_step += 1;
            }

            // 5th order solution + embedded error
            self.x5.copy_from(&self.x)?;
            self.err.fill(0.0);
            for s in 0..7 {
                if B5[s] != 0.0 {
                    self.x5.axpy((B5[s] * h) as f32, &self.stages[s])?;
                }
                let db = B5[s] - B4[s];
                if db != 0.0 {
                    self.err.axpy((db * h) as f32, &self.stages[s])?;
                }
            }

            // scaled error: max over batch of per-sample
            // RMS(err / (atol + rtol max(|x|,|x5|)))
            let scale_tol = |a: f32, b: f32| {
                (self.cfg.atol + self.cfg.rtol * a.abs().max(b.abs()) as f64) as f32
            };
            let mut enorm = 0.0f64;
            {
                let xd = self.x.data();
                let x5d = self.x5.data();
                let ed = self.err.data();
                let dcols = self.x.cols();
                for i in 0..self.x.rows() {
                    let mut acc = 0.0f64;
                    for j in 0..dcols {
                        let idx = i * dcols + j;
                        let w = ed[idx] / scale_tol(xd[idx], x5d[idx]);
                        acc += (w as f64) * (w as f64);
                    }
                    enorm = enorm.max((acc / dcols as f64).sqrt());
                }
            }
            self.last_enorm = Some(enorm);

            let accepted = enorm <= 1.0;
            if accepted {
                self.t += h;
                std::mem::swap(&mut self.x, &mut self.x5);
                self.accepted += 1;
                // FSAL: stage 7 value = f(x5, t+h) becomes the next k1
                self.stages.swap(0, 6);
                if self.record_dense {
                    self.ts.push(self.t as f32);
                    self.xs.push(self.x.clone());
                    self.fs.push(self.stages[0].clone());
                }
            }
            // PI-free step controller
            let factor = if enorm > 0.0 {
                (0.9 * (1.0 / enorm).powf(0.2)).clamp(0.2, 5.0)
            } else {
                5.0
            };
            self.h *= factor;
            self.h = self.h.max(1e-7);

            if accepted {
                if self.is_done() && self.record_dense {
                    // pin the endpoint exactly
                    *self.ts.last_mut().unwrap() = 1.0;
                }
                return Ok(StepInfo {
                    step: self.accepted - 1,
                    t: if self.is_done() { 1.0 } else { self.t as f32 },
                    nfe: nfe_step,
                    done: self.is_done(),
                });
            }
        }
    }
}

impl SolveSession for Dopri5Session {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            // Keep the preallocated stage/candidate/error buffers (they are
            // fully overwritten every attempt; stages[0] re-seeds on the
            // first step) — same-shape re-init allocates nothing.
            self.x.copy_from(x0)?;
            self.ts.clear();
            self.xs.clear();
            self.fs.clear();
            self.t = 0.0;
            self.h = 0.05;
            self.seeded = false;
            self.accepted = 0;
            self.attempts = 0;
            self.nfe = 0;
            self.last_enorm = None;
        } else {
            *self = Dopri5Session::new(self.cfg, x0, self.record_dense);
        }
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        let mut f = |x: &Tensor, t: f32, out: &mut Tensor| model.eval_into(x, t, out);
        self.step_field_into(&mut f)
    }

    fn is_done(&self) -> bool {
        self.t >= 1.0
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn probe(&self, _last: &StepInfo) -> SessionProbe {
        SessionProbe {
            accepted: self.accepted as u64,
            rejected: (self.attempts - self.accepted) as u64,
            err_norm: self.last_enorm,
        }
    }
}

impl Dopri5 {
    /// Open a step-wise session for a generic vector field (also usable via
    /// the [`SolveSession`] trait for model fields). Keeps only the running
    /// state; use [`Dopri5::solve_dense`] when dense output is needed.
    pub fn session(&self, x0: &Tensor) -> Dopri5Session {
        Dopri5Session::new(*self, x0, false)
    }

    /// Solve dx/dt = f(x, t) from t = 0 to 1, keeping dense output.
    pub fn solve_dense(
        &self,
        f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
        x0: &Tensor,
    ) -> Result<DenseSolution> {
        let mut session = Dopri5Session::new(*self, x0, true);
        while !session.is_done() {
            session.step_field(f)?;
        }
        Ok(session.into_dense())
    }

    pub fn solve_model_dense(
        &self,
        model: &dyn VelocityModel,
        x0: &Tensor,
    ) -> Result<DenseSolution> {
        let mut f = |x: &Tensor, t: f32| model.eval(x, t);
        self.solve_dense(&mut f, x0)
    }
}

impl Sampler for Dopri5 {
    fn name(&self) -> String {
        if self.rtol == self.atol {
            format!("dopri5:tol={:.0e}", self.rtol)
        } else {
            format!("dopri5:rtol={:.0e}:atol={:.0e}", self.rtol, self.atol)
        }
    }

    fn nfe(&self) -> usize {
        0 // adaptive: actual NFE reported per solve via StepInfo / DenseSolution
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        Ok(Box::new(self.session(x0)))
    }
}

/// The pre-workspace clone-per-stage integrator, retained verbatim as the
/// bitwise reference for the zero-allocation session (equivalence tests in
/// `rust/tests/perf_equivalence.rs` and the `_naive` benchmarks). Returns
/// the final state and the total NFE.
pub fn reference_solve(
    cfg: &Dopri5,
    f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
    x0: &Tensor,
) -> Result<(Tensor, usize)> {
    let mut t = 0.0f64;
    let mut h = 0.05f64;
    let mut x = x0.clone();
    let mut k1 = f(&x, 0.0)?;
    let mut nfe = 1usize;
    let mut attempts = 0usize;
    while t < 1.0 {
        if attempts >= cfg.max_steps {
            bail!("dopri5: exceeded {} steps (tol too tight?)", cfg.max_steps);
        }
        attempts += 1;
        h = h.min(1.0 - t);

        let mut k = Vec::with_capacity(7);
        k.push(k1.clone()); // FSAL
        for s in 1..7 {
            let mut xs_stage = x.clone();
            for (j, kj) in k.iter().enumerate() {
                let a = A[s][j];
                if a != 0.0 {
                    xs_stage.axpy((a * h) as f32, kj)?;
                }
            }
            k.push(f(&xs_stage, (t + C[s] * h) as f32)?);
            nfe += 1;
        }

        let mut x5 = x.clone();
        let mut err = Tensor::zeros(x.shape());
        for s in 0..7 {
            if B5[s] != 0.0 {
                x5.axpy((B5[s] * h) as f32, &k[s])?;
            }
            let db = B5[s] - B4[s];
            if db != 0.0 {
                err.axpy((db * h) as f32, &k[s])?;
            }
        }

        let scale_tol =
            |a: f32, b: f32| (cfg.atol + cfg.rtol * a.abs().max(b.abs()) as f64) as f32;
        let mut enorm = 0.0f64;
        {
            let xd = x.data();
            let x5d = x5.data();
            let ed = err.data();
            let dcols = x.cols();
            for i in 0..x.rows() {
                let mut acc = 0.0f64;
                for j in 0..dcols {
                    let idx = i * dcols + j;
                    let w = ed[idx] / scale_tol(xd[idx], x5d[idx]);
                    acc += (w as f64) * (w as f64);
                }
                enorm = enorm.max((acc / dcols as f64).sqrt());
            }
        }

        let accepted = enorm <= 1.0;
        if accepted {
            t += h;
            x = x5;
            k1 = k.pop().unwrap(); // stage 7 value = f(x5, t+h) (FSAL)
        }
        let factor =
            if enorm > 0.0 { (0.9 * (1.0 / enorm).powf(0.2)).clamp(0.2, 5.0) } else { 5.0 };
        h *= factor;
        h = h.max(1e-7);
    }
    Ok((x, nfe))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x' = a x: exact solution known, checks tolerance + dense output.
    #[test]
    fn exponential_to_tolerance() {
        let a = -2.5f32;
        let x0 = Tensor::new(vec![1.0, 2.0], vec![1, 2]).unwrap();
        let solver = Dopri5::default();
        let mut f = |x: &Tensor, _t: f32| Ok(x.scale(a));
        let sol = solver.solve_dense(&mut f, &x0).unwrap();
        let got = sol.final_state().data()[0];
        let want = (a).exp();
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        // dense output accuracy at interior points
        for i in 1..10 {
            let t = i as f32 / 10.0;
            let v = sol.eval(t).data()[1];
            let exact = 2.0 * (a * t).exp();
            assert!((v - exact).abs() < 5e-4, "t={t}: {v} vs {exact}");
        }
        assert!(sol.nfe > 7);
    }

    #[test]
    fn nonautonomous_field() {
        // x' = 2t  ->  x(t) = x0 + t^2
        let x0 = Tensor::new(vec![0.5], vec![1, 1]).unwrap();
        let mut f = |x: &Tensor, t: f32| Ok(Tensor::full(x.shape(), 2.0 * t));
        let sol = Dopri5::default().solve_dense(&mut f, &x0).unwrap();
        assert!((sol.final_state().data()[0] - 1.5).abs() < 1e-5);
        let mid = sol.eval(0.5).data()[0];
        assert!((mid - 0.75).abs() < 1e-4);
        // derivative of the interpolant
        let d = sol.eval_deriv(0.5).data()[0];
        assert!((d - 1.0).abs() < 1e-3, "deriv {d}");
    }

    #[test]
    fn eval_clamps_out_of_range() {
        let x0 = Tensor::new(vec![1.0], vec![1, 1]).unwrap();
        let mut f = |x: &Tensor, _t: f32| Ok(x.scale(0.0));
        let sol = Dopri5::default().solve_dense(&mut f, &x0).unwrap();
        assert_eq!(sol.eval(-1.0).data()[0], 1.0);
        assert_eq!(sol.eval(2.0).data()[0], 1.0);
    }

    #[test]
    fn stiffness_guard_errors_out() {
        let solver = Dopri5 { rtol: 1e-12, atol: 1e-14, max_steps: 8 };
        let x0 = Tensor::new(vec![1.0], vec![1, 1]).unwrap();
        let mut f = |x: &Tensor, t: f32| Ok(x.scale((30.0 * t).sin() * 20.0));
        assert!(solver.solve_dense(&mut f, &x0).is_err());
    }

    /// x' = a x as a VelocityModel, to exercise the SolveSession path.
    struct Expo;
    impl crate::models::VelocityModel for Expo {
        fn name(&self) -> &str {
            "expo"
        }
        fn batch(&self) -> usize {
            1
        }
        fn dim(&self) -> usize {
            2
        }
        fn eval(&self, x: &Tensor, _t: f32) -> Result<Tensor> {
            Ok(x.scale(-2.5))
        }
    }

    #[test]
    fn session_matches_dense_solve_bitwise() {
        let m = Expo;
        let x0 = Tensor::new(vec![1.0, 2.0], vec![1, 2]).unwrap();
        let solver = Dopri5::default();
        let dense = solver.solve_model_dense(&m, &x0).unwrap();
        // one-shot sample() drives a session; must equal the dense path
        let one_shot = solver.sample(&m, &x0).unwrap();
        assert_eq!(one_shot.data(), dense.final_state().data());
        // manual stepping: identical final state and total NFE
        let mut sess = solver.begin(&x0).unwrap();
        assert_eq!(sess.steps_total(), None);
        let mut nfe = 0usize;
        let mut last_t = 0.0f32;
        while !sess.is_done() {
            let info = sess.step(&m).unwrap();
            assert!(info.t > last_t, "time must advance");
            last_t = info.t;
            nfe += info.nfe;
        }
        assert_eq!(last_t, 1.0, "endpoint pinned at t = 1");
        assert_eq!(sess.state().data(), dense.final_state().data());
        assert_eq!(nfe, dense.nfe);
        assert!(sess.step(&m).is_err());
    }
}
