//! The learned **Bespoke** samplers (the paper's contribution): scale-time
//! transformed RK1 (eq. 17) and RK2/midpoint (eq. 19-20) steps driven by a
//! decoded theta. At identity theta these coincide exactly with the plain
//! base solvers (consistency anchor, Theorem 2.2 — tested below).

use anyhow::{bail, Result};

use super::theta::{Base, DecodedTheta, RawTheta};
use super::{Sampler, SolveSession, StepInfo};
use crate::models::VelocityModel;
use crate::tensor::{Tensor, Workspace};

pub struct BespokeSolver {
    pub theta: DecodedTheta,
    label: String,
}

impl BespokeSolver {
    pub fn new(raw: &RawTheta) -> BespokeSolver {
        BespokeSolver {
            theta: raw.decode(),
            label: format!("bespoke-{}:n={}", raw.base.name(), raw.n),
        }
    }

    pub fn with_label(raw: &RawTheta, label: impl Into<String>) -> BespokeSolver {
        BespokeSolver { theta: raw.decode(), label: label.into() }
    }

    /// Scratch tensors one [`BespokeSolver::step_into`] call draws from its
    /// workspace.
    pub fn stage_buffers(&self) -> usize {
        match self.theta.base {
            Base::Rk1 => 1,
            Base::Rk2 => 3,
        }
    }

    /// One Bespoke step computed **in place** (paper eq. 17 / 19-20), with
    /// scratch drawn from `ws`: zero heap allocation once the pool is
    /// warm, element-for-element identical to [`BespokeSolver::step`].
    pub fn step_into(
        &self,
        model: &dyn VelocityModel,
        x: &mut Tensor,
        i: usize,
        ws: &mut Workspace,
    ) -> Result<()> {
        let th = &self.theta;
        let n = th.n;
        if i >= n {
            bail!("step index {i} out of range for n={n}");
        }
        let h = 1.0f32 / n as f32;
        match th.base {
            Base::Rk1 => {
                let (s_i, s_ip) = (th.s[i], th.s[i + 1]);
                let mut u = ws.acquire(x.shape());
                model.eval_into(x, th.t[i], &mut u)?;
                // x_{i+1} = ((s_i + h sdot_i)/s_{i+1}) x + h tdot_i (s_i/s_{i+1}) u
                x.scale_axpy((s_i + h * th.sdot[i]) / s_ip, h * th.tdot[i] * s_i / s_ip, &u)?;
                ws.release(u);
            }
            Base::Rk2 => {
                let j = 2 * i;
                let (s_i, s_h, s_ip) = (th.s[j], th.s[j + 1], th.s[j + 2]);
                let (t_i, t_h) = (th.t[j], th.t[j + 1]);
                let (td_i, td_h) = (th.tdot[j], th.tdot[j + 1]);
                let (sd_i, sd_h) = (th.sdot[j], th.sdot[j + 1]);
                // z_i = (s_i + h/2 sdot_i) x + h/2 s_i tdot_i u(x, t_i)   (eq. 20)
                let mut u = ws.acquire(x.shape());
                model.eval_into(x, t_i, &mut u)?;
                let mut z = ws.acquire(x.shape());
                x.scale_into(s_i + 0.5 * h * sd_i, &mut z)?;
                z.axpy(0.5 * h * s_i * td_i, &u)?;
                // u2 = u(z / s_{i+1/2}, t_{i+1/2})
                let mut zs = ws.acquire(x.shape());
                z.scale_into(1.0 / s_h, &mut zs)?;
                model.eval_into(&zs, t_h, &mut u)?; // u now holds u2
                // x_{i+1} = (s_i/s_{i+1}) x + (h/s_{i+1}) [ (sdot_h/s_h) z + tdot_h s_h u2 ]
                x.scale_axpy(s_i / s_ip, h / s_ip * sd_h / s_h, &z)?;
                x.axpy(h / s_ip * td_h * s_h, &u)?;
                ws.release(zs);
                ws.release(z);
                ws.release(u);
            }
        }
        Ok(())
    }

    /// One Bespoke step from integer step index i (paper eq. 17 / 19-20).
    /// Clone-per-stage reference path; the session loop uses
    /// [`BespokeSolver::step_into`].
    pub fn step(
        &self,
        model: &dyn VelocityModel,
        x: &Tensor,
        i: usize,
    ) -> Result<Tensor> {
        let th = &self.theta;
        let n = th.n;
        if i >= n {
            bail!("step index {i} out of range for n={n}");
        }
        let h = 1.0f32 / n as f32;
        match th.base {
            Base::Rk1 => {
                let (s_i, s_ip) = (th.s[i], th.s[i + 1]);
                let u = model.eval(x, th.t[i])?;
                // x_{i+1} = ((s_i + h sdot_i)/s_{i+1}) x + h tdot_i (s_i/s_{i+1}) u
                let mut out = x.scale((s_i + h * th.sdot[i]) / s_ip);
                out.axpy(h * th.tdot[i] * s_i / s_ip, &u)?;
                Ok(out)
            }
            Base::Rk2 => {
                let j = 2 * i;
                let (s_i, s_h, s_ip) = (th.s[j], th.s[j + 1], th.s[j + 2]);
                let (t_i, t_h) = (th.t[j], th.t[j + 1]);
                let (td_i, td_h) = (th.tdot[j], th.tdot[j + 1]);
                let (sd_i, sd_h) = (th.sdot[j], th.sdot[j + 1]);
                // z_i = (s_i + h/2 sdot_i) x + h/2 s_i tdot_i u(x, t_i)   (eq. 20)
                let u1 = model.eval(x, t_i)?;
                let mut z = x.scale(s_i + 0.5 * h * sd_i);
                z.axpy(0.5 * h * s_i * td_i, &u1)?;
                // u2 = u(z / s_{i+1/2}, t_{i+1/2})
                let u2 = model.eval(&z.scale(1.0 / s_h), t_h)?;
                // x_{i+1} = (s_i/s_{i+1}) x + (h/s_{i+1}) [ (sdot_h/s_h) z + tdot_h s_h u2 ]
                let mut out = x.scale(s_i / s_ip);
                out.axpy(h / s_ip * sd_h / s_h, &z)?;
                out.axpy(h / s_ip * td_h * s_h, &u2)?;
                Ok(out)
            }
        }
    }
}

/// Step-wise execution of a [`BespokeSolver`]: one learned scale-time step
/// per [`SolveSession::step`], identical arithmetic to the one-shot loop.
/// Scratch tensors are pre-allocated in [`Sampler::begin`] and recycled
/// through the session's [`Workspace`]: zero heap allocation per step.
pub struct BespokeSession<'a> {
    solver: &'a BespokeSolver,
    x: Tensor,
    i: usize,
    ws: Workspace,
}

impl SolveSession for BespokeSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            self.x.copy_from(x0)?;
        } else {
            // Width-agnostic re-init: top the pool up for the new shape,
            // keeping buffers of widths already visited (DESIGN.md §10).
            self.x = x0.clone();
            self.ws.ensure(x0.shape(), self.solver.stage_buffers());
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        self.solver.step_into(model, &mut self.x, self.i, &mut self.ws)?;
        self.i += 1;
        let th = &self.solver.theta;
        Ok(StepInfo {
            step: self.i - 1,
            // model time reached: the decoded t at integer grid point i
            t: th.t[th.stride() * self.i],
            nfe: th.base.evals_per_step(),
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i >= self.solver.theta.n
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.theta.n)
    }
}

impl Sampler for BespokeSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        self.theta.n * self.theta.base.evals_per_step()
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        Ok(Box::new(BespokeSession {
            solver: self,
            x: x0.clone(),
            i: 0,
            ws: Workspace::preallocate(x0.shape(), self.stage_buffers()),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;
    use crate::solvers::dopri5::Dopri5;
    use crate::solvers::rk::{BaseRk, FixedGridSolver};
    use crate::util::Rng;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![0.9, 0.1], vec![-0.7, -0.5], vec![0.2, 1.1]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 8).unwrap()
    }

    /// Consistency anchor: identity theta == plain base solver.
    #[test]
    fn identity_theta_equals_base_solver() {
        let model = toy();
        let mut rng = Rng::new(3);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        for (base, rk, n) in [(Base::Rk1, BaseRk::Rk1, 6), (Base::Rk2, BaseRk::Rk2, 6)] {
            let bes = BespokeSolver::new(&RawTheta::identity(base, n));
            let plain = FixedGridSolver::uniform(rk, n);
            let a = bes.sample(&model, &x0).unwrap();
            let b = plain.sample(&model, &x0).unwrap();
            let err = a.sub(&b).unwrap().linf();
            // decode eps (1e-6 positivity floor) perturbs tdot by ~n*1e-5
            assert!(err < 1e-3, "{base:?}: identity mismatch linf={err}");
        }
    }

    /// Theorem 2.2: Bespoke solvers keep the base order. Perturb theta and
    /// check the empirical order of convergence on the analytic model.
    #[test]
    fn perturbed_theta_keeps_order_two() {
        let model = toy();
        let mut rng = Rng::new(5);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let gt = Dopri5 { rtol: 1e-8, atol: 1e-8, max_steps: 100_000 }
            .sample(&model, &x0)
            .unwrap();
        // A genuine (smooth) scale-time transform, sampled consistently:
        // t_r = r + 0.15 sin(pi r) (monotone), s_r = exp(0.2 sin(pi r)).
        // Theorem 2.2 guarantees order-2 for members of the family F —
        // the grid values AND their derivatives must come from the same
        // smooth functions.
        let t_of = |r: f32| r + 0.15 * (std::f32::consts::PI * r).sin();
        let td_of = |r: f32| 1.0 + 0.15 * std::f32::consts::PI * (std::f32::consts::PI * r).cos();
        let s_of = |r: f32| (0.2 * (std::f32::consts::PI * r).sin()).exp();
        let sd_of =
            |r: f32| 0.2 * std::f32::consts::PI * (std::f32::consts::PI * r).cos() * s_of(r);
        let err_at = |n: usize| {
            let m = Base::Rk2.grid_points(n) - 1;
            let mut raw = vec![0.0f32; 4 * m];
            for j in 0..m {
                let r0 = j as f32 / m as f32;
                let r1 = (j + 1) as f32 / m as f32;
                raw[j] = t_of(r1) - t_of(r0); // dt
                raw[m + j] = td_of(r0) / m as f32; // tdot (decode multiplies by m)
                raw[2 * m + j] = s_of(r1).ln(); // log s at grid 1..m
                raw[3 * m + j] = sd_of(r0); // sdot
            }
            let bes = BespokeSolver::new(&RawTheta::from_raw(Base::Rk2, n, raw).unwrap());
            bes.sample(&model, &x0).unwrap().sub(&gt).unwrap().rms()
        };
        let (e8, e16) = (err_at(8), err_at(16));
        let order = (e8 / e16).log2();
        assert!(order > 1.5, "expected order ~2, got {order} (e8={e8}, e16={e16})");
    }

    #[test]
    fn nfe_counts() {
        assert_eq!(BespokeSolver::new(&RawTheta::identity(Base::Rk1, 10)).nfe(), 10);
        assert_eq!(BespokeSolver::new(&RawTheta::identity(Base::Rk2, 10)).nfe(), 20);
    }

    #[test]
    fn step_index_bounds() {
        let model = toy();
        let bes = BespokeSolver::new(&RawTheta::identity(Base::Rk2, 3));
        let x = Tensor::zeros(&[8, 2]);
        assert!(bes.step(&model, &x, 3).is_err());
    }

    /// Step-wise session == the explicit step loop, bitwise.
    #[test]
    fn session_matches_step_loop_bitwise() {
        let model = toy();
        let mut rng = Rng::new(9);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let bes = BespokeSolver::new(&RawTheta::identity(Base::Rk2, 5));
        let mut x = x0.clone();
        for i in 0..5 {
            x = bes.step(&model, &x, i).unwrap();
        }
        let one_shot = bes.sample(&model, &x0).unwrap();
        assert_eq!(one_shot.data(), x.data());
        let mut sess = bes.begin(&x0).unwrap();
        assert_eq!(sess.steps_total(), Some(5));
        let mut nfe = 0usize;
        while !sess.is_done() {
            nfe += sess.step(&model).unwrap().nfe;
        }
        assert_eq!(sess.state().data(), x.data());
        assert_eq!(nfe, bes.nfe());
        assert!(sess.step(&model).is_err());
    }
}
