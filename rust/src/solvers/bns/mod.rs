//! Non-stationary solver families (DESIGN.md §11): per-step learned
//! coefficients instead of one stationary step transform.
//!
//! * [`BnsSolver`] — BNS-style per-step coefficient steps (arXiv
//!   2403.01329): each step i applies its own `(a_i, b_i)` (rk1) or
//!   `(a_i, b1_i, b2_i)` (rk2) mix of the previous state and the stage
//!   velocities, on a fixed uniform time grid `t_i = i/n`.
//! * [`MultistepSolver`] — S4S-style learned multistep (arXiv 2502.17423):
//!   one velocity evaluation per step, mixed with a ring buffer of the
//!   previous `window` evaluations via learned per-step coefficients.
//! * [`AbSolver`] — classical Adams–Bashforth history reuse (arXiv
//!   2411.07627): the training-free baseline that pressure-tests whether
//!   BNS/multistep training earns its cost.
//!
//! All three are [`Sampler`]s whose sessions follow the bespoke idioms:
//! stage scratch comes from a pre-warmed [`crate::tensor::Workspace`]
//! (zero heap allocation per step), `init` is width-agnostic for the
//! fusion plane, and every kernel is row-independent — history tensors
//! are full-batch, so fused and solo solves stay byte-identical.

pub mod ab;
pub mod multistep;
pub mod solver;

pub use ab::AbSolver;
pub use multistep::MultistepSolver;
pub use solver::BnsSolver;

use anyhow::{bail, Result};

use super::theta::{Family, RawTheta};
use super::Sampler;

/// Build the right sampler for a loaded theta, dispatching on its family.
/// This is what lets `bespoke:path=...` (and the registry/budget-routing
/// paths built on it) serve any trained family transparently.
pub fn sampler_for_theta(raw: &RawTheta) -> Result<Box<dyn Sampler>> {
    Ok(match raw.family {
        Family::Stationary => Box::new(super::bespoke::BespokeSolver::new(raw)),
        Family::Bns => Box::new(BnsSolver::new(raw)?),
        Family::Multistep => Box::new(MultistepSolver::new(raw)?),
    })
}

/// Shared guard for the family-specific constructors.
pub(crate) fn expect_family(raw: &RawTheta, want: Family) -> Result<()> {
    if raw.family != want {
        bail!(
            "theta is family={}, expected {}",
            raw.family.name(),
            want.name()
        );
    }
    Ok(())
}
