//! The BNS (non-stationary) sampler: per-step learned coefficients on a
//! fixed uniform time grid. Step i of an n-step solve (h = 1/n, t_i = i/n):
//!
//! ```text
//! rk1:  u1 = u(x, t_i)
//!       x' = a_i x + h b_i u1
//! rk2:  u1 = u(x, t_i);  z = x + (h/2) u1;  u2 = u(z, t_i + h/2)
//!       x' = a_i x + h b1_i u1 + h b2_i u2
//! ```
//!
//! At identity coefficients (a=1, b=1 / a=1, b1=0, b2=1) this is exactly
//! the plain base RK solver. Keeping the grid fixed (not learned) keeps
//! the GT-matching loss linear in the coefficients, which is what makes
//! the closed-form trainer in `bespoke::families` possible.

use anyhow::{bail, Result};

use super::expect_family;
use crate::models::VelocityModel;
use crate::solvers::theta::{Base, Family, RawTheta};
use crate::solvers::{Sampler, SolveSession, StepInfo};
use crate::tensor::{Tensor, Workspace};

pub struct BnsSolver {
    pub theta: RawTheta,
    label: String,
}

impl BnsSolver {
    pub fn new(raw: &RawTheta) -> Result<BnsSolver> {
        expect_family(raw, Family::Bns)?;
        Ok(BnsSolver {
            theta: raw.clone(),
            label: format!("bns-{}:n={}", raw.base.name(), raw.n),
        })
    }

    pub fn with_label(raw: &RawTheta, label: impl Into<String>) -> Result<BnsSolver> {
        expect_family(raw, Family::Bns)?;
        Ok(BnsSolver { theta: raw.clone(), label: label.into() })
    }

    /// Per-step coefficient stride in `raw`: `[a, b]` (rk1) or
    /// `[a, b1, b2]` (rk2).
    pub fn stride(&self) -> usize {
        1 + self.theta.base.evals_per_step()
    }

    /// The coefficients of step i.
    pub fn coeffs(&self, i: usize) -> &[f32] {
        let k = self.stride();
        &self.theta.raw[k * i..k * (i + 1)]
    }

    /// Scratch tensors one [`BnsSolver::step_into`] call draws from its
    /// workspace.
    pub fn stage_buffers(&self) -> usize {
        match self.theta.base {
            Base::Rk1 => 1,
            Base::Rk2 => 3,
        }
    }

    /// One BNS step computed **in place**, with scratch drawn from `ws`:
    /// zero heap allocation once the pool is warm, element-for-element
    /// identical to [`BnsSolver::step`].
    pub fn step_into(
        &self,
        model: &dyn VelocityModel,
        x: &mut Tensor,
        i: usize,
        ws: &mut Workspace,
    ) -> Result<()> {
        let n = self.theta.n;
        if i >= n {
            bail!("step index {i} out of range for n={n}");
        }
        let h = 1.0f32 / n as f32;
        let t = i as f32 / n as f32;
        let c = self.coeffs(i);
        match self.theta.base {
            Base::Rk1 => {
                let mut u = ws.acquire(x.shape());
                model.eval_into(x, t, &mut u)?;
                // x' = a x + h b u
                x.scale_axpy(c[0], h * c[1], &u)?;
                ws.release(u);
            }
            Base::Rk2 => {
                let mut u1 = ws.acquire(x.shape());
                model.eval_into(x, t, &mut u1)?;
                let mut mid = ws.acquire(x.shape());
                mid.copy_from(x)?;
                mid.axpy(0.5 * h, &u1)?;
                let mut u2 = ws.acquire(x.shape());
                model.eval_into(&mid, t + 0.5 * h, &mut u2)?;
                // x' = a x + h b1 u1 + h b2 u2
                x.scale_axpy(c[0], h * c[1], &u1)?;
                x.axpy(h * c[2], &u2)?;
                ws.release(u2);
                ws.release(mid);
                ws.release(u1);
            }
        }
        Ok(())
    }

    /// One BNS step from integer step index i. Clone-per-stage reference
    /// path; the session loop uses [`BnsSolver::step_into`].
    pub fn step(&self, model: &dyn VelocityModel, x: &Tensor, i: usize) -> Result<Tensor> {
        let n = self.theta.n;
        if i >= n {
            bail!("step index {i} out of range for n={n}");
        }
        let h = 1.0f32 / n as f32;
        let t = i as f32 / n as f32;
        let c = self.coeffs(i);
        match self.theta.base {
            Base::Rk1 => {
                let u = model.eval(x, t)?;
                let mut out = x.scale(c[0]);
                out.axpy(h * c[1], &u)?;
                Ok(out)
            }
            Base::Rk2 => {
                let u1 = model.eval(x, t)?;
                let mut mid = x.clone();
                mid.axpy(0.5 * h, &u1)?;
                let u2 = model.eval(&mid, t + 0.5 * h)?;
                let mut out = x.scale(c[0]);
                out.axpy(h * c[1], &u1)?;
                out.axpy(h * c[2], &u2)?;
                Ok(out)
            }
        }
    }
}

/// Step-wise execution of a [`BnsSolver`]: one per-step-coefficient step
/// per [`SolveSession::step`], identical arithmetic to the one-shot loop.
/// Scratch tensors are pre-allocated in [`Sampler::begin`] and recycled
/// through the session's [`Workspace`]: zero heap allocation per step.
pub struct BnsSession<'a> {
    solver: &'a BnsSolver,
    x: Tensor,
    i: usize,
    ws: Workspace,
}

impl SolveSession for BnsSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            self.x.copy_from(x0)?;
        } else {
            // Width-agnostic re-init: top the pool up for the new shape,
            // keeping buffers of widths already visited (DESIGN.md §10).
            self.x = x0.clone();
            self.ws.ensure(x0.shape(), self.solver.stage_buffers());
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        self.solver.step_into(model, &mut self.x, self.i, &mut self.ws)?;
        self.i += 1;
        Ok(StepInfo {
            step: self.i - 1,
            t: self.i as f32 / self.solver.theta.n as f32,
            nfe: self.solver.theta.base.evals_per_step(),
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i >= self.solver.theta.n
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.theta.n)
    }
}

impl Sampler for BnsSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        self.theta.n * self.theta.base.evals_per_step()
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        Ok(Box::new(BnsSession {
            solver: self,
            x: x0.clone(),
            i: 0,
            ws: Workspace::preallocate(x0.shape(), self.stage_buffers()),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;
    use crate::solvers::rk::{BaseRk, FixedGridSolver};
    use crate::util::Rng;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![0.9, 0.1], vec![-0.7, -0.5], vec![0.2, 1.1]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 8).unwrap()
    }

    /// Consistency anchor: identity coefficients == plain base solver.
    /// (Tolerance, not bitwise: `0*u1` and the base's own update differ in
    /// op order, so last-bit drift is expected.)
    #[test]
    fn identity_coeffs_equal_base_solver() {
        let model = toy();
        let mut rng = Rng::new(3);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        for (base, rk, n) in [(Base::Rk1, BaseRk::Rk1, 6), (Base::Rk2, BaseRk::Rk2, 6)] {
            let raw = RawTheta::identity_for(Family::Bns, base, n, 0).unwrap();
            let bns = BnsSolver::new(&raw).unwrap();
            let plain = FixedGridSolver::uniform(rk, n);
            let a = bns.sample(&model, &x0).unwrap();
            let b = plain.sample(&model, &x0).unwrap();
            let err = a.sub(&b).unwrap().linf();
            assert!(err < 1e-5, "{base:?}: identity mismatch linf={err}");
        }
    }

    #[test]
    fn nfe_counts_and_family_guard() {
        let rk1 = RawTheta::identity_for(Family::Bns, Base::Rk1, 10, 0).unwrap();
        let rk2 = RawTheta::identity_for(Family::Bns, Base::Rk2, 10, 0).unwrap();
        assert_eq!(BnsSolver::new(&rk1).unwrap().nfe(), 10);
        assert_eq!(BnsSolver::new(&rk2).unwrap().nfe(), 20);
        assert!(BnsSolver::new(&RawTheta::identity(Base::Rk2, 4)).is_err());
    }

    #[test]
    fn step_index_bounds() {
        let model = toy();
        let raw = RawTheta::identity_for(Family::Bns, Base::Rk2, 3, 0).unwrap();
        let bns = BnsSolver::new(&raw).unwrap();
        let x = Tensor::zeros(&[8, 2]);
        assert!(bns.step(&model, &x, 3).is_err());
    }

    /// Step-wise session == the explicit step loop, bitwise — for a
    /// genuinely non-stationary theta (random per-step coefficients).
    #[test]
    fn session_matches_step_loop_bitwise() {
        let model = toy();
        let mut rng = Rng::new(9);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        for base in [Base::Rk1, Base::Rk2] {
            let n = 5;
            let p = RawTheta::n_params_for(Family::Bns, base, n, 0).unwrap();
            let raw_vals: Vec<f32> = (0..p).map(|_| 1.0 + 0.1 * rng.normal()).collect();
            let raw = RawTheta::from_raw_for(Family::Bns, base, n, 0, raw_vals).unwrap();
            let bns = BnsSolver::new(&raw).unwrap();
            let mut x = x0.clone();
            for i in 0..n {
                x = bns.step(&model, &x, i).unwrap();
            }
            let one_shot = bns.sample(&model, &x0).unwrap();
            assert_eq!(one_shot.data(), x.data());
            let mut sess = bns.begin(&x0).unwrap();
            assert_eq!(sess.steps_total(), Some(n));
            let mut nfe = 0usize;
            while !sess.is_done() {
                nfe += sess.step(&model).unwrap().nfe;
            }
            assert_eq!(sess.state().data(), x.data());
            assert_eq!(nfe, bns.nfe());
            assert!(sess.step(&model).is_err());
        }
    }
}
