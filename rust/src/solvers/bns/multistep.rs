//! The learned-multistep sampler: one velocity evaluation per step, mixed
//! with a window of previous evaluations via learned per-step
//! coefficients. Step i of an n-step solve (h = 1/n, t_i = i/n, window W):
//!
//! ```text
//! u_i = u(x, t_i)
//! x'  = a_i x + h * sum_{j=0..min(i, W-1)} c_{i,j} u_{i-j}
//! ```
//!
//! Raw layout: per step `[a_i, c_{i,0}, ..., c_{i,W-1}]`. Coefficients for
//! history that does not exist yet (j > i, the warm-up steps) are present
//! in the layout but ignored here and gradient-masked in training, so
//! they stay at their identity init of 0.
//!
//! The history ring holds full-batch `[B, d]` tensors owned by the
//! session (not the workspace), and every kernel is elementwise — rows
//! never mix, so the fusion plane can stack requests freely and fused vs
//! solo stays byte-identical. A slot is always written (step i writes
//! `hist[i % W]`) before any read of it in the same solve, so stale
//! history from a previous `init` is never observed.

use anyhow::{bail, Result};

use super::expect_family;
use crate::models::VelocityModel;
use crate::solvers::theta::{Family, RawTheta};
use crate::solvers::{Sampler, SolveSession, StepInfo};
use crate::tensor::Tensor;

pub struct MultistepSolver {
    pub theta: RawTheta,
    label: String,
}

impl MultistepSolver {
    pub fn new(raw: &RawTheta) -> Result<MultistepSolver> {
        expect_family(raw, Family::Multistep)?;
        Ok(MultistepSolver {
            theta: raw.clone(),
            label: format!("multistep:n={}:window={}", raw.n, raw.window),
        })
    }

    pub fn with_label(raw: &RawTheta, label: impl Into<String>) -> Result<MultistepSolver> {
        expect_family(raw, Family::Multistep)?;
        Ok(MultistepSolver { theta: raw.clone(), label: label.into() })
    }

    /// The coefficients of step i: `[a_i, c_{i,0}, ..., c_{i,W-1}]`.
    pub fn coeffs(&self, i: usize) -> &[f32] {
        let k = 1 + self.theta.window;
        &self.theta.raw[k * i..k * (i + 1)]
    }

    /// Clone-per-step reference solve with an explicit history vector —
    /// the arithmetic anchor the session path is pinned against, bitwise.
    pub fn solve_reference(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor> {
        let n = self.theta.n;
        let w = self.theta.window;
        let h = 1.0f32 / n as f32;
        let mut x = x0.clone();
        let mut hist: Vec<Tensor> = Vec::with_capacity(n);
        for i in 0..n {
            let t = i as f32 / n as f32;
            hist.push(model.eval(&x, t)?);
            let c = self.coeffs(i);
            let mut out = x.scale(c[0]);
            for j in 0..=i.min(w - 1) {
                out.axpy(h * c[1 + j], &hist[i - j])?;
            }
            x = out;
        }
        Ok(x)
    }
}

/// Step-wise execution of a [`MultistepSolver`]. The velocity of each step
/// is written straight into its history ring slot (`eval_into`), then the
/// state update runs in place — zero heap allocation per step.
pub struct MultistepSession<'a> {
    solver: &'a MultistepSolver,
    x: Tensor,
    i: usize,
    /// Ring of the last `window` velocity evaluations; slot `i % window`
    /// holds u_i. Full-batch tensors: row-independent, fusion-safe.
    hist: Vec<Tensor>,
}

impl SolveSession for MultistepSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            self.x.copy_from(x0)?;
            // hist slots are overwritten before first read (j <= i guard),
            // so stale bytes from the previous solve are never observed
        } else {
            // Width-agnostic re-init (DESIGN.md §10): rebuild the ring at
            // the new shape.
            self.x = x0.clone();
            self.hist = (0..self.solver.theta.window).map(|_| Tensor::zeros(x0.shape())).collect();
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        let n = self.solver.theta.n;
        let w = self.solver.theta.window;
        let h = 1.0f32 / n as f32;
        let i = self.i;
        let t = i as f32 / n as f32;
        let slot = i % w;
        model.eval_into(&self.x, t, &mut self.hist[slot])?;
        let c = self.solver.coeffs(i);
        // x' = a x + h c_0 u_i, then the older history terms
        self.x.scale_axpy(c[0], h * c[1], &self.hist[slot])?;
        for j in 1..=i.min(w - 1) {
            self.x.axpy(h * c[1 + j], &self.hist[(i - j) % w])?;
        }
        self.i += 1;
        Ok(StepInfo {
            step: self.i - 1,
            t: self.i as f32 / n as f32,
            nfe: 1,
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i >= self.solver.theta.n
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.theta.n)
    }
}

impl Sampler for MultistepSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        self.theta.n
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        Ok(Box::new(MultistepSession {
            solver: self,
            x: x0.clone(),
            i: 0,
            hist: (0..self.theta.window).map(|_| Tensor::zeros(x0.shape())).collect(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;
    use crate::solvers::rk::{BaseRk, FixedGridSolver};
    use crate::solvers::theta::Base;
    use crate::util::Rng;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![0.9, 0.1], vec![-0.7, -0.5], vec![0.2, 1.1]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 8).unwrap()
    }

    fn random_theta(n: usize, window: usize, seed: u64) -> RawTheta {
        let mut rng = Rng::new(seed);
        let p = RawTheta::n_params_for(Family::Multistep, Base::Rk1, n, window).unwrap();
        let raw: Vec<f32> = (0..p).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        RawTheta::from_raw_for(Family::Multistep, Base::Rk1, n, window, raw).unwrap()
    }

    /// Identity coefficients (a=1, c0=1, older 0) == Euler.
    #[test]
    fn identity_coeffs_equal_euler() {
        let model = toy();
        let mut rng = Rng::new(3);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let raw = RawTheta::identity_for(Family::Multistep, Base::Rk1, 6, 3).unwrap();
        let ms = MultistepSolver::new(&raw).unwrap();
        let euler = FixedGridSolver::uniform(BaseRk::Rk1, 6);
        let a = ms.sample(&model, &x0).unwrap();
        let b = euler.sample(&model, &x0).unwrap();
        let err = a.sub(&b).unwrap().linf();
        assert!(err < 1e-5, "identity mismatch linf={err}");
    }

    /// Session == clone-per-step reference, bitwise, for random
    /// non-stationary coefficients — including the warm-up steps where
    /// only part of the window exists.
    #[test]
    fn session_matches_reference_bitwise() {
        let model = toy();
        let mut rng = Rng::new(9);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        for window in [1usize, 2, 4] {
            let th = random_theta(6, window, 100 + window as u64);
            let ms = MultistepSolver::new(&th).unwrap();
            let reference = ms.solve_reference(&model, &x0).unwrap();
            let one_shot = ms.sample(&model, &x0).unwrap();
            assert_eq!(one_shot.data(), reference.data(), "window={window}");
            let mut sess = ms.begin(&x0).unwrap();
            assert_eq!(sess.steps_total(), Some(6));
            let mut nfe = 0usize;
            while !sess.is_done() {
                nfe += sess.step(&model).unwrap().nfe;
            }
            assert_eq!(sess.state().data(), reference.data(), "window={window}");
            assert_eq!(nfe, ms.nfe());
            assert!(sess.step(&model).is_err());
            // re-init rewinds; stale history must not leak into the redo
            sess.init(&x0).unwrap();
            while !sess.is_done() {
                sess.step(&model).unwrap();
            }
            assert_eq!(sess.state().data(), reference.data(), "window={window} reinit");
        }
    }

    #[test]
    fn one_eval_per_step_and_family_guard() {
        let th = random_theta(8, 3, 5);
        let ms = MultistepSolver::new(&th).unwrap();
        assert_eq!(ms.nfe(), 8);
        assert!(MultistepSolver::new(&RawTheta::identity(Base::Rk1, 4)).is_err());
    }
}
