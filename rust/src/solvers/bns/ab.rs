//! Training-free Adams–Bashforth history-reuse sampler (arXiv 2411.07627):
//! classical order-M multistep coefficients on the uniform grid, zero
//! training cost — the free quality-per-NFE baseline that pressure-tests
//! whether BNS/multistep training earns its cost.
//!
//! Steps `i < M-1` are warm-up: a full base-RK step (the velocity at the
//! node doubles as the RK stage k1 and is recorded into the history
//! ring). From step `M-1` on, each step costs one evaluation:
//!
//! ```text
//! u_i = u(x, t_i)
//! x'  = x + h * sum_{j=0..M-1} beta_j u_{i-j}
//! ```
//!
//! History is a ring of full-batch tensors owned by the session — every
//! kernel is elementwise, rows never mix, so AB is fusion-safe like the
//! learned families.

use anyhow::{bail, Result};

use crate::models::VelocityModel;
use crate::solvers::rk::BaseRk;
use crate::solvers::{Sampler, SolveSession, StepInfo};
use crate::tensor::{Tensor, Workspace};

pub struct AbSolver {
    pub base: BaseRk,
    pub n: usize,
    pub order: usize,
    /// Classical AB coefficients beta_0..beta_{M-1} (precomputed so the
    /// step loop never allocates).
    beta: Vec<f32>,
    label: String,
}

impl AbSolver {
    pub fn new(base: BaseRk, n: usize, order: usize) -> Result<AbSolver> {
        if n == 0 {
            bail!("ab solver needs n >= 1");
        }
        let beta: Vec<f32> = match order {
            1 => vec![1.0],
            2 => vec![1.5, -0.5],
            3 => vec![23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
            4 => vec![55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
            _ => bail!("ab order must be in 1..=4 (got {order})"),
        };
        // label == canonical spec Display (defaults base=rk2, order=2
        // omitted), so routed and explicit requests agree on the name
        let mut label = String::from("ab");
        if base != BaseRk::Rk2 {
            label.push_str(&format!(":base={}", base.name()));
        }
        label.push_str(&format!(":n={n}"));
        if order != 2 {
            label.push_str(&format!(":order={order}"));
        }
        Ok(AbSolver { base, n, order, beta, label })
    }

    /// Warm-up steps that run the full base RK method instead of AB.
    fn startup_steps(&self) -> usize {
        (self.order - 1).min(self.n)
    }

    /// Scratch tensors one warm-up step draws from the workspace (the
    /// node velocity lives in the history ring, not the pool).
    pub fn stage_buffers(&self) -> usize {
        match self.base {
            BaseRk::Rk1 => 0,
            BaseRk::Rk2 => 2,
            BaseRk::Rk4 => 4,
        }
    }

    /// Complete a warm-up base-RK step in place, reusing the already
    /// evaluated node velocity `k1 = u(x, t)` from the history ring.
    fn finish_startup_step(
        &self,
        model: &dyn VelocityModel,
        x: &mut Tensor,
        t: f32,
        h: f32,
        k1: &Tensor,
        ws: &mut Workspace,
    ) -> Result<()> {
        match self.base {
            BaseRk::Rk1 => {
                x.axpy(h, k1)?;
            }
            BaseRk::Rk2 => {
                let mut mid = ws.acquire(x.shape());
                mid.copy_from(x)?;
                mid.axpy(0.5 * h, k1)?;
                let mut k2 = ws.acquire(x.shape());
                model.eval_into(&mid, t + 0.5 * h, &mut k2)?;
                x.axpy(h, &k2)?;
                ws.release(k2);
                ws.release(mid);
            }
            BaseRk::Rk4 => {
                let mut xs = ws.acquire(x.shape());
                xs.copy_from(x)?;
                xs.axpy(0.5 * h, k1)?;
                let mut k2 = ws.acquire(x.shape());
                model.eval_into(&xs, t + 0.5 * h, &mut k2)?;
                xs.copy_from(x)?;
                xs.axpy(0.5 * h, &k2)?;
                let mut k3 = ws.acquire(x.shape());
                model.eval_into(&xs, t + 0.5 * h, &mut k3)?;
                xs.copy_from(x)?;
                xs.axpy(h, &k3)?;
                let mut k4 = ws.acquire(x.shape());
                model.eval_into(&xs, t + h, &mut k4)?;
                x.axpy(h / 6.0, k1)?;
                x.axpy(h / 3.0, &k2)?;
                x.axpy(h / 3.0, &k3)?;
                x.axpy(h / 6.0, &k4)?;
                for buf in [k2, k3, k4, xs] {
                    ws.release(buf);
                }
            }
        }
        Ok(())
    }

    /// Clone-per-step reference solve with an explicit history vector —
    /// the arithmetic anchor the session path is pinned against, bitwise.
    pub fn solve_reference(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor> {
        let (n, h) = (self.n, 1.0f32 / self.n as f32);
        let startup = self.startup_steps();
        let mut x = x0.clone();
        let mut hist: Vec<Tensor> = Vec::with_capacity(n);
        let mut ws = Workspace::preallocate(x0.shape(), self.stage_buffers());
        for i in 0..n {
            let t = i as f32 / n as f32;
            hist.push(model.eval(&x, t)?);
            if i < startup {
                self.finish_startup_step(model, &mut x, t, h, &hist[i], &mut ws)?;
            } else {
                for (j, &b) in self.beta.iter().enumerate() {
                    x.axpy(h * b, &hist[i - j])?;
                }
            }
        }
        Ok(x)
    }
}

/// Step-wise execution of an [`AbSolver`]: the node velocity of each step
/// is written straight into its history ring slot, warm-up stage scratch
/// comes from the pre-warmed workspace — zero heap allocation per step.
pub struct AbSession<'a> {
    solver: &'a AbSolver,
    x: Tensor,
    i: usize,
    /// Ring of the last `order` node velocities; slot `i % order` holds u_i.
    hist: Vec<Tensor>,
    ws: Workspace,
}

impl SolveSession for AbSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            self.x.copy_from(x0)?;
            // hist slots are rewritten before first read each solve
        } else {
            // Width-agnostic re-init (DESIGN.md §10)
            self.x = x0.clone();
            self.hist = (0..self.solver.order).map(|_| Tensor::zeros(x0.shape())).collect();
            self.ws.ensure(x0.shape(), self.solver.stage_buffers());
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        let s = self.solver;
        let (n, h) = (s.n, 1.0f32 / s.n as f32);
        let i = self.i;
        let t = i as f32 / n as f32;
        let slot = i % s.order;
        model.eval_into(&self.x, t, &mut self.hist[slot])?;
        let nfe = if i < s.startup_steps() {
            s.finish_startup_step(model, &mut self.x, t, h, &self.hist[slot], &mut self.ws)?;
            s.base.evals_per_step()
        } else {
            for (j, &b) in s.beta.iter().enumerate() {
                self.x.axpy(h * b, &self.hist[(i - j) % s.order])?;
            }
            1
        };
        self.i += 1;
        Ok(StepInfo {
            step: self.i - 1,
            t: self.i as f32 / n as f32,
            nfe,
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i >= self.solver.n
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.n)
    }
}

impl Sampler for AbSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        let startup = self.startup_steps();
        startup * self.base.evals_per_step() + (self.n - startup)
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        Ok(Box::new(AbSession {
            solver: self,
            x: x0.clone(),
            i: 0,
            hist: (0..self.order).map(|_| Tensor::zeros(x0.shape())).collect(),
            ws: Workspace::preallocate(x0.shape(), self.stage_buffers()),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;
    use crate::solvers::dopri5::Dopri5;
    use crate::solvers::rk::FixedGridSolver;
    use crate::util::Rng;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![0.9, 0.1], vec![-0.7, -0.5], vec![0.2, 1.1]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 8).unwrap()
    }

    #[test]
    fn order_one_is_euler() {
        let model = toy();
        let mut rng = Rng::new(3);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let ab = AbSolver::new(BaseRk::Rk1, 7, 1).unwrap();
        let euler = FixedGridSolver::uniform(BaseRk::Rk1, 7);
        let a = ab.sample(&model, &x0).unwrap();
        let b = euler.sample(&model, &x0).unwrap();
        assert_eq!(a.data(), b.data(), "AB(1) must be exactly Euler");
    }

    /// AB2 with one-step RK warm-up has empirical convergence order ~2.
    #[test]
    fn ab2_converges_at_order_two() {
        let model = toy();
        let mut rng = Rng::new(5);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let gt = Dopri5 { rtol: 1e-8, atol: 1e-8, max_steps: 100_000 }
            .sample(&model, &x0)
            .unwrap();
        let err = |n: usize| {
            let ab = AbSolver::new(BaseRk::Rk2, n, 2).unwrap();
            ab.sample(&model, &x0).unwrap().sub(&gt).unwrap().rms()
        };
        let (e8, e16) = (err(8), err(16));
        let order = (e8 / e16).log2();
        assert!(order > 1.5, "expected order ~2, got {order} (e8={e8}, e16={e16})");
    }

    #[test]
    fn nfe_accounting_counts_warmup() {
        // order 3 on rk2 base: 2 warm-up steps at 2 evals + 6 AB steps
        let ab = AbSolver::new(BaseRk::Rk2, 8, 3).unwrap();
        assert_eq!(ab.nfe(), 2 * 2 + 6);
        // order 1: no warm-up at all
        assert_eq!(AbSolver::new(BaseRk::Rk4, 5, 1).unwrap().nfe(), 5);
        // n smaller than the warm-up: every step is a full RK step
        assert_eq!(AbSolver::new(BaseRk::Rk4, 2, 4).unwrap().nfe(), 8);
        assert!(AbSolver::new(BaseRk::Rk2, 4, 5).is_err());
        assert!(AbSolver::new(BaseRk::Rk2, 0, 2).is_err());
    }

    /// Session == clone-per-step reference, bitwise, across bases/orders —
    /// including warm-up, and the measured per-step NFE totals.
    #[test]
    fn session_matches_reference_bitwise() {
        let model = toy();
        let mut rng = Rng::new(9);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        for (base, order) in [
            (BaseRk::Rk1, 2),
            (BaseRk::Rk2, 2),
            (BaseRk::Rk2, 3),
            (BaseRk::Rk4, 4),
        ] {
            let ab = AbSolver::new(base, 6, order).unwrap();
            let reference = ab.solve_reference(&model, &x0).unwrap();
            let one_shot = ab.sample(&model, &x0).unwrap();
            assert_eq!(one_shot.data(), reference.data(), "{base:?} order={order}");
            let mut sess = ab.begin(&x0).unwrap();
            assert_eq!(sess.steps_total(), Some(6));
            let mut nfe = 0usize;
            while !sess.is_done() {
                nfe += sess.step(&model).unwrap().nfe;
            }
            assert_eq!(sess.state().data(), reference.data(), "{base:?} order={order}");
            assert_eq!(nfe, ab.nfe(), "{base:?} order={order}");
            assert!(sess.step(&model).is_err());
            // re-init rewinds; stale history must not leak into the redo
            sess.init(&x0).unwrap();
            while !sess.is_done() {
                sess.step(&model).unwrap();
            }
            assert_eq!(sess.state().data(), reference.data(), "{base:?} order={order} reinit");
        }
    }
}
