//! Scheduler-transfer sampler: integrate the model's ODE along the sampling
//! path of a *different* scheduler via the scale-time transform of paper
//! eq. 31/32 — this is exactly how the paper casts DDIM, DPM-Solver and EDM
//! as fixed (hand-chosen) members of the scale-time family that Bespoke
//! solvers instead *learn*.
//!
//! The transformed field (paper eq. 16) is
//!
//! ```text
//! u_bar_r(x) = (s'_r / s_r) x + t'_r s_r u_{t_r}(x / s_r)
//! ```
//!
//! with (t_r, s_r) from [`crate::schedulers::transfer_map`]; derivatives are
//! taken by central differences of the analytic map (h = 1e-4).

use anyhow::{bail, Result};

use super::rk::BaseRk;
use super::{Sampler, SolveSession, StepInfo};
use crate::models::VelocityModel;
use crate::schedulers::{transfer_map, Scheduler};
use crate::tensor::{Tensor, Workspace};

pub struct TransferSolver {
    pub source: Scheduler,
    pub target: Scheduler,
    pub base: BaseRk,
    pub n: usize,
}

const FD_H: f64 = 1e-4;

impl TransferSolver {
    pub fn new(source: Scheduler, target: Scheduler, base: BaseRk, n: usize) -> TransferSolver {
        TransferSolver { source, target, base, n }
    }

    /// (t_r, s_r, dt/dr, ds/dr) at r.
    fn map_with_derivs(&self, r: f64) -> (f64, f64, f64, f64) {
        let (t, s) = transfer_map(self.source, self.target, r);
        let rm = (r - FD_H).max(0.0);
        let rp = (r + FD_H).min(1.0);
        let (tm, sm) = transfer_map(self.source, self.target, rm);
        let (tp, sp) = transfer_map(self.source, self.target, rp);
        let dr = rp - rm;
        ((t), (s), (tp - tm) / dr, (sp - sm) / dr)
    }

    /// u_bar(x_bar, r) on the transformed path. Clone-per-stage reference
    /// path (public so equivalence tests can rebuild the naive loop); the
    /// session hot loop uses [`TransferSolver::u_bar_into`].
    pub fn u_bar(&self, model: &dyn VelocityModel, xbar: &Tensor, r: f64) -> Result<Tensor> {
        let (t, s, dt, ds) = self.map_with_derivs(r);
        let x = xbar.scale(1.0 / s as f32);
        let u = model.eval(&x, t as f32)?;
        let mut out = xbar.scale((ds / s) as f32);
        out.axpy((dt * s) as f32, &u)?;
        Ok(out)
    }

    /// [`TransferSolver::u_bar`] computed into caller-owned buffers
    /// (`xb`/`ub` scratch for the untransformed state and velocity): zero
    /// heap allocation, element-for-element identical arithmetic.
    fn u_bar_into(
        &self,
        model: &dyn VelocityModel,
        xbar: &Tensor,
        r: f64,
        xb: &mut Tensor,
        ub: &mut Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let (t, s, dt, ds) = self.map_with_derivs(r);
        xbar.scale_into(1.0 / s as f32, xb)?;
        model.eval_into(xb, t as f32, ub)?;
        xbar.scale_into((ds / s) as f32, out)?;
        out.axpy((dt * s) as f32, ub)
    }
}

/// Step-wise execution of a [`TransferSolver`]. The session advances the
/// *transformed* state x_bar(r) and keeps an untransformed view x(r) =
/// x_bar(r) / s_r for [`SolveSession::state`], so streamed intermediate
/// states live on the model's own path; the final state is exactly the
/// one-shot untransform x(1) = x_bar(1) / s_1.
/// Stage buffers come from the session's [`Workspace`] and the two
/// transformed-field scratch tensors are session fields, so the step loop
/// performs zero heap allocation after [`Sampler::begin`].
pub struct TransferSession<'a> {
    solver: &'a TransferSolver,
    xbar: Tensor,
    /// Untransformed view of `xbar` at the current r.
    x: Tensor,
    /// Number of completed steps; step i integrates r in [i h, (i+1) h].
    i: usize,
    ws: Workspace,
    /// Scratch for the untransformed state x_bar / s_r inside u_bar.
    scratch_x: Tensor,
    /// Scratch for the model velocity u(x, t_r) inside u_bar.
    scratch_u: Tensor,
}

impl TransferSession<'_> {
    /// Refresh the untransformed view x = x_bar / s_r at the current r.
    fn untransform(&mut self) -> Result<()> {
        // At exactly r = 1 this is the one-shot final untransform; r = 0
        // has s_0 = 1 by construction.
        let r = if self.i == self.solver.n {
            1.0
        } else {
            self.i as f64 / self.solver.n as f64
        };
        let (_, s) = transfer_map(self.solver.source, self.solver.target, r);
        self.xbar.scale_into(1.0 / s as f32, &mut self.x)
    }
}

impl SolveSession for TransferSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        // x_bar(0) = s_0 x(0); s_0 = sigma_target(0)/sigma_source(0) = 1.
        if self.xbar.shape() == x0.shape() {
            self.xbar.copy_from(x0)?;
            self.x.copy_from(x0)?;
        } else {
            // Width-agnostic re-init: top the pool up for the new shape,
            // keeping buffers of widths already visited (DESIGN.md §10).
            self.xbar = x0.clone();
            self.x = x0.clone();
            self.scratch_x = Tensor::zeros(x0.shape());
            self.scratch_u = Tensor::zeros(x0.shape());
            self.ws.ensure(x0.shape(), self.solver.base.stage_buffers());
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        let h = 1.0 / self.solver.n as f64;
        let r = self.i as f64 * h;
        let TransferSession { solver, xbar, ws, scratch_x, scratch_u, .. } = self;
        let mut f = |xb: &Tensor, r: f32, out: &mut Tensor| {
            solver.u_bar_into(model, xb, r as f64, scratch_x, scratch_u, out)
        };
        solver.base.step_into(&mut f, xbar, r as f32, h as f32, ws)?;
        self.i += 1;
        self.untransform()?;
        Ok(StepInfo {
            step: self.i - 1,
            t: if self.i == self.solver.n { 1.0 } else { (self.i as f64 * h) as f32 },
            nfe: self.solver.base.evals_per_step(),
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i >= self.solver.n
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.n)
    }
}

impl Sampler for TransferSolver {
    fn name(&self) -> String {
        format!("{}-{}:n={}", self.base.name(), self.target.name(), self.n)
    }

    fn nfe(&self) -> usize {
        self.n * self.base.evals_per_step()
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        if self.n == 0 {
            bail!("transfer solver needs n >= 1");
        }
        Ok(Box::new(TransferSession {
            solver: self,
            xbar: x0.clone(),
            x: x0.clone(),
            i: 0,
            ws: Workspace::preallocate(x0.shape(), self.base.stage_buffers()),
            scratch_x: Tensor::zeros(x0.shape()),
            scratch_u: Tensor::zeros(x0.shape()),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::solvers::dopri5::Dopri5;
    use crate::util::Rng;

    fn toy(sched: Scheduler) -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![1.0, 0.3], vec![-0.8, -0.4], vec![0.1, 1.2]]).unwrap();
        AnalyticModel::new("toy", pts, sched, 0.08, 8).unwrap()
    }

    /// Transferring to the model's own scheduler must reproduce the plain
    /// fixed-grid solver of the same base (identity transform).
    #[test]
    fn self_transfer_is_identity() {
        let model = toy(Scheduler::CondOt);
        let mut rng = Rng::new(0);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let plain = crate::solvers::rk::FixedGridSolver::uniform(BaseRk::Rk2, 8);
        let xfer = TransferSolver::new(Scheduler::CondOt, Scheduler::CondOt, BaseRk::Rk2, 8);
        let a = plain.sample(&model, &x0).unwrap();
        let b = xfer.sample(&model, &x0).unwrap();
        let err = a.sub(&b).unwrap().rms();
        assert!(err < 2e-3, "self-transfer deviates: rms {err}");
    }

    /// Consistency (Theorem 2.2): as n grows the transfer solver converges
    /// to the GT solution.
    #[test]
    fn transfer_converges_to_gt() {
        let model = toy(Scheduler::Cosine);
        let mut rng = Rng::new(1);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let gt = Dopri5::default().sample(&model, &x0).unwrap();
        let err_at = |n: usize| {
            let s = TransferSolver::new(Scheduler::Cosine, Scheduler::CondOt, BaseRk::Rk2, n);
            s.sample(&model, &x0).unwrap().sub(&gt).unwrap().rms()
        };
        let (e8, e32) = (err_at(8), err_at(32));
        assert!(e32 < e8 * 0.5, "no convergence: e8={e8} e32={e32}");
        assert!(e32 < 0.05, "absolute error too large: {e32}");
    }

    #[test]
    fn nfe_and_name() {
        let s = TransferSolver::new(Scheduler::CondOt, Scheduler::VarPres, BaseRk::Rk2, 5);
        assert_eq!(s.nfe(), 10);
        assert!(s.name().contains("vp"));
    }

    /// Step-wise session == the pre-session one-shot loop, bitwise.
    #[test]
    fn session_matches_legacy_one_shot_bitwise() {
        let model = toy(Scheduler::Cosine);
        let mut rng = Rng::new(7);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let s = TransferSolver::new(Scheduler::Cosine, Scheduler::CondOt, BaseRk::Rk2, 6);
        // legacy reference: the original one-shot sample() loop
        let legacy = {
            let mut xbar = x0.clone();
            let h = 1.0 / s.n as f64;
            let mut f = |x: &Tensor, r: f32| s.u_bar(&model, x, r as f64);
            for i in 0..s.n {
                let r = i as f64 * h;
                xbar = s.base.step(&mut f, &xbar, r as f32, h as f32).unwrap();
            }
            let (_, s1) = transfer_map(s.source, s.target, 1.0);
            xbar.scale(1.0 / s1 as f32)
        };
        let one_shot = s.sample(&model, &x0).unwrap();
        assert_eq!(one_shot.data(), legacy.data());
        let mut sess = s.begin(&x0).unwrap();
        let mut nfe = 0usize;
        while !sess.is_done() {
            nfe += sess.step(&model).unwrap().nfe;
        }
        assert_eq!(sess.state().data(), legacy.data());
        assert_eq!(nfe, s.nfe());
    }
}
