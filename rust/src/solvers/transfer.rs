//! Scheduler-transfer sampler: integrate the model's ODE along the sampling
//! path of a *different* scheduler via the scale-time transform of paper
//! eq. 31/32 — this is exactly how the paper casts DDIM, DPM-Solver and EDM
//! as fixed (hand-chosen) members of the scale-time family that Bespoke
//! solvers instead *learn*.
//!
//! The transformed field (paper eq. 16) is
//!
//! ```text
//! u_bar_r(x) = (s'_r / s_r) x + t'_r s_r u_{t_r}(x / s_r)
//! ```
//!
//! with (t_r, s_r) from [`crate::schedulers::transfer_map`]; derivatives are
//! taken by central differences of the analytic map (h = 1e-4).

use anyhow::Result;

use super::rk::BaseRk;
use super::Sampler;
use crate::models::VelocityModel;
use crate::schedulers::{transfer_map, Scheduler};
use crate::tensor::Tensor;

pub struct TransferSolver {
    pub source: Scheduler,
    pub target: Scheduler,
    pub base: BaseRk,
    pub n: usize,
}

const FD_H: f64 = 1e-4;

impl TransferSolver {
    pub fn new(source: Scheduler, target: Scheduler, base: BaseRk, n: usize) -> TransferSolver {
        TransferSolver { source, target, base, n }
    }

    /// (t_r, s_r, dt/dr, ds/dr) at r.
    fn map_with_derivs(&self, r: f64) -> (f64, f64, f64, f64) {
        let (t, s) = transfer_map(self.source, self.target, r);
        let rm = (r - FD_H).max(0.0);
        let rp = (r + FD_H).min(1.0);
        let (tm, sm) = transfer_map(self.source, self.target, rm);
        let (tp, sp) = transfer_map(self.source, self.target, rp);
        let dr = rp - rm;
        ((t), (s), (tp - tm) / dr, (sp - sm) / dr)
    }

    /// u_bar(x_bar, r) on the transformed path.
    fn u_bar(&self, model: &dyn VelocityModel, xbar: &Tensor, r: f64) -> Result<Tensor> {
        let (t, s, dt, ds) = self.map_with_derivs(r);
        let x = xbar.scale(1.0 / s as f32);
        let u = model.eval(&x, t as f32)?;
        let mut out = xbar.scale((ds / s) as f32);
        out.axpy((dt * s) as f32, &u)?;
        Ok(out)
    }
}

impl Sampler for TransferSolver {
    fn name(&self) -> String {
        format!("{}-{}:n={}", self.base.name(), self.target.name(), self.n)
    }

    fn nfe(&self) -> usize {
        self.n * self.base.evals_per_step()
    }

    fn sample(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor> {
        // x_bar(0) = s_0 x(0); s_0 = sigma_target(0)/sigma_source(0) = 1.
        let mut xbar = x0.clone();
        let h = 1.0 / self.n as f64;
        let mut f = |x: &Tensor, r: f32| self.u_bar(model, x, r as f64);
        for i in 0..self.n {
            let r = i as f64 * h;
            xbar = self.base.step(&mut f, &xbar, r as f32, h as f32)?;
        }
        // untransform: x(1) = x_bar(1) / s_1
        let (_, s1) = transfer_map(self.source, self.target, 1.0);
        Ok(xbar.scale(1.0 / s1 as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::solvers::dopri5::Dopri5;
    use crate::util::Rng;

    fn toy(sched: Scheduler) -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![1.0, 0.3], vec![-0.8, -0.4], vec![0.1, 1.2]]).unwrap();
        AnalyticModel::new("toy", pts, sched, 0.08, 8).unwrap()
    }

    /// Transferring to the model's own scheduler must reproduce the plain
    /// fixed-grid solver of the same base (identity transform).
    #[test]
    fn self_transfer_is_identity() {
        let model = toy(Scheduler::CondOt);
        let mut rng = Rng::new(0);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let plain = crate::solvers::rk::FixedGridSolver::uniform(BaseRk::Rk2, 8);
        let xfer = TransferSolver::new(Scheduler::CondOt, Scheduler::CondOt, BaseRk::Rk2, 8);
        let a = plain.sample(&model, &x0).unwrap();
        let b = xfer.sample(&model, &x0).unwrap();
        let err = a.sub(&b).unwrap().rms();
        assert!(err < 2e-3, "self-transfer deviates: rms {err}");
    }

    /// Consistency (Theorem 2.2): as n grows the transfer solver converges
    /// to the GT solution.
    #[test]
    fn transfer_converges_to_gt() {
        let model = toy(Scheduler::Cosine);
        let mut rng = Rng::new(1);
        let x0 = Tensor::new(rng.normal_vec(16), vec![8, 2]).unwrap();
        let gt = Dopri5::default().sample(&model, &x0).unwrap();
        let err_at = |n: usize| {
            let s = TransferSolver::new(Scheduler::Cosine, Scheduler::CondOt, BaseRk::Rk2, n);
            s.sample(&model, &x0).unwrap().sub(&gt).unwrap().rms()
        };
        let (e8, e32) = (err_at(8), err_at(32));
        assert!(e32 < e8 * 0.5, "no convergence: e8={e8} e32={e32}");
        assert!(e32 < 0.05, "absolute error too large: {e32}");
    }

    #[test]
    fn nfe_and_name() {
        let s = TransferSolver::new(Scheduler::CondOt, Scheduler::VarPres, BaseRk::Rk2, 5);
        assert_eq!(s.nfe(), 10);
        assert!(s.name().contains("vp"));
    }
}
