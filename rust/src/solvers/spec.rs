//! Typed solver specs: the single source of truth for "which solver, with
//! which parameters".
//!
//! A [`SolverSpec`] is the validated, structured form of the colon-separated
//! CLI/server spec strings (`rk2:n=8:grid=edm`, `dopri5:rtol=1e-6:atol=1e-8`,
//! `bespoke:path=out/theta.json`, `bespoke:model=checker2-ot:n=8`, ...).
//! Parsing is strict — unknown keys, duplicate keys and malformed `k=v`
//! segments are errors, never silently dropped — and `Display` emits a
//! canonical string that parses back to an equal spec. Specs also round-trip
//! through JSON (`to_json`/`from_json`) so solver configs can travel inside
//! manifests, reports and wire requests.
//!
//! [`SolverSpec::build`] instantiates the described [`Sampler`] against a
//! model's scheduler; [`make_sampler`] is a thin `parse` + `build` wrapper.
//! The *registry-resolved* forms (`bespoke:model=M:n=8`,
//! `bns:model=M:n=8`, `multistep:model=M:n=8` — no path) cannot be built
//! directly: they name "the best trained artifact for this key", and
//! `crate::registry::Registry::resolve_spec` rewrites them to the concrete
//! `...:path=...` form (the coordinator and CLI do this automatically,
//! re-resolving per request so freshly registered artifacts hot-swap into
//! serving without a restart).
//!
//! The non-stationary families (DESIGN.md §11) follow the same grammar:
//! `bns:path=...` / `multistep:path=...` pin a checkpoint of that family
//! (family mismatch is an error), while `bespoke:path=...` dispatches on
//! whatever family the checkpoint declares — that permissiveness is what
//! lets budget routing and the frontier serve every trained family through
//! one resolved form. `ab:n=K[:base=rk][:order=M]` is the training-free
//! Adams–Bashforth baseline and builds with no checkpoint at all.

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use super::bns::{sampler_for_theta, AbSolver, BnsSolver, MultistepSolver};
use super::dopri5::Dopri5;
use super::grids::GridKind;
use super::rk::{BaseRk, FixedGridSolver};
use super::theta::{Base, RawTheta};
use super::transfer::TransferSolver;
use super::Sampler;
use crate::json::Value;
use crate::schedulers::Scheduler;

/// Default tolerance for spec-built DOPRI5 (matches the paper's GT runs).
pub const DOPRI5_DEFAULT_TOL: f64 = 1e-5;
/// Default step budget for spec-built DOPRI5.
pub const DOPRI5_DEFAULT_MAX_STEPS: usize = 100_000;

/// A fully-validated solver configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverSpec {
    /// Fixed-grid RK1/RK2/RK4 on the model's own path, optionally on a
    /// warped time grid.
    Rk { base: BaseRk, n: usize, grid: GridKind },
    /// Scheduler-transfer solver (DDIM/DPM/EDM analog): integrate along the
    /// sampling path of `sched` via the scale-time transform.
    Transfer { base: BaseRk, n: usize, sched: Scheduler },
    /// Adaptive DOPRI5 ground-truth solver.
    Dopri5 { rtol: f64, atol: f64, max_steps: usize },
    /// Learned Bespoke solver loaded from a theta checkpoint.
    Bespoke { path: String },
    /// Learned Bespoke solver resolved from the artifact registry: the best
    /// registered theta for `(model, n)` (optionally pinned to a base RK
    /// scheme / ablation), any family. Must be resolved to
    /// [`SolverSpec::Bespoke`] via `registry::Registry::resolve_spec`
    /// before building.
    BespokeRegistry {
        model: String,
        n: usize,
        base: Option<Base>,
        ablation: Option<String>,
    },
    /// BNS (per-step coefficient) solver loaded from a theta checkpoint;
    /// the checkpoint must declare `family=bns`.
    Bns { path: String },
    /// BNS solver resolved from the registry: the best `family=bns`
    /// artifact for `(model, n)`.
    BnsRegistry {
        model: String,
        n: usize,
        base: Option<Base>,
        ablation: Option<String>,
    },
    /// Learned-multistep solver loaded from a theta checkpoint; the
    /// checkpoint must declare `family=multistep` (and carries its window).
    Multistep { path: String },
    /// Multistep solver resolved from the registry: the best
    /// `family=multistep` artifact for `(model, n)`, any window.
    MultistepRegistry {
        model: String,
        n: usize,
        ablation: Option<String>,
    },
    /// Training-free Adams–Bashforth history-reuse solver of the given
    /// order, with base-RK warm-up steps.
    Ab { base: BaseRk, n: usize, order: usize },
}

/// Default base RK method for `ab:` specs.
pub const AB_DEFAULT_BASE: BaseRk = BaseRk::Rk2;
/// Default Adams–Bashforth order for `ab:` specs.
pub const AB_DEFAULT_ORDER: usize = 2;

/// Strict `k=v` segment list: rejects malformed segments and duplicates,
/// and tracks consumption so unknown keys can be reported.
struct KvParser {
    pairs: Vec<(String, String)>,
}

impl KvParser {
    fn parse<'a>(segments: impl Iterator<Item = &'a str>) -> Result<KvParser> {
        let mut pairs: Vec<(String, String)> = Vec::new();
        for seg in segments {
            let (k, v) = seg
                .split_once('=')
                .with_context(|| format!("malformed spec segment {seg:?} (expected key=value)"))?;
            if k.is_empty() || v.is_empty() {
                bail!("malformed spec segment {seg:?} (empty key or value)");
            }
            if pairs.iter().any(|(pk, _)| pk == k) {
                bail!("duplicate key {k:?} in spec");
            }
            pairs.push((k.to_string(), v.to_string()));
        }
        Ok(KvParser { pairs })
    }

    fn take(&mut self, key: &str) -> Option<String> {
        let i = self.pairs.iter().position(|(k, _)| k == key)?;
        Some(self.pairs.remove(i).1)
    }

    fn require(&mut self, key: &str) -> Result<String> {
        self.take(key).with_context(|| format!("missing {key}=<value>"))
    }

    /// Error out if any key was not consumed by the kind's grammar.
    fn finish(self, kind: &str) -> Result<()> {
        if let Some((k, _)) = self.pairs.first() {
            bail!("unknown key {k:?} for solver kind {kind:?}");
        }
        Ok(())
    }
}

fn parse_usize(key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>().with_context(|| format!("bad {key}={v:?}"))
}

fn parse_tol(key: &str, v: &str) -> Result<f64> {
    // positivity/finiteness is enforced by SolverSpec::validate
    v.parse().with_context(|| format!("bad {key}={v:?}"))
}

impl SolverSpec {
    /// Parse a spec string. Strict: every segment after the kind must be a
    /// known `key=value` pair for that kind.
    pub fn parse(spec: &str) -> Result<SolverSpec> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let mut kv = KvParser::parse(parts)?;
        let out = match kind {
            "rk1" | "rk2" | "rk4" | "euler" | "midpoint" => {
                let base = BaseRk::parse(kind)?;
                let n = parse_usize("n", &kv.require("n")?)?;
                let grid = match kv.take("grid") {
                    Some(g) => GridKind::parse(&g)?,
                    None => GridKind::Uniform,
                };
                SolverSpec::Rk { base, n, grid }
            }
            "rk1-target" | "rk2-target" | "rk4-target" => {
                let base = BaseRk::parse(kind.trim_end_matches("-target"))?;
                let n = parse_usize("n", &kv.require("n")?)?;
                let sched = Scheduler::parse(&kv.require("sched")?)?;
                SolverSpec::Transfer { base, n, sched }
            }
            "dopri5" => {
                let (mut rtol, mut atol) = (DOPRI5_DEFAULT_TOL, DOPRI5_DEFAULT_TOL);
                if let Some(t) = kv.take("tol") {
                    let t = parse_tol("tol", &t)?;
                    rtol = t;
                    atol = t;
                }
                if let Some(t) = kv.take("rtol") {
                    rtol = parse_tol("rtol", &t)?;
                }
                if let Some(t) = kv.take("atol") {
                    atol = parse_tol("atol", &t)?;
                }
                let max_steps = match kv.take("max_steps") {
                    Some(m) => parse_usize("max_steps", &m)?,
                    None => DOPRI5_DEFAULT_MAX_STEPS,
                };
                SolverSpec::Dopri5 { rtol, atol, max_steps }
            }
            "bespoke" => match kv.take("path") {
                Some(path) => {
                    if kv.pairs.iter().any(|(k, _)| k == "model" || k == "n") {
                        bail!(
                            "bespoke spec takes either path=... or \
                             model=.../n=..., not both"
                        );
                    }
                    SolverSpec::Bespoke { path }
                }
                None => SolverSpec::BespokeRegistry {
                    model: kv.require("model").context("need path=... or model=M:n=K")?,
                    n: parse_usize("n", &kv.require("n")?)?,
                    base: kv.take("base").map(|b| Base::parse(&b)).transpose()?,
                    ablation: kv.take("ablation"),
                },
            },
            "bns" => match kv.take("path") {
                Some(path) => {
                    if kv.pairs.iter().any(|(k, _)| k == "model" || k == "n") {
                        bail!(
                            "bns spec takes either path=... or \
                             model=.../n=..., not both"
                        );
                    }
                    SolverSpec::Bns { path }
                }
                None => SolverSpec::BnsRegistry {
                    model: kv.require("model").context("need path=... or model=M:n=K")?,
                    n: parse_usize("n", &kv.require("n")?)?,
                    base: kv.take("base").map(|b| Base::parse(&b)).transpose()?,
                    ablation: kv.take("ablation"),
                },
            },
            "multistep" => match kv.take("path") {
                Some(path) => {
                    if kv.pairs.iter().any(|(k, _)| k == "model" || k == "n") {
                        bail!(
                            "multistep spec takes either path=... or \
                             model=.../n=..., not both"
                        );
                    }
                    SolverSpec::Multistep { path }
                }
                None => SolverSpec::MultistepRegistry {
                    model: kv.require("model").context("need path=... or model=M:n=K")?,
                    n: parse_usize("n", &kv.require("n")?)?,
                    ablation: kv.take("ablation"),
                },
            },
            "ab" => SolverSpec::Ab {
                base: match kv.take("base") {
                    Some(b) => BaseRk::parse(&b)?,
                    None => AB_DEFAULT_BASE,
                },
                n: parse_usize("n", &kv.require("n")?)?,
                order: match kv.take("order") {
                    Some(o) => parse_usize("order", &o)?,
                    None => AB_DEFAULT_ORDER,
                },
            },
            _ => bail!(
                "unknown solver kind {kind:?} \
                 (rk1|rk2|rk4|rk1-target|rk2-target|rk4-target|dopri5|bespoke|\
                  bns|multistep|ab)"
            ),
        };
        kv.finish(kind)?;
        out.validate()?;
        Ok(out)
    }

    /// Structural validity checks shared by every deserialization path
    /// (string grammar and JSON): a `SolverSpec` that exists is buildable.
    fn validate(&self) -> Result<()> {
        match self {
            SolverSpec::Rk { n, .. } | SolverSpec::Transfer { n, .. } => {
                if *n == 0 {
                    bail!("n must be >= 1");
                }
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                for (name, v) in [("rtol", rtol), ("atol", atol)] {
                    if !(v.is_finite() && *v > 0.0) {
                        bail!("{name} must be a positive finite number, got {v}");
                    }
                }
                if *max_steps == 0 {
                    bail!("max_steps must be >= 1");
                }
            }
            SolverSpec::Bespoke { path } => {
                if path.is_empty() {
                    bail!("bespoke path must be non-empty");
                }
            }
            SolverSpec::Bns { path } => {
                if path.is_empty() {
                    bail!("bns path must be non-empty");
                }
            }
            SolverSpec::Multistep { path } => {
                if path.is_empty() {
                    bail!("multistep path must be non-empty");
                }
            }
            SolverSpec::BespokeRegistry { model, n, ablation, .. }
            | SolverSpec::BnsRegistry { model, n, ablation, .. }
            | SolverSpec::MultistepRegistry { model, n, ablation } => {
                if model.is_empty() {
                    bail!("{} model must be non-empty", self.kind());
                }
                if *n == 0 {
                    bail!("n must be >= 1");
                }
                if ablation.as_deref() == Some("") {
                    bail!("ablation must be non-empty when given");
                }
            }
            SolverSpec::Ab { n, order, .. } => {
                if *n == 0 {
                    bail!("n must be >= 1");
                }
                if !(1..=4).contains(order) {
                    bail!("ab order must be in 1..=4, got {order}");
                }
            }
        }
        Ok(())
    }

    /// The canonical spec kind, as spelled in spec strings.
    pub fn kind(&self) -> &'static str {
        match self {
            SolverSpec::Rk { base, .. } => base.name(),
            SolverSpec::Transfer { base, .. } => match base {
                BaseRk::Rk1 => "rk1-target",
                BaseRk::Rk2 => "rk2-target",
                BaseRk::Rk4 => "rk4-target",
            },
            SolverSpec::Dopri5 { .. } => "dopri5",
            SolverSpec::Bespoke { .. } | SolverSpec::BespokeRegistry { .. } => "bespoke",
            SolverSpec::Bns { .. } | SolverSpec::BnsRegistry { .. } => "bns",
            SolverSpec::Multistep { .. } | SolverSpec::MultistepRegistry { .. } => "multistep",
            SolverSpec::Ab { .. } => "ab",
        }
    }

    /// True for the registry-resolved forms, which need a
    /// `registry::Registry` to become buildable.
    pub fn needs_registry(&self) -> bool {
        matches!(
            self,
            SolverSpec::BespokeRegistry { .. }
                | SolverSpec::BnsRegistry { .. }
                | SolverSpec::MultistepRegistry { .. }
        )
    }

    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Value {
        match self {
            SolverSpec::Rk { base, n, grid } => Value::obj(vec![
                ("kind", Value::Str("rk".into())),
                ("base", Value::Str(base.name().into())),
                ("n", Value::Num(*n as f64)),
                ("grid", Value::Str(grid.name().into())),
            ]),
            SolverSpec::Transfer { base, n, sched } => Value::obj(vec![
                ("kind", Value::Str("transfer".into())),
                ("base", Value::Str(base.name().into())),
                ("n", Value::Num(*n as f64)),
                ("sched", Value::Str(sched.name().into())),
            ]),
            SolverSpec::Dopri5 { rtol, atol, max_steps } => Value::obj(vec![
                ("kind", Value::Str("dopri5".into())),
                ("rtol", Value::Num(*rtol)),
                ("atol", Value::Num(*atol)),
                ("max_steps", Value::Num(*max_steps as f64)),
            ]),
            SolverSpec::Bespoke { path } => Value::obj(vec![
                ("kind", Value::Str("bespoke".into())),
                ("path", Value::Str(path.clone())),
            ]),
            SolverSpec::BespokeRegistry { model, n, base, ablation } => {
                let mut fields = vec![
                    ("kind", Value::Str("bespoke-registry".into())),
                    ("model", Value::Str(model.clone())),
                    ("n", Value::Num(*n as f64)),
                ];
                if let Some(b) = base {
                    fields.push(("base", Value::Str(b.name().into())));
                }
                if let Some(a) = ablation {
                    fields.push(("ablation", Value::Str(a.clone())));
                }
                Value::obj(fields)
            }
            SolverSpec::Bns { path } => Value::obj(vec![
                ("kind", Value::Str("bns".into())),
                ("path", Value::Str(path.clone())),
            ]),
            SolverSpec::BnsRegistry { model, n, base, ablation } => {
                let mut fields = vec![
                    ("kind", Value::Str("bns-registry".into())),
                    ("model", Value::Str(model.clone())),
                    ("n", Value::Num(*n as f64)),
                ];
                if let Some(b) = base {
                    fields.push(("base", Value::Str(b.name().into())));
                }
                if let Some(a) = ablation {
                    fields.push(("ablation", Value::Str(a.clone())));
                }
                Value::obj(fields)
            }
            SolverSpec::Multistep { path } => Value::obj(vec![
                ("kind", Value::Str("multistep".into())),
                ("path", Value::Str(path.clone())),
            ]),
            SolverSpec::MultistepRegistry { model, n, ablation } => {
                let mut fields = vec![
                    ("kind", Value::Str("multistep-registry".into())),
                    ("model", Value::Str(model.clone())),
                    ("n", Value::Num(*n as f64)),
                ];
                if let Some(a) = ablation {
                    fields.push(("ablation", Value::Str(a.clone())));
                }
                Value::obj(fields)
            }
            SolverSpec::Ab { base, n, order } => Value::obj(vec![
                ("kind", Value::Str("ab".into())),
                ("base", Value::Str(base.name().into())),
                ("n", Value::Num(*n as f64)),
                ("order", Value::Num(*order as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<SolverSpec> {
        let out = match v.get("kind")?.as_str()? {
            "rk" => SolverSpec::Rk {
                base: BaseRk::parse(v.get("base")?.as_str()?)?,
                n: v.get("n")?.as_usize()?,
                grid: GridKind::parse(v.get("grid")?.as_str()?)?,
            },
            "transfer" => SolverSpec::Transfer {
                base: BaseRk::parse(v.get("base")?.as_str()?)?,
                n: v.get("n")?.as_usize()?,
                sched: Scheduler::parse(v.get("sched")?.as_str()?)?,
            },
            "dopri5" => SolverSpec::Dopri5 {
                rtol: v.get("rtol")?.as_f64()?,
                atol: v.get("atol")?.as_f64()?,
                max_steps: v.get("max_steps")?.as_usize()?,
            },
            "bespoke" => SolverSpec::Bespoke { path: v.get("path")?.as_str()?.to_string() },
            "bespoke-registry" => SolverSpec::BespokeRegistry {
                model: v.get("model")?.as_str()?.to_string(),
                n: v.get("n")?.as_usize()?,
                base: v.get_opt("base").map(|b| Base::parse(b.as_str()?)).transpose()?,
                ablation: v
                    .get_opt("ablation")
                    .map(|a| Ok::<_, anyhow::Error>(a.as_str()?.to_string()))
                    .transpose()?,
            },
            "bns" => SolverSpec::Bns { path: v.get("path")?.as_str()?.to_string() },
            "bns-registry" => SolverSpec::BnsRegistry {
                model: v.get("model")?.as_str()?.to_string(),
                n: v.get("n")?.as_usize()?,
                base: v.get_opt("base").map(|b| Base::parse(b.as_str()?)).transpose()?,
                ablation: v
                    .get_opt("ablation")
                    .map(|a| Ok::<_, anyhow::Error>(a.as_str()?.to_string()))
                    .transpose()?,
            },
            "multistep" => SolverSpec::Multistep { path: v.get("path")?.as_str()?.to_string() },
            "multistep-registry" => SolverSpec::MultistepRegistry {
                model: v.get("model")?.as_str()?.to_string(),
                n: v.get("n")?.as_usize()?,
                ablation: v
                    .get_opt("ablation")
                    .map(|a| Ok::<_, anyhow::Error>(a.as_str()?.to_string()))
                    .transpose()?,
            },
            "ab" => SolverSpec::Ab {
                base: BaseRk::parse(v.get("base")?.as_str()?)?,
                n: v.get("n")?.as_usize()?,
                order: v.get("order")?.as_usize()?,
            },
            other => bail!("unknown solver spec kind {other:?} in JSON"),
        };
        out.validate()?;
        Ok(out)
    }

    // ---- construction ----------------------------------------------------

    /// Instantiate the sampler this spec describes. `model_sched` is the
    /// scheduler of the model the sampler will run against (needed by warped
    /// grids and scheduler transfer).
    pub fn build(&self, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
        match self {
            SolverSpec::Rk { base, n, grid } => {
                let g = grid.build(*n, model_sched);
                Ok(Box::new(FixedGridSolver::with_grid(*base, g, self.to_string())))
            }
            SolverSpec::Transfer { base, n, sched } => {
                Ok(Box::new(TransferSolver::new(model_sched, *sched, *base, *n)))
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => Ok(Box::new(Dopri5 {
                rtol: *rtol,
                atol: *atol,
                max_steps: *max_steps,
            })),
            SolverSpec::Bespoke { path } => {
                // permissive: serves whatever family the checkpoint
                // declares, so registry-resolved and budget-routed paths
                // work for every trained family
                let raw = RawTheta::load(std::path::Path::new(path))
                    .with_context(|| format!("loading theta from {path}"))?;
                sampler_for_theta(&raw)
            }
            SolverSpec::Bns { path } => {
                let raw = RawTheta::load(std::path::Path::new(path))
                    .with_context(|| format!("loading theta from {path}"))?;
                Ok(Box::new(
                    BnsSolver::new(&raw).with_context(|| format!("building bns from {path}"))?,
                ))
            }
            SolverSpec::Multistep { path } => {
                let raw = RawTheta::load(std::path::Path::new(path))
                    .with_context(|| format!("loading theta from {path}"))?;
                Ok(Box::new(
                    MultistepSolver::new(&raw)
                        .with_context(|| format!("building multistep from {path}"))?,
                ))
            }
            SolverSpec::Ab { base, n, order } => Ok(Box::new(AbSolver::new(*base, *n, *order)?)),
            SolverSpec::BespokeRegistry { .. }
            | SolverSpec::BnsRegistry { .. }
            | SolverSpec::MultistepRegistry { .. } => bail!(
                "spec {self} is registry-resolved; resolve it to a concrete \
                 {}:path=... via registry::Registry::resolve_spec first \
                 (serve/sample attach the registry automatically)",
                self.kind()
            ),
        }
    }
}

/// Build a sampler from a spec string; `model_sched` is the scheduler of
/// the model the sampler will run against. Equivalent to
/// `SolverSpec::parse(spec)?.build(model_sched)`.
pub fn make_sampler(spec: &str, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
    SolverSpec::parse(spec)?.build(model_sched)
}

impl fmt::Display for SolverSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolverSpec::Rk { base, n, grid } => {
                write!(f, "{}:n={n}", base.name())?;
                if *grid != GridKind::Uniform {
                    write!(f, ":grid={}", grid.name())?;
                }
                Ok(())
            }
            SolverSpec::Transfer { base, n, sched } => {
                write!(f, "{}-target:n={n}:sched={}", base.name(), sched.name())
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                if rtol == atol {
                    write!(f, "dopri5:tol={rtol:e}")?;
                } else {
                    write!(f, "dopri5:rtol={rtol:e}:atol={atol:e}")?;
                }
                if *max_steps != DOPRI5_DEFAULT_MAX_STEPS {
                    write!(f, ":max_steps={max_steps}")?;
                }
                Ok(())
            }
            SolverSpec::Bespoke { path } => write!(f, "bespoke:path={path}"),
            SolverSpec::BespokeRegistry { model, n, base, ablation } => {
                write!(f, "bespoke:model={model}:n={n}")?;
                if let Some(b) = base {
                    write!(f, ":base={}", b.name())?;
                }
                if let Some(a) = ablation {
                    write!(f, ":ablation={a}")?;
                }
                Ok(())
            }
            SolverSpec::Bns { path } => write!(f, "bns:path={path}"),
            SolverSpec::BnsRegistry { model, n, base, ablation } => {
                write!(f, "bns:model={model}:n={n}")?;
                if let Some(b) = base {
                    write!(f, ":base={}", b.name())?;
                }
                if let Some(a) = ablation {
                    write!(f, ":ablation={a}")?;
                }
                Ok(())
            }
            SolverSpec::Multistep { path } => write!(f, "multistep:path={path}"),
            SolverSpec::MultistepRegistry { model, n, ablation } => {
                write!(f, "multistep:model={model}:n={n}")?;
                if let Some(a) = ablation {
                    write!(f, ":ablation={a}")?;
                }
                Ok(())
            }
            SolverSpec::Ab { base, n, order } => {
                write!(f, "ab")?;
                if *base != AB_DEFAULT_BASE {
                    write!(f, ":base={}", base.name())?;
                }
                write!(f, ":n={n}")?;
                if *order != AB_DEFAULT_ORDER {
                    write!(f, ":order={order}")?;
                }
                Ok(())
            }
        }
    }
}

impl FromStr for SolverSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SolverSpec> {
        SolverSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::theta::Family;

    /// Every spec shape documented in the CLI HELP text.
    const DOCUMENTED: &[&str] = &[
        "rk1:n=10",
        "rk2:n=5",
        "rk4:n=3",
        "rk2:n=5:grid=edm",
        "rk2:n=5:grid=logsnr",
        "rk2:n=5:grid=cosine",
        "rk1-target:n=5:sched=vp",
        "rk2-target:n=5:sched=vp",
        "rk2-target:n=5:sched=edm",
        "dopri5:tol=1e-5",
        "dopri5:rtol=1e-6:atol=1e-8",
        "dopri5:tol=1e-4:max_steps=500",
        "dopri5",
        "bespoke:path=out/thetas/theta_checker2-ot_rk2_n8.json",
        "bespoke:model=checker2-ot:n=8",
        "bespoke:model=checker2-ot:n=8:base=rk1",
        "bespoke:model=checker2-ot:n=8:base=rk2:ablation=time-only",
        "bns:path=out/thetas/bns_checker2-ot_rk2_n8.json",
        "bns:model=checker2-ot:n=8",
        "bns:model=checker2-ot:n=8:base=rk2",
        "bns:model=checker2-ot:n=8:base=rk1:ablation=full",
        "multistep:path=out/thetas/ms_checker2-ot_n8.json",
        "multistep:model=checker2-ot:n=8",
        "multistep:model=checker2-ot:n=8:ablation=full",
        "ab:n=8",
        "ab:base=rk1:n=8",
        "ab:base=rk4:n=6:order=3",
        "ab:n=8:order=1",
        "ab:n=8:order=4",
    ];

    #[test]
    fn display_roundtrips_documented_specs() {
        for s in DOCUMENTED {
            let spec = SolverSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e:#}"));
            let shown = spec.to_string();
            let back = SolverSpec::parse(&shown)
                .unwrap_or_else(|e| panic!("reparse {shown:?}: {e:#}"));
            assert_eq!(back, spec, "round-trip mismatch for {s:?} -> {shown:?}");
        }
    }

    #[test]
    fn json_roundtrips_documented_specs() {
        for s in DOCUMENTED {
            let spec = SolverSpec::parse(s).unwrap();
            let j = spec.to_json().to_string_compact();
            let back = SolverSpec::from_json(&Value::parse(&j).unwrap())
                .unwrap_or_else(|e| panic!("{j}: {e:#}"));
            assert_eq!(back, spec, "JSON round-trip mismatch for {s:?}");
        }
    }

    #[test]
    fn json_rejects_invalid_specs() {
        for j in [
            r#"{"kind":"rk","base":"rk2","n":0,"grid":"uniform"}"#,
            r#"{"kind":"dopri5","rtol":-1,"atol":1e-5,"max_steps":100}"#,
            r#"{"kind":"dopri5","rtol":1e-5,"atol":1e-5,"max_steps":0}"#,
            r#"{"kind":"bespoke","path":""}"#,
            r#"{"kind":"bns","path":""}"#,
            r#"{"kind":"multistep","path":""}"#,
            r#"{"kind":"bns-registry","model":"m","n":0}"#,
            r#"{"kind":"ab","base":"rk2","n":4,"order":5}"#,
            r#"{"kind":"ab","base":"rk2","n":0,"order":2}"#,
            r#"{"kind":"nope"}"#,
        ] {
            let v = Value::parse(j).unwrap();
            assert!(SolverSpec::from_json(&v).is_err(), "should reject {j}");
        }
    }

    #[test]
    fn aliases_canonicalize() {
        assert_eq!(
            SolverSpec::parse("euler:n=4").unwrap(),
            SolverSpec::parse("rk1:n=4").unwrap()
        );
        assert_eq!(
            SolverSpec::parse("midpoint:n=4").unwrap(),
            SolverSpec::parse("rk2:n=4").unwrap()
        );
        assert_eq!(SolverSpec::parse("euler:n=4").unwrap().to_string(), "rk1:n=4");
    }

    #[test]
    fn dopri5_tolerance_grammar() {
        // bare -> defaults
        match SolverSpec::parse("dopri5").unwrap() {
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                assert_eq!(rtol, DOPRI5_DEFAULT_TOL);
                assert_eq!(atol, DOPRI5_DEFAULT_TOL);
                assert_eq!(max_steps, DOPRI5_DEFAULT_MAX_STEPS);
            }
            s => panic!("wrong spec {s:?}"),
        }
        // tol sets both; rtol/atol set independently and override tol
        match SolverSpec::parse("dopri5:tol=1e-4:atol=1e-7").unwrap() {
            SolverSpec::Dopri5 { rtol, atol, .. } => {
                assert_eq!(rtol, 1e-4);
                assert_eq!(atol, 1e-7);
            }
            s => panic!("wrong spec {s:?}"),
        }
    }

    #[test]
    fn strict_rejections() {
        for s in [
            "nope:n=4",            // unknown kind
            "rk2",                 // missing n
            "rk2:n=x",             // bad n value
            "rk2:n=0",             // zero steps
            "rk2:n=4:grid=nope",   // unknown grid
            "rk2:n=4:foo=1",       // unknown key
            "rk2:n",               // k without =
            "rk2:n=4:",            // empty trailing segment
            "rk2:=4",              // empty key
            "rk2:n=",              // empty value
            "rk2:n=4:n=8",         // duplicate key
            "rk2-target:n=4",      // missing sched
            "rk2-target:n=4:sched=nope",
            "dopri5:tol=-1",       // non-positive tol
            "dopri5:tol=abc",
            "dopri5:max_steps=0",
            "dopri5:n=4",          // key from another kind
            "bespoke",             // missing path and model
            "bespoke:model=m",     // registry form missing n
            "bespoke:model=m:n=0", // zero steps
            "bespoke:model=m:n=4:base=rk4",  // no rk4 bespoke base
            "bespoke:path=x:model=m:n=4",    // path and model are exclusive
            "bespoke:model=m:n=4:foo=1",     // unknown key
            "bns",                           // missing path and model
            "bns:path=x:model=m:n=4",        // path and model are exclusive
            "bns:model=m",                   // registry form missing n
            "bns:model=m:n=0",               // zero steps
            "bns:model=m:n=4:base=rk4",      // no rk4 bns base
            "multistep",                     // missing path and model
            "multistep:model=m:n=4:base=rk1", // multistep has no base key
            "multistep:model=m:n=0",         // zero steps
            "ab",                            // missing n
            "ab:n=0",                        // zero steps
            "ab:n=4:order=0",                // order out of range
            "ab:n=4:order=5",                // order out of range
            "ab:n=4:window=2",               // unknown key
        ] {
            assert!(SolverSpec::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn registry_form_needs_resolution() {
        for (s, kind) in [
            ("bespoke:model=m:n=4", "bespoke"),
            ("bns:model=m:n=4", "bns"),
            ("multistep:model=m:n=4", "multistep"),
        ] {
            let spec = SolverSpec::parse(s).unwrap();
            assert!(spec.needs_registry(), "{s}");
            assert_eq!(spec.kind(), kind);
            let err = spec.build(Scheduler::CondOt).unwrap_err().to_string();
            assert!(err.contains("registry"), "unhelpful error for {s}: {err}");
        }
        for s in ["bespoke:path=x.json", "bns:path=x.json", "multistep:path=x.json", "ab:n=4"] {
            assert!(!SolverSpec::parse(s).unwrap().needs_registry(), "{s}");
        }
    }

    #[test]
    fn make_sampler_builds_every_buildable_kind() {
        let s = Scheduler::CondOt;
        for spec in [
            "rk1:n=4",
            "rk2:n=8:grid=edm",
            "rk2:n=8:grid=logsnr",
            "rk2:n=8:grid=cosine",
            "rk4:n=2",
            "rk2-target:n=4:sched=vp",
            "dopri5:tol=1e-4",
            "dopri5:rtol=1e-4:atol=1e-6",
            "dopri5",
            "ab:n=4",
            "ab:base=rk1:n=4:order=1",
            "ab:base=rk4:n=3:order=4",
        ] {
            let sampler = make_sampler(spec, s).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!sampler.name().is_empty());
        }
        for spec in [
            "nope:n=4",
            "rk2",
            "rk2:n=4:n=8",
            "bespoke:model=m:n=4",
            "bns:model=m:n=4",
            "multistep:model=m:n=4",
        ] {
            assert!(make_sampler(spec, s).is_err(), "should reject {spec}");
        }
    }

    #[test]
    fn make_sampler_bespoke_from_checkpoint() {
        let th = RawTheta::identity(Base::Rk2, 4);
        let dir = std::env::temp_dir().join(format!("bespoke_spec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("theta.json");
        th.save(&path).unwrap();
        let s = make_sampler(
            &format!("bespoke:path={}", path.display()),
            Scheduler::CondOt,
        )
        .unwrap();
        assert_eq!(s.nfe(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builds_non_checkpoint_kinds() {
        for s in DOCUMENTED {
            if s.starts_with("bespoke") || s.starts_with("bns") || s.starts_with("multistep") {
                // needs a checkpoint on disk (covered above) or a registry
                continue;
            }
            let spec = SolverSpec::parse(s).unwrap();
            let sampler = spec
                .build(Scheduler::CondOt)
                .unwrap_or_else(|e| panic!("{s}: {e:#}"));
            assert!(!sampler.name().is_empty());
        }
    }

    #[test]
    fn built_sampler_name_matches_canonical_spec() {
        for s in [
            "rk2:n=8",
            "rk2:n=8:grid=edm",
            "rk1:n=4",
            "ab:n=8",
            "ab:base=rk1:n=8",
            "ab:base=rk4:n=6:order=3",
            "ab:n=8:order=1",
        ] {
            let spec = SolverSpec::parse(s).unwrap();
            let sampler = spec.build(Scheduler::CondOt).unwrap();
            assert_eq!(sampler.name(), spec.to_string());
        }
    }

    /// `bespoke:path=...` dispatches on the checkpoint's declared family
    /// (serves whatever the theta is), while `bns:path=...` /
    /// `multistep:path=...` pin the family and reject mismatches.
    #[test]
    fn path_forms_dispatch_on_checkpoint_family() {
        let dir = std::env::temp_dir().join(format!("family_spec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let bns = RawTheta::identity_for(Family::Bns, Base::Rk2, 4, 0).unwrap();
        let bns_path = dir.join("bns.json");
        bns.save(&bns_path).unwrap();
        let ms = RawTheta::identity_for(Family::Multistep, Base::Rk1, 4, 2).unwrap();
        let ms_path = dir.join("ms.json");
        ms.save(&ms_path).unwrap();
        let st = RawTheta::identity(Base::Rk2, 4);
        let st_path = dir.join("stationary.json");
        st.save(&st_path).unwrap();

        // bespoke:path serves every family
        for (p, nfe) in [(&bns_path, 8), (&ms_path, 4), (&st_path, 8)] {
            let s = make_sampler(&format!("bespoke:path={}", p.display()), Scheduler::CondOt)
                .unwrap_or_else(|e| panic!("{}: {e:#}", p.display()));
            assert_eq!(s.nfe(), nfe);
        }
        // pinned forms accept their own family...
        assert!(make_sampler(&format!("bns:path={}", bns_path.display()), Scheduler::CondOt).is_ok());
        assert!(
            make_sampler(&format!("multistep:path={}", ms_path.display()), Scheduler::CondOt)
                .is_ok()
        );
        // ...and reject others with a family-mismatch error
        let err = make_sampler(&format!("bns:path={}", st_path.display()), Scheduler::CondOt)
            .map(|_| ())
            .unwrap_err();
        let err = format!("{err:#}");
        assert!(err.contains("bns") || err.contains("family"), "unhelpful error: {err}");
        assert!(
            make_sampler(&format!("multistep:path={}", bns_path.display()), Scheduler::CondOt)
                .is_err()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
