//! Typed solver specs: the single source of truth for "which solver, with
//! which parameters".
//!
//! A [`SolverSpec`] is the validated, structured form of the colon-separated
//! CLI/server spec strings (`rk2:n=8:grid=edm`, `dopri5:rtol=1e-6:atol=1e-8`,
//! `bespoke:path=out/theta.json`, `bespoke:model=checker2-ot:n=8`, ...).
//! Parsing is strict — unknown keys, duplicate keys and malformed `k=v`
//! segments are errors, never silently dropped — and `Display` emits a
//! canonical string that parses back to an equal spec. Specs also round-trip
//! through JSON (`to_json`/`from_json`) so solver configs can travel inside
//! manifests, reports and wire requests.
//!
//! [`SolverSpec::build`] instantiates the described [`Sampler`] against a
//! model's scheduler; [`make_sampler`] is a thin `parse` + `build` wrapper.
//! The *registry-resolved* bespoke form (`bespoke:model=M:n=8` — no path)
//! cannot be built directly: it names "the best trained artifact for this
//! key", and `crate::registry::Registry::resolve_spec` rewrites it to the
//! concrete `bespoke:path=...` form (the coordinator and CLI do this
//! automatically, re-resolving per request so freshly registered artifacts
//! hot-swap into serving without a restart).

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use super::bespoke::BespokeSolver;
use super::dopri5::Dopri5;
use super::grids::GridKind;
use super::rk::{BaseRk, FixedGridSolver};
use super::theta::{Base, RawTheta};
use super::transfer::TransferSolver;
use super::Sampler;
use crate::json::Value;
use crate::schedulers::Scheduler;

/// Default tolerance for spec-built DOPRI5 (matches the paper's GT runs).
pub const DOPRI5_DEFAULT_TOL: f64 = 1e-5;
/// Default step budget for spec-built DOPRI5.
pub const DOPRI5_DEFAULT_MAX_STEPS: usize = 100_000;

/// A fully-validated solver configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverSpec {
    /// Fixed-grid RK1/RK2/RK4 on the model's own path, optionally on a
    /// warped time grid.
    Rk { base: BaseRk, n: usize, grid: GridKind },
    /// Scheduler-transfer solver (DDIM/DPM/EDM analog): integrate along the
    /// sampling path of `sched` via the scale-time transform.
    Transfer { base: BaseRk, n: usize, sched: Scheduler },
    /// Adaptive DOPRI5 ground-truth solver.
    Dopri5 { rtol: f64, atol: f64, max_steps: usize },
    /// Learned Bespoke solver loaded from a theta checkpoint.
    Bespoke { path: String },
    /// Learned Bespoke solver resolved from the artifact registry: the best
    /// registered theta for `(model, n)` (optionally pinned to a base RK
    /// scheme / ablation). Must be resolved to [`SolverSpec::Bespoke`] via
    /// `registry::Registry::resolve_spec` before building.
    BespokeRegistry {
        model: String,
        n: usize,
        base: Option<Base>,
        ablation: Option<String>,
    },
}

/// Strict `k=v` segment list: rejects malformed segments and duplicates,
/// and tracks consumption so unknown keys can be reported.
struct KvParser {
    pairs: Vec<(String, String)>,
}

impl KvParser {
    fn parse<'a>(segments: impl Iterator<Item = &'a str>) -> Result<KvParser> {
        let mut pairs: Vec<(String, String)> = Vec::new();
        for seg in segments {
            let (k, v) = seg
                .split_once('=')
                .with_context(|| format!("malformed spec segment {seg:?} (expected key=value)"))?;
            if k.is_empty() || v.is_empty() {
                bail!("malformed spec segment {seg:?} (empty key or value)");
            }
            if pairs.iter().any(|(pk, _)| pk == k) {
                bail!("duplicate key {k:?} in spec");
            }
            pairs.push((k.to_string(), v.to_string()));
        }
        Ok(KvParser { pairs })
    }

    fn take(&mut self, key: &str) -> Option<String> {
        let i = self.pairs.iter().position(|(k, _)| k == key)?;
        Some(self.pairs.remove(i).1)
    }

    fn require(&mut self, key: &str) -> Result<String> {
        self.take(key).with_context(|| format!("missing {key}=<value>"))
    }

    /// Error out if any key was not consumed by the kind's grammar.
    fn finish(self, kind: &str) -> Result<()> {
        if let Some((k, _)) = self.pairs.first() {
            bail!("unknown key {k:?} for solver kind {kind:?}");
        }
        Ok(())
    }
}

fn parse_usize(key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>().with_context(|| format!("bad {key}={v:?}"))
}

fn parse_tol(key: &str, v: &str) -> Result<f64> {
    // positivity/finiteness is enforced by SolverSpec::validate
    v.parse().with_context(|| format!("bad {key}={v:?}"))
}

impl SolverSpec {
    /// Parse a spec string. Strict: every segment after the kind must be a
    /// known `key=value` pair for that kind.
    pub fn parse(spec: &str) -> Result<SolverSpec> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let mut kv = KvParser::parse(parts)?;
        let out = match kind {
            "rk1" | "rk2" | "rk4" | "euler" | "midpoint" => {
                let base = BaseRk::parse(kind)?;
                let n = parse_usize("n", &kv.require("n")?)?;
                let grid = match kv.take("grid") {
                    Some(g) => GridKind::parse(&g)?,
                    None => GridKind::Uniform,
                };
                SolverSpec::Rk { base, n, grid }
            }
            "rk1-target" | "rk2-target" | "rk4-target" => {
                let base = BaseRk::parse(kind.trim_end_matches("-target"))?;
                let n = parse_usize("n", &kv.require("n")?)?;
                let sched = Scheduler::parse(&kv.require("sched")?)?;
                SolverSpec::Transfer { base, n, sched }
            }
            "dopri5" => {
                let (mut rtol, mut atol) = (DOPRI5_DEFAULT_TOL, DOPRI5_DEFAULT_TOL);
                if let Some(t) = kv.take("tol") {
                    let t = parse_tol("tol", &t)?;
                    rtol = t;
                    atol = t;
                }
                if let Some(t) = kv.take("rtol") {
                    rtol = parse_tol("rtol", &t)?;
                }
                if let Some(t) = kv.take("atol") {
                    atol = parse_tol("atol", &t)?;
                }
                let max_steps = match kv.take("max_steps") {
                    Some(m) => parse_usize("max_steps", &m)?,
                    None => DOPRI5_DEFAULT_MAX_STEPS,
                };
                SolverSpec::Dopri5 { rtol, atol, max_steps }
            }
            "bespoke" => match kv.take("path") {
                Some(path) => {
                    if kv.pairs.iter().any(|(k, _)| k == "model" || k == "n") {
                        bail!(
                            "bespoke spec takes either path=... or \
                             model=.../n=..., not both"
                        );
                    }
                    SolverSpec::Bespoke { path }
                }
                None => SolverSpec::BespokeRegistry {
                    model: kv.require("model").context("need path=... or model=M:n=K")?,
                    n: parse_usize("n", &kv.require("n")?)?,
                    base: kv.take("base").map(|b| Base::parse(&b)).transpose()?,
                    ablation: kv.take("ablation"),
                },
            },
            _ => bail!(
                "unknown solver kind {kind:?} \
                 (rk1|rk2|rk4|rk1-target|rk2-target|rk4-target|dopri5|bespoke)"
            ),
        };
        kv.finish(kind)?;
        out.validate()?;
        Ok(out)
    }

    /// Structural validity checks shared by every deserialization path
    /// (string grammar and JSON): a `SolverSpec` that exists is buildable.
    fn validate(&self) -> Result<()> {
        match self {
            SolverSpec::Rk { n, .. } | SolverSpec::Transfer { n, .. } => {
                if *n == 0 {
                    bail!("n must be >= 1");
                }
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                for (name, v) in [("rtol", rtol), ("atol", atol)] {
                    if !(v.is_finite() && *v > 0.0) {
                        bail!("{name} must be a positive finite number, got {v}");
                    }
                }
                if *max_steps == 0 {
                    bail!("max_steps must be >= 1");
                }
            }
            SolverSpec::Bespoke { path } => {
                if path.is_empty() {
                    bail!("bespoke path must be non-empty");
                }
            }
            SolverSpec::BespokeRegistry { model, n, ablation, .. } => {
                if model.is_empty() {
                    bail!("bespoke model must be non-empty");
                }
                if *n == 0 {
                    bail!("n must be >= 1");
                }
                if ablation.as_deref() == Some("") {
                    bail!("ablation must be non-empty when given");
                }
            }
        }
        Ok(())
    }

    /// The canonical spec kind, as spelled in spec strings.
    pub fn kind(&self) -> &'static str {
        match self {
            SolverSpec::Rk { base, .. } => base.name(),
            SolverSpec::Transfer { base, .. } => match base {
                BaseRk::Rk1 => "rk1-target",
                BaseRk::Rk2 => "rk2-target",
                BaseRk::Rk4 => "rk4-target",
            },
            SolverSpec::Dopri5 { .. } => "dopri5",
            SolverSpec::Bespoke { .. } | SolverSpec::BespokeRegistry { .. } => "bespoke",
        }
    }

    /// True for the registry-resolved bespoke form, which needs a
    /// `registry::Registry` to become buildable.
    pub fn needs_registry(&self) -> bool {
        matches!(self, SolverSpec::BespokeRegistry { .. })
    }

    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Value {
        match self {
            SolverSpec::Rk { base, n, grid } => Value::obj(vec![
                ("kind", Value::Str("rk".into())),
                ("base", Value::Str(base.name().into())),
                ("n", Value::Num(*n as f64)),
                ("grid", Value::Str(grid.name().into())),
            ]),
            SolverSpec::Transfer { base, n, sched } => Value::obj(vec![
                ("kind", Value::Str("transfer".into())),
                ("base", Value::Str(base.name().into())),
                ("n", Value::Num(*n as f64)),
                ("sched", Value::Str(sched.name().into())),
            ]),
            SolverSpec::Dopri5 { rtol, atol, max_steps } => Value::obj(vec![
                ("kind", Value::Str("dopri5".into())),
                ("rtol", Value::Num(*rtol)),
                ("atol", Value::Num(*atol)),
                ("max_steps", Value::Num(*max_steps as f64)),
            ]),
            SolverSpec::Bespoke { path } => Value::obj(vec![
                ("kind", Value::Str("bespoke".into())),
                ("path", Value::Str(path.clone())),
            ]),
            SolverSpec::BespokeRegistry { model, n, base, ablation } => {
                let mut fields = vec![
                    ("kind", Value::Str("bespoke-registry".into())),
                    ("model", Value::Str(model.clone())),
                    ("n", Value::Num(*n as f64)),
                ];
                if let Some(b) = base {
                    fields.push(("base", Value::Str(b.name().into())));
                }
                if let Some(a) = ablation {
                    fields.push(("ablation", Value::Str(a.clone())));
                }
                Value::obj(fields)
            }
        }
    }

    pub fn from_json(v: &Value) -> Result<SolverSpec> {
        let out = match v.get("kind")?.as_str()? {
            "rk" => SolverSpec::Rk {
                base: BaseRk::parse(v.get("base")?.as_str()?)?,
                n: v.get("n")?.as_usize()?,
                grid: GridKind::parse(v.get("grid")?.as_str()?)?,
            },
            "transfer" => SolverSpec::Transfer {
                base: BaseRk::parse(v.get("base")?.as_str()?)?,
                n: v.get("n")?.as_usize()?,
                sched: Scheduler::parse(v.get("sched")?.as_str()?)?,
            },
            "dopri5" => SolverSpec::Dopri5 {
                rtol: v.get("rtol")?.as_f64()?,
                atol: v.get("atol")?.as_f64()?,
                max_steps: v.get("max_steps")?.as_usize()?,
            },
            "bespoke" => SolverSpec::Bespoke { path: v.get("path")?.as_str()?.to_string() },
            "bespoke-registry" => SolverSpec::BespokeRegistry {
                model: v.get("model")?.as_str()?.to_string(),
                n: v.get("n")?.as_usize()?,
                base: v.get_opt("base").map(|b| Base::parse(b.as_str()?)).transpose()?,
                ablation: v
                    .get_opt("ablation")
                    .map(|a| Ok::<_, anyhow::Error>(a.as_str()?.to_string()))
                    .transpose()?,
            },
            other => bail!("unknown solver spec kind {other:?} in JSON"),
        };
        out.validate()?;
        Ok(out)
    }

    // ---- construction ----------------------------------------------------

    /// Instantiate the sampler this spec describes. `model_sched` is the
    /// scheduler of the model the sampler will run against (needed by warped
    /// grids and scheduler transfer).
    pub fn build(&self, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
        match self {
            SolverSpec::Rk { base, n, grid } => {
                let g = grid.build(*n, model_sched);
                Ok(Box::new(FixedGridSolver::with_grid(*base, g, self.to_string())))
            }
            SolverSpec::Transfer { base, n, sched } => {
                Ok(Box::new(TransferSolver::new(model_sched, *sched, *base, *n)))
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => Ok(Box::new(Dopri5 {
                rtol: *rtol,
                atol: *atol,
                max_steps: *max_steps,
            })),
            SolverSpec::Bespoke { path } => {
                let raw = RawTheta::load(std::path::Path::new(path))
                    .with_context(|| format!("loading theta from {path}"))?;
                Ok(Box::new(BespokeSolver::new(&raw)))
            }
            SolverSpec::BespokeRegistry { .. } => bail!(
                "spec {self} is registry-resolved; resolve it to a concrete \
                 bespoke:path=... via registry::Registry::resolve_spec first \
                 (serve/sample attach the registry automatically)"
            ),
        }
    }
}

/// Build a sampler from a spec string; `model_sched` is the scheduler of
/// the model the sampler will run against. Equivalent to
/// `SolverSpec::parse(spec)?.build(model_sched)`.
pub fn make_sampler(spec: &str, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
    SolverSpec::parse(spec)?.build(model_sched)
}

impl fmt::Display for SolverSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolverSpec::Rk { base, n, grid } => {
                write!(f, "{}:n={n}", base.name())?;
                if *grid != GridKind::Uniform {
                    write!(f, ":grid={}", grid.name())?;
                }
                Ok(())
            }
            SolverSpec::Transfer { base, n, sched } => {
                write!(f, "{}-target:n={n}:sched={}", base.name(), sched.name())
            }
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                if rtol == atol {
                    write!(f, "dopri5:tol={rtol:e}")?;
                } else {
                    write!(f, "dopri5:rtol={rtol:e}:atol={atol:e}")?;
                }
                if *max_steps != DOPRI5_DEFAULT_MAX_STEPS {
                    write!(f, ":max_steps={max_steps}")?;
                }
                Ok(())
            }
            SolverSpec::Bespoke { path } => write!(f, "bespoke:path={path}"),
            SolverSpec::BespokeRegistry { model, n, base, ablation } => {
                write!(f, "bespoke:model={model}:n={n}")?;
                if let Some(b) = base {
                    write!(f, ":base={}", b.name())?;
                }
                if let Some(a) = ablation {
                    write!(f, ":ablation={a}")?;
                }
                Ok(())
            }
        }
    }
}

impl FromStr for SolverSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SolverSpec> {
        SolverSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every spec shape documented in the CLI HELP text.
    const DOCUMENTED: &[&str] = &[
        "rk1:n=10",
        "rk2:n=5",
        "rk4:n=3",
        "rk2:n=5:grid=edm",
        "rk2:n=5:grid=logsnr",
        "rk2:n=5:grid=cosine",
        "rk1-target:n=5:sched=vp",
        "rk2-target:n=5:sched=vp",
        "rk2-target:n=5:sched=edm",
        "dopri5:tol=1e-5",
        "dopri5:rtol=1e-6:atol=1e-8",
        "dopri5:tol=1e-4:max_steps=500",
        "dopri5",
        "bespoke:path=out/thetas/theta_checker2-ot_rk2_n8.json",
        "bespoke:model=checker2-ot:n=8",
        "bespoke:model=checker2-ot:n=8:base=rk1",
        "bespoke:model=checker2-ot:n=8:base=rk2:ablation=time-only",
    ];

    #[test]
    fn display_roundtrips_documented_specs() {
        for s in DOCUMENTED {
            let spec = SolverSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e:#}"));
            let shown = spec.to_string();
            let back = SolverSpec::parse(&shown)
                .unwrap_or_else(|e| panic!("reparse {shown:?}: {e:#}"));
            assert_eq!(back, spec, "round-trip mismatch for {s:?} -> {shown:?}");
        }
    }

    #[test]
    fn json_roundtrips_documented_specs() {
        for s in DOCUMENTED {
            let spec = SolverSpec::parse(s).unwrap();
            let j = spec.to_json().to_string_compact();
            let back = SolverSpec::from_json(&Value::parse(&j).unwrap())
                .unwrap_or_else(|e| panic!("{j}: {e:#}"));
            assert_eq!(back, spec, "JSON round-trip mismatch for {s:?}");
        }
    }

    #[test]
    fn json_rejects_invalid_specs() {
        for j in [
            r#"{"kind":"rk","base":"rk2","n":0,"grid":"uniform"}"#,
            r#"{"kind":"dopri5","rtol":-1,"atol":1e-5,"max_steps":100}"#,
            r#"{"kind":"dopri5","rtol":1e-5,"atol":1e-5,"max_steps":0}"#,
            r#"{"kind":"bespoke","path":""}"#,
            r#"{"kind":"nope"}"#,
        ] {
            let v = Value::parse(j).unwrap();
            assert!(SolverSpec::from_json(&v).is_err(), "should reject {j}");
        }
    }

    #[test]
    fn aliases_canonicalize() {
        assert_eq!(
            SolverSpec::parse("euler:n=4").unwrap(),
            SolverSpec::parse("rk1:n=4").unwrap()
        );
        assert_eq!(
            SolverSpec::parse("midpoint:n=4").unwrap(),
            SolverSpec::parse("rk2:n=4").unwrap()
        );
        assert_eq!(SolverSpec::parse("euler:n=4").unwrap().to_string(), "rk1:n=4");
    }

    #[test]
    fn dopri5_tolerance_grammar() {
        // bare -> defaults
        match SolverSpec::parse("dopri5").unwrap() {
            SolverSpec::Dopri5 { rtol, atol, max_steps } => {
                assert_eq!(rtol, DOPRI5_DEFAULT_TOL);
                assert_eq!(atol, DOPRI5_DEFAULT_TOL);
                assert_eq!(max_steps, DOPRI5_DEFAULT_MAX_STEPS);
            }
            s => panic!("wrong spec {s:?}"),
        }
        // tol sets both; rtol/atol set independently and override tol
        match SolverSpec::parse("dopri5:tol=1e-4:atol=1e-7").unwrap() {
            SolverSpec::Dopri5 { rtol, atol, .. } => {
                assert_eq!(rtol, 1e-4);
                assert_eq!(atol, 1e-7);
            }
            s => panic!("wrong spec {s:?}"),
        }
    }

    #[test]
    fn strict_rejections() {
        for s in [
            "nope:n=4",            // unknown kind
            "rk2",                 // missing n
            "rk2:n=x",             // bad n value
            "rk2:n=0",             // zero steps
            "rk2:n=4:grid=nope",   // unknown grid
            "rk2:n=4:foo=1",       // unknown key
            "rk2:n",               // k without =
            "rk2:n=4:",            // empty trailing segment
            "rk2:=4",              // empty key
            "rk2:n=",              // empty value
            "rk2:n=4:n=8",         // duplicate key
            "rk2-target:n=4",      // missing sched
            "rk2-target:n=4:sched=nope",
            "dopri5:tol=-1",       // non-positive tol
            "dopri5:tol=abc",
            "dopri5:max_steps=0",
            "dopri5:n=4",          // key from another kind
            "bespoke",             // missing path and model
            "bespoke:model=m",     // registry form missing n
            "bespoke:model=m:n=0", // zero steps
            "bespoke:model=m:n=4:base=rk4",  // no rk4 bespoke base
            "bespoke:path=x:model=m:n=4",    // path and model are exclusive
            "bespoke:model=m:n=4:foo=1",     // unknown key
        ] {
            assert!(SolverSpec::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn registry_form_needs_resolution() {
        let spec = SolverSpec::parse("bespoke:model=m:n=4").unwrap();
        assert!(spec.needs_registry());
        assert_eq!(spec.kind(), "bespoke");
        let err = spec.build(Scheduler::CondOt).unwrap_err().to_string();
        assert!(err.contains("registry"), "unhelpful error: {err}");
        assert!(!SolverSpec::parse("bespoke:path=x.json").unwrap().needs_registry());
    }

    #[test]
    fn make_sampler_builds_every_buildable_kind() {
        let s = Scheduler::CondOt;
        for spec in [
            "rk1:n=4",
            "rk2:n=8:grid=edm",
            "rk2:n=8:grid=logsnr",
            "rk2:n=8:grid=cosine",
            "rk4:n=2",
            "rk2-target:n=4:sched=vp",
            "dopri5:tol=1e-4",
            "dopri5:rtol=1e-4:atol=1e-6",
            "dopri5",
        ] {
            let sampler = make_sampler(spec, s).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!sampler.name().is_empty());
        }
        for spec in ["nope:n=4", "rk2", "rk2:n=4:n=8", "bespoke:model=m:n=4"] {
            assert!(make_sampler(spec, s).is_err(), "should reject {spec}");
        }
    }

    #[test]
    fn make_sampler_bespoke_from_checkpoint() {
        let th = RawTheta::identity(Base::Rk2, 4);
        let dir = std::env::temp_dir().join(format!("bespoke_spec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("theta.json");
        th.save(&path).unwrap();
        let s = make_sampler(
            &format!("bespoke:path={}", path.display()),
            Scheduler::CondOt,
        )
        .unwrap();
        assert_eq!(s.nfe(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builds_non_checkpoint_kinds() {
        for s in DOCUMENTED {
            if s.starts_with("bespoke") {
                // needs a checkpoint on disk (covered above) or a registry
                continue;
            }
            let spec = SolverSpec::parse(s).unwrap();
            let sampler = spec
                .build(Scheduler::CondOt)
                .unwrap_or_else(|e| panic!("{s}: {e:#}"));
            assert!(!sampler.name().is_empty());
        }
    }

    #[test]
    fn built_sampler_name_matches_canonical_spec() {
        for s in ["rk2:n=8", "rk2:n=8:grid=edm", "rk1:n=4"] {
            let spec = SolverSpec::parse(s).unwrap();
            let sampler = spec.build(Scheduler::CondOt).unwrap();
            assert_eq!(sampler.name(), spec.to_string());
        }
    }
}
