//! Raw-theta codec for the Bespoke scale-time transform — the bit-exact
//! Rust mirror of `python/compile/theta.py` (paper eq. 74/76, Appendix F).
//!
//! Grid convention: base-RK1 n-step solvers use grid points i = 0..n
//! (g = n+1); base-RK2 uses i = 0, 1/2, 1, ..., n (g = 2n+1). Raw layout
//! (p = 4(g-1) floats):
//!
//! ```text
//! [ dt_raw (g-1) | tdot_raw (g-1) | log_s (g-1) | sdot (g-1) ]
//! ```

use anyhow::{bail, Result};

use crate::json::Value;

const EPS: f32 = 1e-6;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Base {
    Rk1,
    Rk2,
}

impl Base {
    pub fn parse(s: &str) -> Result<Base> {
        Ok(match s {
            "rk1" => Base::Rk1,
            "rk2" => Base::Rk2,
            _ => bail!("unknown base solver {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Base::Rk1 => "rk1",
            Base::Rk2 => "rk2",
        }
    }

    /// Grid points g for an n-step solver.
    pub fn grid_points(&self, n: usize) -> usize {
        match self {
            Base::Rk1 => n + 1,
            Base::Rk2 => 2 * n + 1,
        }
    }

    /// Model evaluations per step.
    pub fn evals_per_step(&self) -> usize {
        match self {
            Base::Rk1 => 1,
            Base::Rk2 => 2,
        }
    }
}

/// Raw learnable parameters of one Bespoke solver.
#[derive(Clone, Debug)]
pub struct RawTheta {
    pub base: Base,
    pub n: usize,
    pub raw: Vec<f32>,
}

/// Decoded grid sequences (paper notation): `t[g]`, `tdot[g-1]`, `s[g]`,
/// `sdot[g-1]`.
#[derive(Clone, Debug)]
pub struct DecodedTheta {
    pub base: Base,
    pub n: usize,
    pub t: Vec<f32>,
    pub tdot: Vec<f32>,
    pub s: Vec<f32>,
    pub sdot: Vec<f32>,
}

impl RawTheta {
    pub fn n_params(base: Base, n: usize) -> usize {
        4 * (base.grid_points(n) - 1)
    }

    /// Identity-transform initialization (paper eq. 77-80): the decoded
    /// Bespoke solver coincides with the plain base RK solver.
    pub fn identity(base: Base, n: usize) -> RawTheta {
        let m = base.grid_points(n) - 1;
        let mut raw = Vec::with_capacity(4 * m);
        raw.extend(std::iter::repeat(1.0f32).take(m)); // dt -> uniform grid
        raw.extend(std::iter::repeat(1.0f32 / m as f32).take(m)); // tdot -> 1
        raw.extend(std::iter::repeat(0.0f32).take(m)); // log_s -> s = 1
        raw.extend(std::iter::repeat(0.0f32).take(m)); // sdot -> 0
        RawTheta { base, n, raw }
    }

    pub fn from_raw(base: Base, n: usize, raw: Vec<f32>) -> Result<RawTheta> {
        if raw.len() != Self::n_params(base, n) {
            bail!(
                "theta length {} != expected {} for base={} n={n}",
                raw.len(),
                Self::n_params(base, n),
                base.name()
            );
        }
        Ok(RawTheta { base, n, raw })
    }

    /// Decode raw -> grid sequences (mirror of python `theta.decode`).
    pub fn decode(&self) -> DecodedTheta {
        let g = self.base.grid_points(self.n);
        let m = g - 1;
        let (dt_raw, rest) = self.raw.split_at(m);
        let (tdot_raw, rest) = rest.split_at(m);
        let (log_s, sdot) = rest.split_at(m);

        let mut t = Vec::with_capacity(g);
        t.push(0.0);
        let mut acc = 0.0f32;
        for &d in dt_raw {
            acc += d.abs() + EPS;
            t.push(acc);
        }
        let total = acc;
        for v in t.iter_mut() {
            *v /= total;
        }
        // exact endpoints
        t[0] = 0.0;
        t[m] = 1.0;

        let tdot: Vec<f32> = tdot_raw.iter().map(|v| (v.abs() + EPS) * m as f32).collect();
        let mut s = Vec::with_capacity(g);
        s.push(1.0);
        s.extend(log_s.iter().map(|v| v.exp()));
        DecodedTheta {
            base: self.base,
            n: self.n,
            t,
            tdot,
            s,
            sdot: sdot.to_vec(),
        }
    }

    // ---- gradient masks (paper Fig. 15 ablations) --------------------------

    /// Elementwise gradient mask: "full" | "time-only" | "scale-only".
    pub fn ablation_mask(base: Base, n: usize, mode: &str) -> Result<Vec<f32>> {
        let m = base.grid_points(n) - 1;
        let p = 4 * m;
        let mut mask = vec![1.0f32; p];
        match mode {
            "full" => {}
            "time-only" => mask[2 * m..].iter_mut().for_each(|v| *v = 0.0),
            "scale-only" => mask[..2 * m].iter_mut().for_each(|v| *v = 0.0),
            _ => bail!("unknown ablation mode {mode:?}"),
        }
        Ok(mask)
    }

    // ---- persistence --------------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("base", Value::Str(self.base.name().into())),
            ("n", Value::Num(self.n as f64)),
            ("raw", Value::from_f32s(&self.raw)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RawTheta> {
        let base = Base::parse(v.get("base")?.as_str()?)?;
        let n = v.get("n")?.as_usize()?;
        Self::from_raw(base, n, v.get("raw")?.as_f32_vec()?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<RawTheta> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }
}

impl DecodedTheta {
    /// Grid index of integer step i (RK2 grids interleave half steps).
    pub fn stride(&self) -> usize {
        match self.base {
            Base::Rk1 => 1,
            Base::Rk2 => 2,
        }
    }

    /// The integer-step times t_0..t_n — where GT snapshots are taken.
    pub fn step_times(&self) -> Vec<f32> {
        let k = self.stride();
        (0..=self.n).map(|i| self.t[k * i]).collect()
    }

    /// Lipschitz bound of the transformed field at grid point j (lemma D.1,
    /// L_tau = 1).
    pub fn l_ubar(&self, j: usize) -> f32 {
        self.sdot[j].abs() / self.s[j] + self.tdot[j]
    }

    /// L_i of step i (lemmas D.2 / D.3).
    pub fn lipschitz_step(&self, i: usize) -> f32 {
        let h = 1.0 / self.n as f32;
        match self.base {
            Base::Rk1 => (self.s[i] / self.s[i + 1]) * (1.0 + h * self.l_ubar(i)),
            Base::Rk2 => {
                let j = 2 * i;
                (self.s[j] / self.s[j + 2])
                    * (1.0 + h * self.l_ubar(j + 1) * (1.0 + 0.5 * h * self.l_ubar(j)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn identity_decodes_to_identity() {
        for (base, n) in [(Base::Rk1, 5), (Base::Rk2, 8)] {
            let dec = RawTheta::identity(base, n).decode();
            let g = base.grid_points(n);
            for (j, &tv) in dec.t.iter().enumerate() {
                let want = j as f32 / (g - 1) as f32;
                assert!((tv - want).abs() < 1e-5, "t[{j}]={tv} want {want}");
            }
            assert!(dec.tdot.iter().all(|&v| (v - 1.0).abs() < 1e-4));
            assert!(dec.s.iter().all(|&v| v == 1.0));
            assert!(dec.sdot.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn param_counts_match_paper_order() {
        assert_eq!(RawTheta::n_params(Base::Rk1, 5), 20); // 4n
        assert_eq!(RawTheta::n_params(Base::Rk2, 10), 80); // paper's "80 parameters"
    }

    #[test]
    fn decode_invariants_for_random_raw() {
        forall("theta-decode", 60, |rng, case| {
            let base = if case % 2 == 0 { Base::Rk1 } else { Base::Rk2 };
            let n = 2 + case % 11;
            let p = RawTheta::n_params(base, n);
            let raw: Vec<f32> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let dec = RawTheta::from_raw(base, n, raw).unwrap().decode();
            assert_eq!(dec.t[0], 0.0);
            assert_eq!(*dec.t.last().unwrap(), 1.0);
            for w in dec.t.windows(2) {
                assert!(w[1] > w[0], "t grid not strictly increasing");
            }
            assert!(dec.tdot.iter().all(|&v| v > 0.0));
            assert!(dec.s.iter().all(|&v| v > 0.0));
            assert_eq!(dec.s[0], 1.0);
            for i in 0..n {
                assert!(dec.lipschitz_step(i).is_finite());
            }
        });
    }

    #[test]
    fn identity_lipschitz_matches_closed_form() {
        let n = 6;
        let h = 1.0 / n as f32;
        let d1 = RawTheta::identity(Base::Rk1, n).decode();
        let d2 = RawTheta::identity(Base::Rk2, n).decode();
        for i in 0..n {
            assert!((d1.lipschitz_step(i) - (1.0 + h)).abs() < 1e-4);
            assert!((d2.lipschitz_step(i) - (1.0 + h * (1.0 + 0.5 * h))).abs() < 1e-4);
        }
    }

    #[test]
    fn json_roundtrip() {
        let th = RawTheta::identity(Base::Rk2, 4);
        let back = RawTheta::from_json(&th.to_json()).unwrap();
        assert_eq!(back.raw, th.raw);
        assert_eq!(back.base, Base::Rk2);
        assert_eq!(back.n, 4);
    }

    #[test]
    fn masks() {
        let m = RawTheta::ablation_mask(Base::Rk2, 4, "time-only").unwrap();
        let p = m.len();
        assert_eq!(m[..p / 2].iter().sum::<f32>(), (p / 2) as f32);
        assert_eq!(m[p / 2..].iter().sum::<f32>(), 0.0);
        assert!(RawTheta::ablation_mask(Base::Rk1, 4, "huh").is_err());
    }

    #[test]
    fn length_validation() {
        assert!(RawTheta::from_raw(Base::Rk1, 4, vec![0.0; 3]).is_err());
    }
}
