//! Raw-theta codec for the Bespoke scale-time transform — the bit-exact
//! Rust mirror of `python/compile/theta.py` (paper eq. 74/76, Appendix F) —
//! plus the non-stationary solver families layered on the same checkpoint
//! format (DESIGN.md §11).
//!
//! Stationary grid convention: base-RK1 n-step solvers use grid points
//! i = 0..n (g = n+1); base-RK2 uses i = 0, 1/2, 1, ..., n (g = 2n+1).
//! Raw layout (p = 4(g-1) floats):
//!
//! ```text
//! [ dt_raw (g-1) | tdot_raw (g-1) | log_s (g-1) | sdot (g-1) ]
//! ```
//!
//! Non-stationary layouts (uniform time grid t_i = i/n, coefficients only):
//!
//! ```text
//! bns/rk1:   [ a_0 b_0 | a_1 b_1 | ... ]                 (p = 2n)
//! bns/rk2:   [ a_0 b1_0 b2_0 | ... ]                     (p = 3n)
//! multistep: [ a_0 c_{0,0}..c_{0,W-1} | ... ]            (p = n(1+W))
//! ```
//!
//! On disk, stationary checkpoints serialize to exactly the legacy
//! `{base, n, raw}` object (byte-identical, so pre-family content hashes
//! re-verify); non-stationary checkpoints add a `"family"` key (and
//! `"window"` for multistep). A missing `family` reads as stationary.

use anyhow::{bail, Result};

use crate::json::Value;

const EPS: f32 = 1e-6;

/// Which solver family a theta parameterizes. The stationary family is the
/// paper's scale-time transform (one step transform reused at every step);
/// `Bns` holds independent per-step coefficients (arXiv 2403.01329) and
/// `Multistep` learned history-mixing coefficients (arXiv 2502.17423).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Family {
    Stationary,
    Bns,
    Multistep,
}

impl Family {
    pub fn parse(s: &str) -> Result<Family> {
        Ok(match s {
            "stationary" => Family::Stationary,
            "bns" => Family::Bns,
            "multistep" => Family::Multistep,
            _ => bail!("unknown solver family {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Family::Stationary => "stationary",
            Family::Bns => "bns",
            Family::Multistep => "multistep",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Base {
    Rk1,
    Rk2,
}

impl Base {
    pub fn parse(s: &str) -> Result<Base> {
        Ok(match s {
            "rk1" => Base::Rk1,
            "rk2" => Base::Rk2,
            _ => bail!("unknown base solver {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Base::Rk1 => "rk1",
            Base::Rk2 => "rk2",
        }
    }

    /// Grid points g for an n-step solver.
    pub fn grid_points(&self, n: usize) -> usize {
        match self {
            Base::Rk1 => n + 1,
            Base::Rk2 => 2 * n + 1,
        }
    }

    /// Model evaluations per step.
    pub fn evals_per_step(&self) -> usize {
        match self {
            Base::Rk1 => 1,
            Base::Rk2 => 2,
        }
    }
}

/// Raw learnable parameters of one trained solver. `family` selects the
/// layout of `raw`; `window` is the multistep history length W (0 for the
/// other families).
#[derive(Clone, Debug)]
pub struct RawTheta {
    pub base: Base,
    pub n: usize,
    pub raw: Vec<f32>,
    pub family: Family,
    pub window: usize,
}

/// Decoded grid sequences (paper notation): `t[g]`, `tdot[g-1]`, `s[g]`,
/// `sdot[g-1]`.
#[derive(Clone, Debug)]
pub struct DecodedTheta {
    pub base: Base,
    pub n: usize,
    pub t: Vec<f32>,
    pub tdot: Vec<f32>,
    pub s: Vec<f32>,
    pub sdot: Vec<f32>,
}

impl RawTheta {
    pub fn n_params(base: Base, n: usize) -> usize {
        4 * (base.grid_points(n) - 1)
    }

    /// Parameter count for any family. `window` is only consulted for
    /// multistep; multistep requires `base == rk1` and `window >= 1`.
    pub fn n_params_for(family: Family, base: Base, n: usize, window: usize) -> Result<usize> {
        Ok(match family {
            Family::Stationary => Self::n_params(base, n),
            Family::Bns => (1 + base.evals_per_step()) * n,
            Family::Multistep => {
                if base != Base::Rk1 {
                    bail!("multistep thetas require base=rk1 (got {})", base.name());
                }
                if window == 0 {
                    bail!("multistep thetas require window >= 1");
                }
                n * (1 + window)
            }
        })
    }

    /// Identity-transform initialization (paper eq. 77-80): the decoded
    /// Bespoke solver coincides with the plain base RK solver.
    pub fn identity(base: Base, n: usize) -> RawTheta {
        let m = base.grid_points(n) - 1;
        let mut raw = Vec::with_capacity(4 * m);
        raw.extend(std::iter::repeat(1.0f32).take(m)); // dt -> uniform grid
        raw.extend(std::iter::repeat(1.0f32 / m as f32).take(m)); // tdot -> 1
        raw.extend(std::iter::repeat(0.0f32).take(m)); // log_s -> s = 1
        raw.extend(std::iter::repeat(0.0f32).take(m)); // sdot -> 0
        RawTheta { base, n, raw, family: Family::Stationary, window: 0 }
    }

    /// Identity initialization for any family: the solver coincides with
    /// the plain base RK solver (bns: a=1 plus the base's own stage
    /// weights; multistep: a=1, c_{i,0}=1, older history 0 — Euler).
    pub fn identity_for(family: Family, base: Base, n: usize, window: usize) -> Result<RawTheta> {
        let p = Self::n_params_for(family, base, n, window)?;
        Ok(match family {
            Family::Stationary => Self::identity(base, n),
            Family::Bns => {
                let mut raw = Vec::with_capacity(p);
                for _ in 0..n {
                    match base {
                        Base::Rk1 => raw.extend_from_slice(&[1.0, 1.0]), // a, b
                        Base::Rk2 => raw.extend_from_slice(&[1.0, 0.0, 1.0]), // a, b1, b2
                    }
                }
                RawTheta { base, n, raw, family, window: 0 }
            }
            Family::Multistep => {
                let mut raw = Vec::with_capacity(p);
                for _ in 0..n {
                    raw.push(1.0); // a
                    raw.push(1.0); // c_{i,0}
                    raw.extend(std::iter::repeat(0.0f32).take(window - 1));
                }
                RawTheta { base, n, raw, family, window }
            }
        })
    }

    pub fn from_raw(base: Base, n: usize, raw: Vec<f32>) -> Result<RawTheta> {
        if raw.len() != Self::n_params(base, n) {
            bail!(
                "theta length {} != expected {} for base={} n={n}",
                raw.len(),
                Self::n_params(base, n),
                base.name()
            );
        }
        Ok(RawTheta { base, n, raw, family: Family::Stationary, window: 0 })
    }

    /// [`RawTheta::from_raw`] for any family, with the family's own length
    /// validation.
    pub fn from_raw_for(
        family: Family,
        base: Base,
        n: usize,
        window: usize,
        raw: Vec<f32>,
    ) -> Result<RawTheta> {
        let p = Self::n_params_for(family, base, n, window)?;
        if raw.len() != p {
            bail!(
                "theta length {} != expected {p} for family={} base={} n={n}",
                raw.len(),
                family.name(),
                base.name()
            );
        }
        let window = if family == Family::Multistep { window } else { 0 };
        Ok(RawTheta { base, n, raw, family, window })
    }

    /// Decode raw -> grid sequences (mirror of python `theta.decode`).
    /// Only the stationary layout decodes to scale-time grids; the
    /// non-stationary families consume `raw` directly in their steppers.
    pub fn decode(&self) -> DecodedTheta {
        assert_eq!(
            self.family,
            Family::Stationary,
            "decode() is only defined for stationary thetas (got {})",
            self.family.name()
        );
        let g = self.base.grid_points(self.n);
        let m = g - 1;
        let (dt_raw, rest) = self.raw.split_at(m);
        let (tdot_raw, rest) = rest.split_at(m);
        let (log_s, sdot) = rest.split_at(m);

        let mut t = Vec::with_capacity(g);
        t.push(0.0);
        let mut acc = 0.0f32;
        for &d in dt_raw {
            acc += d.abs() + EPS;
            t.push(acc);
        }
        let total = acc;
        for v in t.iter_mut() {
            *v /= total;
        }
        // exact endpoints
        t[0] = 0.0;
        t[m] = 1.0;

        let tdot: Vec<f32> = tdot_raw.iter().map(|v| (v.abs() + EPS) * m as f32).collect();
        let mut s = Vec::with_capacity(g);
        s.push(1.0);
        s.extend(log_s.iter().map(|v| v.exp()));
        DecodedTheta {
            base: self.base,
            n: self.n,
            t,
            tdot,
            s,
            sdot: sdot.to_vec(),
        }
    }

    // ---- gradient masks (paper Fig. 15 ablations) --------------------------

    /// Elementwise gradient mask: "full" | "time-only" | "scale-only".
    pub fn ablation_mask(base: Base, n: usize, mode: &str) -> Result<Vec<f32>> {
        let m = base.grid_points(n) - 1;
        let p = 4 * m;
        let mut mask = vec![1.0f32; p];
        match mode {
            "full" => {}
            "time-only" => mask[2 * m..].iter_mut().for_each(|v| *v = 0.0),
            "scale-only" => mask[..2 * m].iter_mut().for_each(|v| *v = 0.0),
            _ => bail!("unknown ablation mode {mode:?}"),
        }
        Ok(mask)
    }

    // ---- persistence --------------------------------------------------------

    /// Stationary thetas serialize to exactly the legacy `{base, n, raw}`
    /// object — byte-identical to pre-family checkpoints, so registry
    /// content hashes of old artifacts keep verifying. Non-stationary
    /// thetas add `"family"` (and `"window"` for multistep).
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("base", Value::Str(self.base.name().into())),
            ("n", Value::Num(self.n as f64)),
        ];
        if self.family != Family::Stationary {
            fields.push(("family", Value::Str(self.family.name().into())));
        }
        if self.family == Family::Multistep {
            fields.push(("window", Value::Num(self.window as f64)));
        }
        fields.push(("raw", Value::from_f32s(&self.raw)));
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<RawTheta> {
        let base = Base::parse(v.get("base")?.as_str()?)?;
        let n = v.get("n")?.as_usize()?;
        let family = match v.get_opt("family") {
            Some(f) => Family::parse(f.as_str()?)?,
            None => Family::Stationary,
        };
        let window = match v.get_opt("window") {
            Some(w) => {
                if family != Family::Multistep {
                    bail!("theta key \"window\" is only valid for family=multistep");
                }
                w.as_usize()?
            }
            None => {
                if family == Family::Multistep {
                    bail!("multistep theta is missing required key \"window\"");
                }
                0
            }
        };
        Self::from_raw_for(family, base, n, window, v.get("raw")?.as_f32_vec()?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<RawTheta> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }
}

impl DecodedTheta {
    /// Grid index of integer step i (RK2 grids interleave half steps).
    pub fn stride(&self) -> usize {
        match self.base {
            Base::Rk1 => 1,
            Base::Rk2 => 2,
        }
    }

    /// The integer-step times t_0..t_n — where GT snapshots are taken.
    pub fn step_times(&self) -> Vec<f32> {
        let k = self.stride();
        (0..=self.n).map(|i| self.t[k * i]).collect()
    }

    /// Lipschitz bound of the transformed field at grid point j (lemma D.1,
    /// L_tau = 1).
    pub fn l_ubar(&self, j: usize) -> f32 {
        self.sdot[j].abs() / self.s[j] + self.tdot[j]
    }

    /// L_i of step i (lemmas D.2 / D.3).
    pub fn lipschitz_step(&self, i: usize) -> f32 {
        let h = 1.0 / self.n as f32;
        match self.base {
            Base::Rk1 => (self.s[i] / self.s[i + 1]) * (1.0 + h * self.l_ubar(i)),
            Base::Rk2 => {
                let j = 2 * i;
                (self.s[j] / self.s[j + 2])
                    * (1.0 + h * self.l_ubar(j + 1) * (1.0 + 0.5 * h * self.l_ubar(j)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn identity_decodes_to_identity() {
        for (base, n) in [(Base::Rk1, 5), (Base::Rk2, 8)] {
            let dec = RawTheta::identity(base, n).decode();
            let g = base.grid_points(n);
            for (j, &tv) in dec.t.iter().enumerate() {
                let want = j as f32 / (g - 1) as f32;
                assert!((tv - want).abs() < 1e-5, "t[{j}]={tv} want {want}");
            }
            assert!(dec.tdot.iter().all(|&v| (v - 1.0).abs() < 1e-4));
            assert!(dec.s.iter().all(|&v| v == 1.0));
            assert!(dec.sdot.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn param_counts_match_paper_order() {
        assert_eq!(RawTheta::n_params(Base::Rk1, 5), 20); // 4n
        assert_eq!(RawTheta::n_params(Base::Rk2, 10), 80); // paper's "80 parameters"
    }

    #[test]
    fn decode_invariants_for_random_raw() {
        forall("theta-decode", 60, |rng, case| {
            let base = if case % 2 == 0 { Base::Rk1 } else { Base::Rk2 };
            let n = 2 + case % 11;
            let p = RawTheta::n_params(base, n);
            let raw: Vec<f32> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let dec = RawTheta::from_raw(base, n, raw).unwrap().decode();
            assert_eq!(dec.t[0], 0.0);
            assert_eq!(*dec.t.last().unwrap(), 1.0);
            for w in dec.t.windows(2) {
                assert!(w[1] > w[0], "t grid not strictly increasing");
            }
            assert!(dec.tdot.iter().all(|&v| v > 0.0));
            assert!(dec.s.iter().all(|&v| v > 0.0));
            assert_eq!(dec.s[0], 1.0);
            for i in 0..n {
                assert!(dec.lipschitz_step(i).is_finite());
            }
        });
    }

    #[test]
    fn identity_lipschitz_matches_closed_form() {
        let n = 6;
        let h = 1.0 / n as f32;
        let d1 = RawTheta::identity(Base::Rk1, n).decode();
        let d2 = RawTheta::identity(Base::Rk2, n).decode();
        for i in 0..n {
            assert!((d1.lipschitz_step(i) - (1.0 + h)).abs() < 1e-4);
            assert!((d2.lipschitz_step(i) - (1.0 + h * (1.0 + 0.5 * h))).abs() < 1e-4);
        }
    }

    #[test]
    fn json_roundtrip() {
        let th = RawTheta::identity(Base::Rk2, 4);
        let back = RawTheta::from_json(&th.to_json()).unwrap();
        assert_eq!(back.raw, th.raw);
        assert_eq!(back.base, Base::Rk2);
        assert_eq!(back.n, 4);
    }

    #[test]
    fn masks() {
        let m = RawTheta::ablation_mask(Base::Rk2, 4, "time-only").unwrap();
        let p = m.len();
        assert_eq!(m[..p / 2].iter().sum::<f32>(), (p / 2) as f32);
        assert_eq!(m[p / 2..].iter().sum::<f32>(), 0.0);
        assert!(RawTheta::ablation_mask(Base::Rk1, 4, "huh").is_err());
    }

    #[test]
    fn length_validation() {
        assert!(RawTheta::from_raw(Base::Rk1, 4, vec![0.0; 3]).is_err());
        assert!(RawTheta::from_raw_for(Family::Bns, Base::Rk2, 4, 0, vec![0.0; 11]).is_err());
        assert!(
            RawTheta::from_raw_for(Family::Multistep, Base::Rk1, 4, 2, vec![0.0; 11]).is_err()
        );
    }

    #[test]
    fn family_param_counts() {
        assert_eq!(RawTheta::n_params_for(Family::Stationary, Base::Rk2, 10, 0).unwrap(), 80);
        assert_eq!(RawTheta::n_params_for(Family::Bns, Base::Rk1, 6, 0).unwrap(), 12);
        assert_eq!(RawTheta::n_params_for(Family::Bns, Base::Rk2, 6, 0).unwrap(), 18);
        assert_eq!(RawTheta::n_params_for(Family::Multistep, Base::Rk1, 6, 3).unwrap(), 24);
        // multistep is rk1-only and needs a window
        assert!(RawTheta::n_params_for(Family::Multistep, Base::Rk2, 6, 3).is_err());
        assert!(RawTheta::n_params_for(Family::Multistep, Base::Rk1, 6, 0).is_err());
    }

    #[test]
    fn stationary_json_is_byte_identical_to_legacy() {
        // the exact serialized form old registries hashed: {base, n, raw}
        let th = RawTheta::identity(Base::Rk1, 2);
        let text = th.to_json().to_string_compact();
        assert!(!text.contains("family"), "{text}");
        assert!(!text.contains("window"), "{text}");
        let legacy = Value::obj(vec![
            ("base", Value::Str("rk1".into())),
            ("n", Value::Num(2.0)),
            ("raw", Value::from_f32s(&th.raw)),
        ]);
        assert_eq!(text, legacy.to_string_compact());
        // and a legacy object (no family key) reads back as stationary
        let back = RawTheta::from_json(&legacy).unwrap();
        assert_eq!(back.family, Family::Stationary);
        assert_eq!(back.window, 0);
    }

    #[test]
    fn family_json_roundtrips() {
        for th in [
            RawTheta::identity_for(Family::Bns, Base::Rk1, 5, 0).unwrap(),
            RawTheta::identity_for(Family::Bns, Base::Rk2, 3, 0).unwrap(),
            RawTheta::identity_for(Family::Multistep, Base::Rk1, 6, 3).unwrap(),
        ] {
            let back = RawTheta::from_json(&th.to_json()).unwrap();
            assert_eq!(back.family, th.family);
            assert_eq!(back.base, th.base);
            assert_eq!(back.n, th.n);
            assert_eq!(back.window, th.window);
            assert_eq!(back.raw, th.raw);
        }
    }

    #[test]
    fn json_rejects_bad_family_and_window() {
        let good = RawTheta::identity_for(Family::Bns, Base::Rk1, 4, 0).unwrap().to_json();
        let with = |key: &str, val: Value| match &good {
            Value::Obj(map) => {
                let mut map = map.clone();
                map.insert(key.to_string(), val);
                Value::Obj(map)
            }
            _ => unreachable!(),
        };
        // unknown family string errors (never panics)
        assert!(RawTheta::from_json(&with("family", Value::Str("quantum".into()))).is_err());
        // window on a non-multistep family errors
        assert!(RawTheta::from_json(&with("window", Value::Num(2.0))).is_err());
        // multistep without window errors
        let ms = RawTheta::identity_for(Family::Multistep, Base::Rk1, 4, 2).unwrap().to_json();
        let stripped = match &ms {
            Value::Obj(map) => {
                let mut map = map.clone();
                map.remove("window");
                Value::Obj(map)
            }
            _ => unreachable!(),
        };
        assert!(RawTheta::from_json(&stripped).is_err());
    }

    #[test]
    #[should_panic(expected = "only defined for stationary")]
    fn decode_rejects_non_stationary() {
        let th = RawTheta::identity_for(Family::Bns, Base::Rk1, 4, 0).unwrap();
        let _ = th.decode();
    }
}
