//! Heuristic time grids — the "non-uniform time steps" family of dedicated
//! solvers (Karras et al. 2022 and the DDIM log-SNR spacing), expressed as
//! warps of the model's own time axis.
//!
//! Each grid maps n steps to n+1 times in [0, 1]. Combined with
//! [`super::rk::FixedGridSolver`] these reproduce the paper's dedicated-
//! solver baselines that only re-space time (the scale component is handled
//! by [`super::transfer`]).

use anyhow::{bail, Result};

use crate::schedulers::{edm_sigma, Scheduler};

/// The warped-grid family, as a typed enum (see [`super::spec::SolverSpec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridKind {
    Uniform,
    Edm,
    Cosine,
    LogSnr,
}

impl GridKind {
    pub fn parse(name: &str) -> Result<GridKind> {
        Ok(match name {
            "uniform" => GridKind::Uniform,
            "edm" => GridKind::Edm,
            "cosine" => GridKind::Cosine,
            "logsnr" => GridKind::LogSnr,
            _ => bail!("unknown grid {name:?} (uniform|edm|cosine|logsnr)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            GridKind::Uniform => "uniform",
            GridKind::Edm => "edm",
            GridKind::Cosine => "cosine",
            GridKind::LogSnr => "logsnr",
        }
    }

    /// Materialize the n-step grid (n+1 times in [0, 1]).
    pub fn build(&self, n: usize, sched: Scheduler) -> Vec<f32> {
        match self {
            GridKind::Uniform => uniform(n),
            GridKind::Edm => edm(n, sched),
            GridKind::Cosine => cosine(n),
            GridKind::LogSnr => log_snr(n, sched),
        }
    }
}

/// Uniform grid t_i = i / n.
pub fn uniform(n: usize) -> Vec<f32> {
    (0..=n).map(|i| i as f32 / n as f32).collect()
}

/// EDM rho-grid (Karras et al. 2022, rho = 7): the sigma ladder
/// sigma_i = (A + i/n (B - A))^rho mapped onto the model's time axis by
/// SNR matching: t_i = snr^-1(1 / sigma_i).
pub fn edm(n: usize, sched: Scheduler) -> Vec<f32> {
    let mut g: Vec<f32> = (0..=n)
        .map(|i| {
            let r = i as f64 / n as f64;
            let sigma = edm_sigma(r);
            sched.snr_inverse(1.0 / sigma) as f32
        })
        .collect();
    // snr matching can saturate at the ends; pin the boundary conditions.
    g[0] = 0.0;
    g[n] = 1.0;
    g
}

/// Cosine-warped grid: denser steps near t = 1 where flow paths curve
/// hardest for OT schedules.
pub fn cosine(n: usize) -> Vec<f32> {
    (0..=n)
        .map(|i| {
            let r = i as f32 / n as f32;
            1.0 - (std::f32::consts::FRAC_PI_2 * r).cos()
        })
        .collect()
}

/// Uniform in log-SNR (the DDIM/DPM-solver spacing): lambda_i linear
/// between lambda(t_lo) and lambda(t_hi), mapped back through snr^-1.
pub fn log_snr(n: usize, sched: Scheduler) -> Vec<f32> {
    let t_lo = 1e-3;
    let t_hi = 1.0 - 1e-3;
    let l_lo = sched.log_snr(t_lo);
    let l_hi = sched.log_snr(t_hi);
    let mut g: Vec<f32> = (0..=n)
        .map(|i| {
            let l = l_lo + (l_hi - l_lo) * i as f64 / n as f64;
            sched.snr_inverse(l.exp()) as f32
        })
        .collect();
    g[0] = 0.0;
    g[n] = 1.0;
    g
}

/// Parse a grid spec name and materialize it.
pub fn make(name: &str, n: usize, sched: Scheduler) -> Result<Vec<f32>> {
    Ok(GridKind::parse(name)?.build(n, sched))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(g: &[f32], n: usize) {
        assert_eq!(g.len(), n + 1);
        assert_eq!(g[0], 0.0);
        assert_eq!(g[n], 1.0);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid not strictly increasing: {g:?}");
        }
    }

    #[test]
    fn all_grids_valid_for_all_schedulers() {
        for sched in [Scheduler::CondOt, Scheduler::Cosine, Scheduler::VarPres] {
            for name in ["uniform", "edm", "cosine", "logsnr"] {
                for n in [4, 8, 20] {
                    check(&make(name, n, sched).unwrap(), n);
                }
            }
        }
    }

    #[test]
    fn edm_grid_denser_near_data_end() {
        // EDM spends most steps at low sigma (high t).
        let g = edm(10, Scheduler::CondOt);
        let first = g[1] - g[0];
        let last = g[10] - g[9];
        assert!(last < first, "expected fine steps near t=1: {g:?}");
    }

    #[test]
    fn unknown_grid_rejected() {
        assert!(make("nope", 4, Scheduler::CondOt).is_err());
        assert!(GridKind::parse("nope").is_err());
    }

    #[test]
    fn grid_kind_name_roundtrip() {
        for k in [GridKind::Uniform, GridKind::Edm, GridKind::Cosine, GridKind::LogSnr] {
            assert_eq!(GridKind::parse(k.name()).unwrap(), k);
        }
    }
}
