//! Fixed-grid Runge–Kutta samplers over arbitrary (possibly warped) time
//! grids: the paper's generic baselines RK1 (Euler), RK2 (midpoint) and RK4,
//! plus the shared [`solve`] driver (paper Algorithm 1).

use anyhow::{bail, Result};

use super::Sampler;
use crate::models::VelocityModel;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaseRk {
    Rk1,
    Rk2,
    Rk4,
}

impl BaseRk {
    pub fn parse(s: &str) -> Result<BaseRk> {
        Ok(match s {
            "rk1" | "euler" => BaseRk::Rk1,
            "rk2" | "midpoint" => BaseRk::Rk2,
            "rk4" => BaseRk::Rk4,
            _ => bail!("unknown RK method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaseRk::Rk1 => "rk1",
            BaseRk::Rk2 => "rk2",
            BaseRk::Rk4 => "rk4",
        }
    }

    pub fn evals_per_step(&self) -> usize {
        match self {
            BaseRk::Rk1 => 1,
            BaseRk::Rk2 => 2,
            BaseRk::Rk4 => 4,
        }
    }

    /// One step x(t) -> x(t + h) of the classic method against a generic
    /// vector field `f(x, t)`.
    pub fn step(
        &self,
        f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
        x: &Tensor,
        t: f32,
        h: f32,
    ) -> Result<Tensor> {
        match self {
            BaseRk::Rk1 => {
                let k1 = f(x, t)?;
                let mut out = x.clone();
                out.axpy(h, &k1)?;
                Ok(out)
            }
            BaseRk::Rk2 => {
                let k1 = f(x, t)?;
                let mut mid = x.clone();
                mid.axpy(0.5 * h, &k1)?;
                let k2 = f(&mid, t + 0.5 * h)?;
                let mut out = x.clone();
                out.axpy(h, &k2)?;
                Ok(out)
            }
            BaseRk::Rk4 => {
                let k1 = f(x, t)?;
                let mut x2 = x.clone();
                x2.axpy(0.5 * h, &k1)?;
                let k2 = f(&x2, t + 0.5 * h)?;
                let mut x3 = x.clone();
                x3.axpy(0.5 * h, &k2)?;
                let k3 = f(&x3, t + 0.5 * h)?;
                let mut x4 = x.clone();
                x4.axpy(h, &k3)?;
                let k4 = f(&x4, t + h)?;
                let mut out = x.clone();
                out.axpy(h / 6.0, &k1)?;
                out.axpy(h / 3.0, &k2)?;
                out.axpy(h / 3.0, &k3)?;
                out.axpy(h / 6.0, &k4)?;
                Ok(out)
            }
        }
    }
}

/// Algorithm 1: iterate `step` over a time grid.
pub fn solve(
    base: BaseRk,
    f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
    x0: &Tensor,
    grid: &[f32],
) -> Result<Tensor> {
    if grid.len() < 2 {
        bail!("time grid needs at least 2 points");
    }
    let mut x = x0.clone();
    for w in grid.windows(2) {
        let (t, tn) = (w[0], w[1]);
        x = base.step(f, &x, t, tn - t)?;
    }
    Ok(x)
}

/// A fixed-grid sampler on the *original* (untransformed) path: the plain
/// RK1/RK2/RK4 baselines, optionally on a warped time grid (see `grids`).
pub struct FixedGridSolver {
    pub base: BaseRk,
    pub grid: Vec<f32>,
    pub label: String,
}

impl FixedGridSolver {
    pub fn uniform(base: BaseRk, n: usize) -> FixedGridSolver {
        let grid = (0..=n).map(|i| i as f32 / n as f32).collect();
        FixedGridSolver { base, grid, label: format!("{}:n={n}", base.name()) }
    }

    pub fn with_grid(base: BaseRk, grid: Vec<f32>, label: impl Into<String>) -> FixedGridSolver {
        FixedGridSolver { base, grid, label: label.into() }
    }
}

impl Sampler for FixedGridSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.base.evals_per_step()
    }

    fn sample(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor> {
        let mut f = |x: &Tensor, t: f32| model.eval(x, t);
        solve(self.base, &mut f, x0, &self.grid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x' = a x solved exactly: x(1) = e^a x(0); check convergence order.
    fn order_of(base: BaseRk) -> f32 {
        let a = -1.3f32;
        let x0 = Tensor::new(vec![1.0], vec![1, 1]).unwrap();
        let exact = (a).exp();
        let err = |n: usize| {
            let mut f = |x: &Tensor, _t: f32| Ok(x.scale(a));
            let grid: Vec<f32> = (0..=n).map(|i| i as f32 / n as f32).collect();
            let x1 = solve(base, &mut f, &x0, &grid).unwrap();
            (x1.data()[0] - exact).abs()
        };
        let (e1, e2) = (err(8), err(16));
        (e1 / e2).log2()
    }

    #[test]
    fn empirical_convergence_orders() {
        assert!((order_of(BaseRk::Rk1) - 1.0).abs() < 0.2);
        assert!((order_of(BaseRk::Rk2) - 2.0).abs() < 0.2);
        assert!((order_of(BaseRk::Rk4) - 4.0).abs() < 0.5);
    }

    #[test]
    fn nonuniform_grid_reaches_endpoint() {
        // x' = 1: x(1) = x(0) + 1 regardless of the grid.
        let x0 = Tensor::new(vec![0.0, 2.0], vec![1, 2]).unwrap();
        let mut f = |x: &Tensor, _t: f32| Ok(Tensor::full(x.shape(), 1.0));
        let grid = vec![0.0, 0.07, 0.5, 0.51, 1.0];
        for base in [BaseRk::Rk1, BaseRk::Rk2, BaseRk::Rk4] {
            let x1 = solve(base, &mut f, &x0, &grid).unwrap();
            assert!((x1.data()[0] - 1.0).abs() < 1e-6);
            assert!((x1.data()[1] - 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nfe_accounting() {
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk1, 10).nfe(), 10);
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk2, 10).nfe(), 20);
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk4, 5).nfe(), 20);
    }

    #[test]
    fn short_grid_rejected() {
        let x0 = Tensor::zeros(&[1, 1]);
        let mut f = |x: &Tensor, _t: f32| Ok(x.clone());
        assert!(solve(BaseRk::Rk1, &mut f, &x0, &[0.0]).is_err());
    }
}
