//! Fixed-grid Runge–Kutta samplers over arbitrary (possibly warped) time
//! grids: the paper's generic baselines RK1 (Euler), RK2 (midpoint) and RK4,
//! plus the shared [`solve`] driver (paper Algorithm 1).

use anyhow::{bail, Result};

use super::{Sampler, SolveSession, StepInfo};
use crate::models::VelocityModel;
use crate::tensor::{Tensor, Workspace};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaseRk {
    Rk1,
    Rk2,
    Rk4,
}

impl BaseRk {
    pub fn parse(s: &str) -> Result<BaseRk> {
        Ok(match s {
            "rk1" | "euler" => BaseRk::Rk1,
            "rk2" | "midpoint" => BaseRk::Rk2,
            "rk4" => BaseRk::Rk4,
            _ => bail!("unknown RK method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaseRk::Rk1 => "rk1",
            BaseRk::Rk2 => "rk2",
            BaseRk::Rk4 => "rk4",
        }
    }

    pub fn evals_per_step(&self) -> usize {
        match self {
            BaseRk::Rk1 => 1,
            BaseRk::Rk2 => 2,
            BaseRk::Rk4 => 4,
        }
    }

    /// Stage buffers [`BaseRk::step_into`] acquires from its workspace per
    /// step (sessions pre-fill the pool with exactly this many in `begin`).
    pub fn stage_buffers(&self) -> usize {
        match self {
            BaseRk::Rk1 => 1,
            BaseRk::Rk2 => 2,
            BaseRk::Rk4 => 5,
        }
    }

    /// One step x(t) -> x(t + h) computed **in place** against a write-into
    /// vector field `f(x, t, out)`, with all stage storage drawn from (and
    /// returned to) `ws`: zero heap allocation once the pool is warm. The
    /// arithmetic is element-for-element identical to [`BaseRk::step`], so
    /// swapping the paths is bitwise neutral (pinned by tests).
    pub fn step_into(
        &self,
        f: &mut dyn FnMut(&Tensor, f32, &mut Tensor) -> Result<()>,
        x: &mut Tensor,
        t: f32,
        h: f32,
        ws: &mut Workspace,
    ) -> Result<()> {
        match self {
            BaseRk::Rk1 => {
                let mut k1 = ws.acquire(x.shape());
                f(x, t, &mut k1)?;
                x.axpy(h, &k1)?;
                ws.release(k1);
            }
            BaseRk::Rk2 => {
                let mut k = ws.acquire(x.shape());
                f(x, t, &mut k)?;
                let mut mid = ws.acquire(x.shape());
                mid.copy_from(x)?;
                mid.axpy(0.5 * h, &k)?;
                f(&mid, t + 0.5 * h, &mut k)?; // k now holds k2
                x.axpy(h, &k)?;
                ws.release(mid);
                ws.release(k);
            }
            BaseRk::Rk4 => {
                let mut k1 = ws.acquire(x.shape());
                f(x, t, &mut k1)?;
                let mut xs = ws.acquire(x.shape());
                xs.copy_from(x)?;
                xs.axpy(0.5 * h, &k1)?;
                let mut k2 = ws.acquire(x.shape());
                f(&xs, t + 0.5 * h, &mut k2)?;
                xs.copy_from(x)?;
                xs.axpy(0.5 * h, &k2)?;
                let mut k3 = ws.acquire(x.shape());
                f(&xs, t + 0.5 * h, &mut k3)?;
                xs.copy_from(x)?;
                xs.axpy(h, &k3)?;
                let mut k4 = ws.acquire(x.shape());
                f(&xs, t + h, &mut k4)?;
                x.axpy(h / 6.0, &k1)?;
                x.axpy(h / 3.0, &k2)?;
                x.axpy(h / 3.0, &k3)?;
                x.axpy(h / 6.0, &k4)?;
                for buf in [k1, k2, k3, k4, xs] {
                    ws.release(buf);
                }
            }
        }
        Ok(())
    }

    /// One step x(t) -> x(t + h) of the classic method against a generic
    /// vector field `f(x, t)`. Clone-per-stage reference path; the hot loop
    /// uses [`BaseRk::step_into`].
    pub fn step(
        &self,
        f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
        x: &Tensor,
        t: f32,
        h: f32,
    ) -> Result<Tensor> {
        match self {
            BaseRk::Rk1 => {
                let k1 = f(x, t)?;
                let mut out = x.clone();
                out.axpy(h, &k1)?;
                Ok(out)
            }
            BaseRk::Rk2 => {
                let k1 = f(x, t)?;
                let mut mid = x.clone();
                mid.axpy(0.5 * h, &k1)?;
                let k2 = f(&mid, t + 0.5 * h)?;
                let mut out = x.clone();
                out.axpy(h, &k2)?;
                Ok(out)
            }
            BaseRk::Rk4 => {
                let k1 = f(x, t)?;
                let mut x2 = x.clone();
                x2.axpy(0.5 * h, &k1)?;
                let k2 = f(&x2, t + 0.5 * h)?;
                let mut x3 = x.clone();
                x3.axpy(0.5 * h, &k2)?;
                let k3 = f(&x3, t + 0.5 * h)?;
                let mut x4 = x.clone();
                x4.axpy(h, &k3)?;
                let k4 = f(&x4, t + h)?;
                let mut out = x.clone();
                out.axpy(h / 6.0, &k1)?;
                out.axpy(h / 3.0, &k2)?;
                out.axpy(h / 3.0, &k3)?;
                out.axpy(h / 6.0, &k4)?;
                Ok(out)
            }
        }
    }
}

/// Algorithm 1: iterate `step` over a time grid.
pub fn solve(
    base: BaseRk,
    f: &mut dyn FnMut(&Tensor, f32) -> Result<Tensor>,
    x0: &Tensor,
    grid: &[f32],
) -> Result<Tensor> {
    if grid.len() < 2 {
        bail!("time grid needs at least 2 points");
    }
    let mut x = x0.clone();
    for w in grid.windows(2) {
        let (t, tn) = (w[0], w[1]);
        x = base.step(f, &x, t, tn - t)?;
    }
    Ok(x)
}

/// A fixed-grid sampler on the *original* (untransformed) path: the plain
/// RK1/RK2/RK4 baselines, optionally on a warped time grid (see `grids`).
pub struct FixedGridSolver {
    pub base: BaseRk,
    pub grid: Vec<f32>,
    pub label: String,
}

impl FixedGridSolver {
    pub fn uniform(base: BaseRk, n: usize) -> FixedGridSolver {
        let grid = (0..=n).map(|i| i as f32 / n as f32).collect();
        FixedGridSolver { base, grid, label: format!("{}:n={n}", base.name()) }
    }

    pub fn with_grid(base: BaseRk, grid: Vec<f32>, label: impl Into<String>) -> FixedGridSolver {
        FixedGridSolver { base, grid, label: label.into() }
    }
}

/// Step-wise execution of a [`FixedGridSolver`]: one grid interval per
/// [`SolveSession::step`], arithmetic identical to the one-shot [`solve`].
/// Stage buffers are pre-allocated in [`Sampler::begin`] and recycled
/// through the session's [`Workspace`], so the step loop performs zero
/// heap allocation (pinned by `rust/tests/alloc_free.rs`).
pub struct FixedGridSession<'a> {
    solver: &'a FixedGridSolver,
    x: Tensor,
    /// Index of the next grid interval [grid[i], grid[i+1]] to integrate.
    i: usize,
    ws: Workspace,
}

impl SolveSession for FixedGridSession<'_> {
    fn init(&mut self, x0: &Tensor) -> Result<()> {
        if self.x.shape() == x0.shape() {
            self.x.copy_from(x0)?;
        } else {
            // Batch-width-agnostic re-init: keep the pool and top it up for
            // the new shape, so a session hopping between fused widths
            // allocates each width's stage buffers once (DESIGN.md §10).
            self.x = x0.clone();
            self.ws.ensure(x0.shape(), self.solver.base.stage_buffers());
        }
        self.i = 0;
        Ok(())
    }

    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo> {
        if self.is_done() {
            bail!("session already complete ({} steps)", self.i);
        }
        let (t, tn) = (self.solver.grid[self.i], self.solver.grid[self.i + 1]);
        let mut f = |x: &Tensor, t: f32, out: &mut Tensor| model.eval_into(x, t, out);
        self.solver.base.step_into(&mut f, &mut self.x, t, tn - t, &mut self.ws)?;
        self.i += 1;
        Ok(StepInfo {
            step: self.i - 1,
            t: tn,
            nfe: self.solver.base.evals_per_step(),
            done: self.is_done(),
        })
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.solver.grid.len()
    }

    fn state(&self) -> &Tensor {
        &self.x
    }

    fn steps_total(&self) -> Option<usize> {
        Some(self.solver.grid.len() - 1)
    }
}

impl Sampler for FixedGridSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn nfe(&self) -> usize {
        (self.grid.len() - 1) * self.base.evals_per_step()
    }

    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>> {
        if self.grid.len() < 2 {
            bail!("time grid needs at least 2 points");
        }
        Ok(Box::new(FixedGridSession {
            solver: self,
            x: x0.clone(),
            i: 0,
            ws: Workspace::preallocate(x0.shape(), self.base.stage_buffers()),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x' = a x solved exactly: x(1) = e^a x(0); check convergence order.
    fn order_of(base: BaseRk) -> f32 {
        let a = -1.3f32;
        let x0 = Tensor::new(vec![1.0], vec![1, 1]).unwrap();
        let exact = (a).exp();
        let err = |n: usize| {
            let mut f = |x: &Tensor, _t: f32| Ok(x.scale(a));
            let grid: Vec<f32> = (0..=n).map(|i| i as f32 / n as f32).collect();
            let x1 = solve(base, &mut f, &x0, &grid).unwrap();
            (x1.data()[0] - exact).abs()
        };
        let (e1, e2) = (err(8), err(16));
        (e1 / e2).log2()
    }

    #[test]
    fn empirical_convergence_orders() {
        assert!((order_of(BaseRk::Rk1) - 1.0).abs() < 0.2);
        assert!((order_of(BaseRk::Rk2) - 2.0).abs() < 0.2);
        assert!((order_of(BaseRk::Rk4) - 4.0).abs() < 0.5);
    }

    #[test]
    fn nonuniform_grid_reaches_endpoint() {
        // x' = 1: x(1) = x(0) + 1 regardless of the grid.
        let x0 = Tensor::new(vec![0.0, 2.0], vec![1, 2]).unwrap();
        let mut f = |x: &Tensor, _t: f32| Ok(Tensor::full(x.shape(), 1.0));
        let grid = vec![0.0, 0.07, 0.5, 0.51, 1.0];
        for base in [BaseRk::Rk1, BaseRk::Rk2, BaseRk::Rk4] {
            let x1 = solve(base, &mut f, &x0, &grid).unwrap();
            assert!((x1.data()[0] - 1.0).abs() < 1e-6);
            assert!((x1.data()[1] - 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nfe_accounting() {
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk1, 10).nfe(), 10);
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk2, 10).nfe(), 20);
        assert_eq!(FixedGridSolver::uniform(BaseRk::Rk4, 5).nfe(), 20);
    }

    #[test]
    fn short_grid_rejected() {
        let x0 = Tensor::zeros(&[1, 1]);
        let mut f = |x: &Tensor, _t: f32| Ok(x.clone());
        assert!(solve(BaseRk::Rk1, &mut f, &x0, &[0.0]).is_err());
        let s = FixedGridSolver::with_grid(BaseRk::Rk1, vec![0.0], "bad");
        assert!(s.begin(&x0).is_err());
    }

    /// A trivial velocity model x' = a x for exercising the session path.
    struct Field(f32);
    impl crate::models::VelocityModel for Field {
        fn name(&self) -> &str {
            "field"
        }
        fn batch(&self) -> usize {
            1
        }
        fn dim(&self) -> usize {
            2
        }
        fn eval(&self, x: &Tensor, _t: f32) -> Result<Tensor> {
            Ok(x.scale(self.0))
        }
    }

    #[test]
    fn session_matches_direct_solve_bitwise() {
        let field = Field(-1.3);
        let x0 = Tensor::new(vec![1.0, -0.5], vec![1, 2]).unwrap();
        for base in [BaseRk::Rk1, BaseRk::Rk2, BaseRk::Rk4] {
            let s = FixedGridSolver::uniform(base, 7);
            let mut f = |x: &Tensor, t: f32| field.eval(x, t);
            let direct = solve(base, &mut f, &x0, &s.grid).unwrap();
            // one-shot sample() is the session driver by construction
            let one_shot = s.sample(&field, &x0).unwrap();
            assert_eq!(one_shot.data(), direct.data());
            // manual stepping with StepInfo accounting
            let mut sess = s.begin(&x0).unwrap();
            assert_eq!(sess.steps_total(), Some(7));
            let (mut nfe, mut steps) = (0usize, 0usize);
            while !sess.is_done() {
                let info = sess.step(&field).unwrap();
                nfe += info.nfe;
                steps += 1;
                assert_eq!(info.step + 1, steps);
                assert_eq!(info.done, steps == 7);
            }
            assert_eq!(sess.state().data(), direct.data());
            assert_eq!(nfe, s.nfe());
            assert!(sess.step(&field).is_err(), "stepping past the end must fail");
            // init() rewinds for reuse
            sess.init(&x0).unwrap();
            assert!(!sess.is_done());
            while !sess.is_done() {
                sess.step(&field).unwrap();
            }
            assert_eq!(sess.state().data(), direct.data());
        }
    }
}
