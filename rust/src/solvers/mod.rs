//! The numerical-solver library (L3 of the stack).
//!
//! Everything the paper evaluates lives here:
//!
//! * fixed-grid Runge–Kutta samplers (RK1/RK2/RK4) over arbitrary time
//!   grids ([`rk`], [`grids`]) — the paper's generic baselines,
//! * the adaptive DOPRI5 solver with dense output ([`dopri5`]) — the
//!   ground-truth sampler (paper: "adaptive RK45 / DOPRI5"),
//! * heuristic scale-time *scheduler-transfer* samplers ([`transfer`]) —
//!   the DDIM / DPM-Solver / EDM analogs, which the paper shows are fixed
//!   members of the scale-time family,
//! * the learned **Bespoke** samplers ([`bespoke`]) over the raw-theta
//!   parameterization ([`theta`]),
//! * the non-stationary families ([`bns`]): BNS per-step coefficients,
//!   learned multistep, and the training-free Adams–Bashforth baseline
//!   (DESIGN.md §11).
//!
//! # The two-layer solver API
//!
//! **Typed specs** ([`spec::SolverSpec`]): every solver configuration is a
//! value of the `SolverSpec` enum. Specs parse strictly from the CLI/server
//! string grammar (`"rk2:n=10:grid=edm"`, `"dopri5:rtol=1e-6:atol=1e-8"`),
//! `Display` back to a canonical string, round-trip through JSON, and
//! [`spec::SolverSpec::build`] instantiates the described [`Sampler`]. The
//! string-in/sampler-out [`spec::make_sampler`] remains as a one-line
//! convenience wrapper. The registry-resolved form
//! (`bespoke:model=M:n=8`) names the best trained artifact in the
//! `crate::registry` store and is rewritten to `bespoke:path=...` by
//! `Registry::resolve_spec` before building.
//!
//! **Step-wise execution** ([`SolveSession`]): a sampler is not a one-shot
//! black box — [`Sampler::begin`] opens a session that advances one paper-
//! Algorithm-1 step per [`SolveSession::step`] call and exposes the current
//! state between steps. This is what lets the coordinator stream
//! trajectories, report per-step progress, and (eventually) interleave
//! steps across requests. [`Sampler::sample`] is a default method that
//! drives a session to completion, so one-shot call sites are unchanged.

pub mod bespoke;
pub mod bns;
pub mod dopri5;
pub mod grids;
pub mod rk;
pub mod spec;
pub mod theta;
pub mod transfer;

pub use bespoke::BespokeSolver;
pub use bns::{sampler_for_theta, AbSolver, BnsSolver, MultistepSolver};
pub use dopri5::{DenseSolution, Dopri5};
pub use grids::GridKind;
pub use rk::{BaseRk, FixedGridSolver};
pub use spec::{make_sampler, SolverSpec};
pub use theta::{Base, DecodedTheta, Family, RawTheta};
pub use transfer::TransferSolver;

use anyhow::Result;

use crate::models::VelocityModel;
use crate::tensor::Tensor;

/// Numerics probe snapshot read at step boundaries by the solver flight
/// recorder (DESIGN.md §14). Fixed-grid sessions use the default (every
/// attempted step is accepted, no embedded error estimate); adaptive
/// sessions (dopri5) report their accept/reject totals and the error norm
/// of the most recent attempt.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionProbe {
    /// Steps accepted since `begin`/`init`.
    pub accepted: u64,
    /// Attempts rejected by the error controller since `begin`/`init`.
    pub rejected: u64,
    /// Scaled embedded error norm of the most recent attempt (adaptive
    /// solvers only; acceptance threshold is 1.0).
    pub err_norm: Option<f64>,
}

/// Progress report for one completed [`SolveSession::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    /// 0-based index of the step just completed.
    pub step: usize,
    /// Integration time reached after this step, on the solver's native
    /// axis (model time for fixed-grid/bespoke, transformed time r for
    /// scheduler transfer).
    pub t: f32,
    /// Model evaluations consumed by this step (including rejected
    /// attempts for adaptive solvers).
    pub nfe: usize,
    /// Whether the trajectory is complete after this step.
    pub done: bool,
}

/// An in-flight solve: one ODE trajectory advanced step by step.
///
/// Protocol: a session produced by [`Sampler::begin`] is already
/// initialized; call [`SolveSession::step`] until [`SolveSession::is_done`]
/// returns true, then read the final sample from [`SolveSession::state`].
/// [`SolveSession::init`] rewinds the session to t = 0 with a fresh noise
/// batch so sessions can be reused without rebuilding the solver.
///
/// Allocation contract: a session **owns** its stage buffers (pre-allocated
/// in `begin()`, recycled through a [`crate::tensor::Workspace`]) — that is
/// why `step` takes `&mut self`. After `begin()` the step loop performs
/// zero heap allocation (pinned by `rust/tests/alloc_free.rs`; see
/// DESIGN.md §7), while remaining bitwise identical to the retained
/// clone-per-stage reference paths (`rust/tests/perf_equivalence.rs`).
pub trait SolveSession: Send {
    /// (Re)initialize the trajectory at x(0) = x0.
    fn init(&mut self, x0: &Tensor) -> Result<()>;

    /// Advance one solver step. Errors if the session is already done.
    fn step(&mut self, model: &dyn VelocityModel) -> Result<StepInfo>;

    /// True once the trajectory has reached t = 1.
    fn is_done(&self) -> bool;

    /// The current state x [B, d] — the final sample once [`Self::is_done`].
    fn state(&self) -> &Tensor;

    /// Total number of steps, when known in advance (fixed-grid solvers);
    /// `None` for adaptive solvers.
    fn steps_total(&self) -> Option<usize> {
        None
    }

    /// Flight-recorder probe (DESIGN.md §14): read-only numerics snapshot
    /// taken at step boundaries when the `[obs] probe` knob is on. The
    /// default suits every fixed-grid solver: `step + 1` accepted steps,
    /// zero rejections, no error estimate. Implementations must not mutate
    /// solver state — the probe being on or off cannot change sample bytes.
    fn probe(&self, last: &StepInfo) -> SessionProbe {
        SessionProbe { accepted: last.step as u64 + 1, rejected: 0, err_norm: None }
    }
}

/// A sampler integrates the flow ODE from t = 0 (noise) to t = 1 (data).
pub trait Sampler: Send + Sync {
    fn name(&self) -> String;

    /// Number of model evaluations one full solve performs (0 when adaptive;
    /// adaptive NFE is reported per solve via [`StepInfo::nfe`]).
    fn nfe(&self) -> usize;

    /// Open a step-wise [`SolveSession`] initialized at `x0`.
    fn begin(&self, x0: &Tensor) -> Result<Box<dyn SolveSession + '_>>;

    /// Map a noise batch x0 [B, d] to approximate data samples [B, d].
    ///
    /// Default: drive a [`SolveSession`] to completion. Step-wise and
    /// one-shot execution are therefore the same code path and produce
    /// bitwise-identical output.
    fn sample(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor> {
        let mut session = self.begin(x0)?;
        while !session.is_done() {
            session.step(model)?;
        }
        Ok(session.state().clone())
    }
}
