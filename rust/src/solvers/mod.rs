//! The numerical-solver library (L3 of the stack).
//!
//! Everything the paper evaluates lives here:
//!
//! * fixed-grid Runge–Kutta samplers (RK1/RK2/RK4) over arbitrary time
//!   grids ([`rk`], [`grids`]) — the paper's generic baselines,
//! * the adaptive DOPRI5 solver with dense output ([`dopri5`]) — the
//!   ground-truth sampler (paper: "adaptive RK45 / DOPRI5"),
//! * heuristic scale-time *scheduler-transfer* samplers ([`transfer`]) —
//!   the DDIM / DPM-Solver / EDM analogs, which the paper shows are fixed
//!   members of the scale-time family,
//! * the learned **Bespoke** samplers ([`bespoke`]) over the raw-theta
//!   parameterization ([`theta`]),
//! * a name-based [`registry`] so the CLI/server/benches can instantiate
//!   any solver from a string spec like `"bespoke-rk2:n=8"` or
//!   `"rk2:n=10:grid=edm"`.

pub mod bespoke;
pub mod dopri5;
pub mod grids;
pub mod registry;
pub mod rk;
pub mod theta;
pub mod transfer;

pub use bespoke::BespokeSolver;
pub use dopri5::{DenseSolution, Dopri5};
pub use registry::make_sampler;
pub use rk::{BaseRk, FixedGridSolver};
pub use theta::{Base, DecodedTheta, RawTheta};
pub use transfer::TransferSolver;

use anyhow::Result;

use crate::models::VelocityModel;
use crate::tensor::Tensor;

/// A sampler integrates the flow ODE from t = 0 (noise) to t = 1 (data).
pub trait Sampler: Send + Sync {
    fn name(&self) -> String;
    /// Number of model evaluations one `sample` call performs.
    fn nfe(&self) -> usize;
    /// Map a noise batch x0 [B, d] to approximate data samples [B, d].
    fn sample(&self, model: &dyn VelocityModel, x0: &Tensor) -> Result<Tensor>;
}
