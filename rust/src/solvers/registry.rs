//! Name-based solver registry: instantiate any sampler from a string spec.
//!
//! Grammar (colon-separated key=val after the kind; parsed strictly by
//! [`SolverSpec`] — unknown keys and malformed segments are errors):
//!
//! ```text
//! rk1:n=10                       plain Euler, uniform grid
//! rk2:n=10:grid=edm              midpoint on the EDM rho-grid
//! rk4:n=5                        (grids: uniform|edm|cosine|logsnr)
//! rk2-target:n=10:sched=vp       scheduler-transfer (DPM/DDIM/EDM analog)
//! dopri5:tol=1e-5                adaptive ground truth (tol sets rtol+atol)
//! dopri5:rtol=1e-6:atol=1e-8     ... or set them independently
//! bespoke:path=out/theta.json    learned Bespoke solver from a checkpoint
//! ```
//!
//! The model's own scheduler (needed by warped grids and transfer) is
//! passed in by the caller.

use anyhow::Result;

use super::spec::SolverSpec;
use super::Sampler;
use crate::schedulers::Scheduler;

/// Build a sampler from a spec string; `model_sched` is the scheduler of
/// the model the sampler will run against. Equivalent to
/// `SolverSpec::parse(spec)?.build(model_sched)`.
pub fn make_sampler(spec: &str, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
    SolverSpec::parse(spec)?.build(model_sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::theta::RawTheta;

    #[test]
    fn builds_every_kind() {
        let s = Scheduler::CondOt;
        for spec in [
            "rk1:n=4",
            "rk2:n=8:grid=edm",
            "rk2:n=8:grid=logsnr",
            "rk2:n=8:grid=cosine",
            "rk4:n=2",
            "rk2-target:n=4:sched=vp",
            "dopri5:tol=1e-4",
            "dopri5:rtol=1e-4:atol=1e-6",
            "dopri5",
        ] {
            let sampler = make_sampler(spec, s).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!sampler.name().is_empty());
        }
    }

    #[test]
    fn bespoke_from_checkpoint() {
        let th = RawTheta::identity(crate::solvers::theta::Base::Rk2, 4);
        let dir = std::env::temp_dir().join(format!("bespoke_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("theta.json");
        th.save(&path).unwrap();
        let s = make_sampler(
            &format!("bespoke:path={}", path.display()),
            Scheduler::CondOt,
        )
        .unwrap();
        assert_eq!(s.nfe(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn independent_dopri5_tolerances() {
        let s = make_sampler("dopri5:rtol=1e-3:atol=1e-6", Scheduler::CondOt).unwrap();
        // the name carries rtol; the typed spec carries both (see spec tests)
        assert!(s.name().contains("dopri5"));
        match SolverSpec::parse("dopri5:rtol=1e-3:atol=1e-6").unwrap() {
            SolverSpec::Dopri5 { rtol, atol, .. } => {
                assert_eq!(rtol, 1e-3);
                assert_eq!(atol, 1e-6);
            }
            other => panic!("wrong spec {other:?}"),
        }
    }

    #[test]
    fn rejects_bad_specs() {
        let s = Scheduler::CondOt;
        for spec in [
            "nope:n=4",
            "rk2",
            "rk2:n=x",
            "rk2-target:n=4",
            "bespoke",
            // strictness (previously silently ignored):
            "rk2:n=4:foo=1",  // unknown key
            "rk2:n",          // key without '='
            "rk2:n=4:grid",   // trailing key without '='
            "rk2:n=4:n=8",    // duplicate key
        ] {
            assert!(make_sampler(spec, s).is_err(), "should reject {spec}");
        }
    }
}
