//! Name-based solver registry: instantiate any sampler from a string spec.
//!
//! Grammar (colon-separated key=val after the kind):
//!
//! ```text
//! rk1:n=10                     plain Euler, uniform grid
//! rk2:n=10:grid=edm            midpoint on the EDM rho-grid
//! rk4:n=5
//! rk2-target:n=10:sched=vp     scheduler-transfer (DPM/DDIM/EDM analog)
//! dopri5:tol=1e-5              adaptive ground truth
//! bespoke:path=out/theta.json  learned Bespoke solver from a checkpoint
//! ```
//!
//! The model's own scheduler (needed by warped grids and transfer) is
//! passed in by the caller.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::bespoke::BespokeSolver;
use super::dopri5::Dopri5;
use super::grids;
use super::rk::{BaseRk, FixedGridSolver};
use super::theta::RawTheta;
use super::transfer::TransferSolver;
use super::Sampler;
use crate::schedulers::Scheduler;

fn parse_spec(spec: &str) -> (String, BTreeMap<String, String>) {
    let mut parts = spec.split(':');
    let kind = parts.next().unwrap_or("").to_string();
    let mut kv = BTreeMap::new();
    for p in parts {
        if let Some((k, v)) = p.split_once('=') {
            kv.insert(k.to_string(), v.to_string());
        }
    }
    (kind, kv)
}

fn get_n(kv: &BTreeMap<String, String>) -> Result<usize> {
    kv.get("n")
        .context("missing n=<steps>")?
        .parse::<usize>()
        .context("bad n")
}

/// Build a sampler from a spec string; `model_sched` is the scheduler of
/// the model the sampler will run against.
pub fn make_sampler(spec: &str, model_sched: Scheduler) -> Result<Box<dyn Sampler>> {
    let (kind, kv) = parse_spec(spec);
    match kind.as_str() {
        "rk1" | "rk2" | "rk4" | "euler" | "midpoint" => {
            let base = BaseRk::parse(&kind)?;
            let n = get_n(&kv)?;
            let grid_name = kv.get("grid").map(String::as_str).unwrap_or("uniform");
            let grid = grids::make(grid_name, n, model_sched)?;
            let label = if grid_name == "uniform" {
                format!("{}:n={n}", base.name())
            } else {
                format!("{}:n={n}:grid={grid_name}", base.name())
            };
            Ok(Box::new(FixedGridSolver::with_grid(base, grid, label)))
        }
        "rk1-target" | "rk2-target" => {
            let base = BaseRk::parse(kind.trim_end_matches("-target"))?;
            let n = get_n(&kv)?;
            let target = Scheduler::parse(kv.get("sched").context("missing sched=")?)?;
            Ok(Box::new(TransferSolver::new(model_sched, target, base, n)))
        }
        "dopri5" => {
            let tol = kv
                .get("tol")
                .map(|s| s.parse::<f64>())
                .transpose()
                .context("bad tol")?
                .unwrap_or(1e-5);
            Ok(Box::new(Dopri5 { rtol: tol, atol: tol, max_steps: 100_000 }))
        }
        "bespoke" => {
            let path = kv.get("path").context("missing path=<theta.json>")?;
            let raw = RawTheta::load(std::path::Path::new(path))
                .with_context(|| format!("loading theta from {path}"))?;
            Ok(Box::new(BespokeSolver::new(&raw)))
        }
        _ => bail!(
            "unknown solver kind {kind:?} \
             (rk1|rk2|rk4|rk1-target|rk2-target|dopri5|bespoke)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_kind() {
        let s = Scheduler::CondOt;
        for spec in [
            "rk1:n=4",
            "rk2:n=8:grid=edm",
            "rk2:n=8:grid=logsnr",
            "rk4:n=2",
            "rk2-target:n=4:sched=vp",
            "dopri5:tol=1e-4",
            "dopri5",
        ] {
            let sampler = make_sampler(spec, s).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!sampler.name().is_empty());
        }
    }

    #[test]
    fn bespoke_from_checkpoint() {
        let th = RawTheta::identity(crate::solvers::theta::Base::Rk2, 4);
        let dir = std::env::temp_dir().join(format!("bespoke_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("theta.json");
        th.save(&path).unwrap();
        let s = make_sampler(
            &format!("bespoke:path={}", path.display()),
            Scheduler::CondOt,
        )
        .unwrap();
        assert_eq!(s.nfe(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_specs() {
        let s = Scheduler::CondOt;
        for spec in ["nope:n=4", "rk2", "rk2:n=x", "rk2-target:n=4", "bespoke"] {
            assert!(make_sampler(spec, s).is_err(), "should reject {spec}");
        }
    }
}
