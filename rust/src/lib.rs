//! # bespoke-flow
//!
//! A production-grade Rust + JAX + Pallas reproduction of **"Bespoke Solvers
//! for Generative Flow Models"** (Shaul et al., ICLR 2024).
//!
//! The crate is the L3 layer of a three-layer stack (see `DESIGN.md`):
//! python/JAX authors the flow models and the differentiable Bespoke loss and
//! AOT-lowers them to HLO text (`make artifacts`); this crate loads those
//! artifacts through PJRT (`runtime`), implements the full numerical-solver
//! library including the learned Bespoke solvers (`solvers` — typed
//! `SolverSpec` configs plus step-wise `SolveSession` execution), owns the
//! Bespoke training loop (`bespoke`), stores trained solvers in a versioned
//! artifact registry with in-server training jobs and hot-swap serving
//! (`registry`), measures every solver's quality-vs-NFE tradeoff into
//! scorecards and Pareto frontiers that budget-aware requests resolve
//! against (`quality`), serves samples through a batching coordinator
//! (`coordinator`, with step-streamed trajectories via `sample_traj`), and
//! regenerates every table and figure of the paper's evaluation
//! (`bench_harness`).
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.

pub mod bench_harness;
pub mod bespoke;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod json;
pub mod models;
pub mod quality;
pub mod registry;
pub mod runtime;
pub mod schedulers;
pub mod solvers;
pub mod tensor;
pub mod testing;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
