//! Minimal, dependency-free JSON: value model, recursive-descent parser and
//! writer. Used for the artifact manifest, the serving wire protocol, theta
//! checkpoints, and experiment reports.
//!
//! Supports the full JSON grammar (RFC 8259) minus exotic number edge cases
//! beyond f64 range; object keys keep insertion order via a Vec-backed map.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    /// Explicit NaN/Inf-safe number: non-finite -> `Value::Null`. The
    /// writer would lossily emit `null` for non-finite numbers anyway
    /// (JSON has no NaN); this makes the intent visible at the encoding
    /// site, and decoders map `null` back to NaN.
    pub fn num_or_null(x: f64) -> Value {
        if x.is_finite() {
            Value::Num(x)
        } else {
            Value::Null
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Value::Null),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate pair"))?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        e => bail!("bad escape \\{:?}", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "1e-3", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            let v2 = Value::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Value::obj(vec![
            ("name", Value::Str("bespoke".into())),
            ("vals", Value::from_f32s(&[1.0, 2.5, -3.0])),
            ("nested", Value::obj(vec![("k", Value::Bool(true))])),
        ]);
        for s in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Value::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Value::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "tru", "\"abc", "1 2", "{\"a\" 1}"] {
            assert!(Value::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn numbers_precise() {
        let v = Value::parse("[0.125, -7, 3e8]").unwrap();
        let xs = v.as_f32_vec().unwrap();
        assert_eq!(xs, vec![0.125, -7.0, 3e8]);
    }

    #[test]
    fn num_or_null_maps_non_finite() {
        assert_eq!(Value::num_or_null(1.5), Value::Num(1.5));
        assert_eq!(Value::num_or_null(f64::NAN), Value::Null);
        assert_eq!(Value::num_or_null(f64::INFINITY), Value::Null);
    }

    #[test]
    fn accessor_errors() {
        let v = Value::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("b").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert!(Value::Num(1.5).as_usize().is_err());
    }
}
