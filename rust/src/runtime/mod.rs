//! PJRT runtime: loads the AOT'd HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the request path.
//!
//! The interchange format is HLO *text* — jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md §1).

pub mod executable;
pub mod manifest;

pub use executable::{Executable, LiteralBuf};
pub use manifest::{LossGradMeta, Manifest, ModelMeta};

use std::sync::OnceLock;

use anyhow::{Context, Result};

/// The process-wide PJRT CPU client.
///
/// SAFETY: `xla::PjRtClient` holds raw pointers and is not auto-Send/Sync,
/// but the PJRT CPU client is documented thread-safe for compilation and
/// execution; we serialize nothing and share it across the coordinator's
/// worker threads.
pub struct Client(pub xla::PjRtClient);
unsafe impl Send for Client {}
unsafe impl Sync for Client {}

static CLIENT: OnceLock<Client> = OnceLock::new();

/// Get (or lazily create) the global PJRT CPU client.
pub fn client() -> Result<&'static Client> {
    if CLIENT.get().is_none() {
        let c = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let _ = CLIENT.set(Client(c));
    }
    Ok(CLIENT.get().unwrap())
}

/// Platform string of the global client (for diagnostics/CLI).
pub fn platform() -> Result<String> {
    Ok(client()?.0.platform_name())
}
