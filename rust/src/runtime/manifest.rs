//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust coordinator. Parses `artifacts/manifest.json`, loads datasets, and
//! resolves artifact paths.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub file: String,
    pub k: usize,
    pub d: usize,
}

#[derive(Clone, Debug)]
pub struct LossGradMeta {
    pub file: String,
    pub base: String,
    pub n: usize,
    pub p: usize,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub u_hlo: String,
    pub dataset: String,
    pub sched: String,
    pub kind: String,
    pub batch: usize,
    pub d: usize,
    pub gamma: f32,
    pub lossgrads: BTreeMap<String, LossGradMeta>,
}

/// Parsed manifest + the directory it lives in.
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
    pub datasets: BTreeMap<String, DatasetMeta>,
}

impl Manifest {
    /// Default location: `<repo>/artifacts` (override with BESPOKE_ARTIFACTS).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("BESPOKE_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let mut datasets = BTreeMap::new();
        for (name, dv) in v.get("datasets")?.as_obj()? {
            datasets.insert(
                name.clone(),
                DatasetMeta {
                    file: dv.get("file")?.as_str()?.to_string(),
                    k: dv.get("k")?.as_usize()?,
                    d: dv.get("d")?.as_usize()?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            let mut lossgrads = BTreeMap::new();
            for (key, lv) in mv.get("lossgrads")?.as_obj()? {
                lossgrads.insert(
                    key.clone(),
                    LossGradMeta {
                        file: lv.get("file")?.as_str()?.to_string(),
                        base: lv.get("base")?.as_str()?.to_string(),
                        n: lv.get("n")?.as_usize()?,
                        p: lv.get("p")?.as_usize()?,
                    },
                );
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    u_hlo: mv.get("u_hlo")?.as_str()?.to_string(),
                    dataset: mv.get("dataset")?.as_str()?.to_string(),
                    sched: mv.get("sched")?.as_str()?.to_string(),
                    kind: mv.get("kind")?.as_str()?.to_string(),
                    batch: mv.get("batch")?.as_usize()?,
                    d: mv.get("d")?.as_usize()?,
                    gamma: mv.get("gamma")?.as_f64()? as f32,
                    lossgrads,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, datasets })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model {name:?}; available: {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a dataset dump (`data_<name>.f32`, little-endian f32 [K, d]).
    pub fn load_dataset(&self, name: &str) -> Result<Tensor> {
        let meta = self
            .datasets
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
        let bytes = std::fs::read(self.path(&meta.file))
            .with_context(|| format!("reading dataset {name}"))?;
        if bytes.len() != meta.k * meta.d * 4 {
            bail!(
                "dataset {name}: expected {} bytes, found {}",
                meta.k * meta.d * 4,
                bytes.len()
            );
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(data, vec![meta.k, meta.d])
    }

    /// Loss-grad artifact for (model, base, n), if exported.
    pub fn lossgrad(&self, model: &str, base: &str, n: usize) -> Result<&LossGradMeta> {
        let m = self.model(model)?;
        m.lossgrads.get(&format!("{base}_n{n}")).ok_or_else(|| {
            anyhow::anyhow!(
                "no lossgrad artifact for model={model} base={base} n={n}; \
                 exported: {:?}",
                m.lossgrads.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("bespoke_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"datasets": {"ds": {"file": "data_ds.f32", "k": 2, "d": 3}},
                "models": {"m": {"u_hlo": "u_m.hlo.txt", "dataset": "ds",
                 "sched": "ot", "kind": "ideal", "batch": 4, "d": 3,
                 "gamma": 0.05,
                 "lossgrads": {"rk2_n4": {"file": "lg.hlo.txt", "base": "rk2",
                                           "n": 4, "p": 32}}}},
                "lossgrads": {}}"#,
        )
        .unwrap();
        let raw: Vec<u8> = (0..6u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("data_ds.f32"), raw).unwrap();

        let man = Manifest::load(&dir).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(man.lossgrad("m", "rk2", 4).unwrap().p, 32);
        assert!(man.lossgrad("m", "rk1", 4).is_err());
        let ds = man.load_dataset("ds").unwrap();
        assert_eq!(ds.shape(), &[2, 3]);
        assert_eq!(ds.data()[4], 4.0);
        assert!(man.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
