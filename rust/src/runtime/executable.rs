//! A compiled HLO executable with Tensor-level marshalling.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// A loaded + compiled HLO module. All exported artifacts are lowered with
/// `return_tuple=True`, so outputs always arrive as a (possibly 1-ary) tuple.
///
/// SAFETY: see `runtime::Client` — PJRT CPU execution is thread-safe; the
/// wrapper is shared read-only across worker threads.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Load HLO text from `path`, compile it on the global CPU client.
    pub fn load(path: &Path) -> Result<Executable> {
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = crate::runtime::client()?
            .0
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { name, exe })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host tensors; returns the tuple elements as tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-marshalled literals (lets hot loops reuse buffers).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let out = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.name))?;
        let result = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = result.to_tuple().context("untupling result")?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }
}

/// Host Tensor -> xla Literal (f32, row-major).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping literal")
}

/// xla Literal -> host Tensor (f32 only; artifacts are all-f32).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal shape")?;
    if shape.ty() != xla::ElementType::F32 {
        bail!("expected f32 output, got {:?}", shape.ty());
    }
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal to_vec")?;
    Tensor::new(data, dims)
}
