//! A compiled HLO executable with Tensor-level marshalling.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// A loaded + compiled HLO module. All exported artifacts are lowered with
/// `return_tuple=True`, so outputs always arrive as a (possibly 1-ary) tuple.
///
/// SAFETY: see `runtime::Client` — PJRT CPU execution is thread-safe; the
/// wrapper is shared read-only across worker threads.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Load HLO text from `path`, compile it on the global CPU client.
    pub fn load(path: &Path) -> Result<Executable> {
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = crate::runtime::client()?
            .0
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { name, exe })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host tensors; returns the tuple elements as tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-marshalled literals (lets hot loops reuse buffers).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self.execute_one(literals)?;
        let parts = result.to_tuple().context("untupling result")?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }

    /// Execute with host tensors, staging through a caller-owned
    /// [`LiteralBuf`] and decoding the single expected output in place
    /// (`out` must already have the output's shape). This is the hot-loop
    /// entry: `HloModel::eval_into` calls it once per solver step with a
    /// buffer that lives for the whole session, so the steady-state step
    /// loop re-marshals no Rust-side vectors (the alloc_free.rs invariant,
    /// extended to the HLO backend — DESIGN.md §15).
    pub fn run_into(&self, buf: &mut LiteralBuf, inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
        buf.lits.clear();
        for t in inputs {
            buf.lits.push(tensor_to_literal(t)?);
        }
        let result = self.execute_one(&buf.lits)?;
        let parts = result.to_tuple().context("untupling result")?;
        if parts.len() != 1 {
            bail!("{}: expected 1 output, got {}", self.name, parts.len());
        }
        literal_into_tensor(&parts[0], out)
    }

    /// Launch + fetch the (single) result literal of one execution.
    fn execute_one(&self, literals: &[xla::Literal]) -> Result<xla::Literal> {
        let out = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.name))?;
        // PJRT returns one buffer list per addressable device; a malformed
        // or zero-output executable legitimately returns empty lists. That
        // must surface as a structured error the coordinator can code and
        // retry on — an unchecked out[0][0] here used to panic the worker.
        let first = out.first().and_then(|device| device.first()).ok_or_else(|| {
            anyhow::anyhow!(
                "{}: execution returned no output buffers (devices={}, outputs_on_first={})",
                self.name,
                out.len(),
                out.first().map_or(0, |d| d.len())
            )
        })?;
        first
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))
    }
}

/// Reusable marshalling buffers for hot solve loops: the literal vector is
/// rebuilt in place each call, so a session's step loop reuses its Rust-side
/// capacity instead of growing fresh vectors per NFE. (The literal payloads
/// themselves live on the XLA side of the FFI boundary; what this plus
/// [`Executable::run_into`]'s in-place decode eliminates is every per-call
/// Rust-heap allocation.)
#[derive(Default)]
pub struct LiteralBuf {
    lits: Vec<xla::Literal>,
}

impl LiteralBuf {
    pub fn new() -> LiteralBuf {
        LiteralBuf { lits: Vec::new() }
    }
}

/// Host Tensor -> xla Literal (f32, row-major).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping literal")
}

/// xla Literal -> host Tensor (f32 only; artifacts are all-f32).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal shape")?;
    if shape.ty() != xla::ElementType::F32 {
        bail!("expected f32 output, got {:?}", shape.ty());
    }
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal to_vec")?;
    Tensor::new(data, dims)
}

/// xla Literal -> existing host Tensor (f32; shapes must match): the
/// allocation-free counterpart of [`literal_to_tensor`] — decodes the
/// payload straight into a caller-owned buffer.
pub fn literal_into_tensor(l: &xla::Literal, out: &mut Tensor) -> Result<()> {
    let shape = l.array_shape().context("literal shape")?;
    if shape.ty() != xla::ElementType::F32 {
        bail!("expected f32 output, got {:?}", shape.ty());
    }
    let dims = shape.dims();
    let matches = out.shape().len() == dims.len()
        && out.shape().iter().zip(dims.iter()).all(|(&a, &b)| a as i64 == b);
    if !matches {
        bail!("output shape {:?} does not match literal shape {:?}", out.shape(), dims);
    }
    l.copy_raw_to(out.data_mut()).context("literal copy_raw_to")
}
