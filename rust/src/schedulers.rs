//! Gaussian-path schedulers (paper eq. 22/82/83/85) — the Rust mirror of
//! `python/compile/schedulers.py`, plus the scheduler-transfer maps
//! (paper eq. 31/32) used by the heuristic scale-time baseline solvers
//! (DDIM / DPM / EDM analogs) and the EDM time grid.
//!
//! Convention: noise at t = 0, data at t = 1.

use anyhow::{bail, Result};

pub const VP_BETA_MAX: f64 = 20.0;
pub const VP_BETA_MIN: f64 = 0.1;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// Conditional-OT Flow Matching: alpha = t, sigma = 1 - t.
    CondOt,
    /// Cosine: alpha = sin(pi t / 2), sigma = cos(pi t / 2).
    Cosine,
    /// Variance-preserving (eq. 85), B = 20, b = 0.1.
    VarPres,
    /// EDM-style variance-exploding path expressed in our convention:
    /// alpha = t, sigma = (1 - t) * SIGMA_MAX / ... — implemented as a
    /// *target* for scheduler transfer via its snr, see `edm_snr`.
    Edm,
}

/// EDM sigma range (Karras et al. 2022), scaled to unit-variance data.
pub const EDM_SIGMA_MIN: f64 = 0.002;
pub const EDM_SIGMA_MAX: f64 = 80.0;
pub const EDM_RHO: f64 = 7.0;

impl Scheduler {
    pub fn parse(name: &str) -> Result<Scheduler> {
        Ok(match name {
            "ot" => Scheduler::CondOt,
            "cs" => Scheduler::Cosine,
            "vp" => Scheduler::VarPres,
            "edm" => Scheduler::Edm,
            _ => bail!("unknown scheduler {name:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheduler::CondOt => "ot",
            Scheduler::Cosine => "cs",
            Scheduler::VarPres => "vp",
            Scheduler::Edm => "edm",
        }
    }

    fn xi(s: f64) -> f64 {
        (-0.25 * s * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * s * VP_BETA_MIN).exp()
    }

    pub fn alpha(&self, t: f64) -> f64 {
        match self {
            Scheduler::CondOt => t,
            Scheduler::Cosine => (std::f64::consts::FRAC_PI_2 * t).sin(),
            Scheduler::VarPres => Self::xi(1.0 - t),
            // EDM in "scaled" form: x_t = x1 + sigma_edm(t) eps with
            // sigma_edm decreasing from SIGMA_MAX to SIGMA_MIN; normalized
            // to our alpha/sigma convention by dividing by sqrt(1+sigma^2)
            // is not needed for snr-based transfer, so we expose the
            // un-normalized alpha = 1 path here.
            Scheduler::Edm => 1.0,
        }
    }

    pub fn sigma(&self, t: f64) -> f64 {
        match self {
            Scheduler::CondOt => 1.0 - t,
            Scheduler::Cosine => (std::f64::consts::FRAC_PI_2 * t).cos(),
            Scheduler::VarPres => {
                let a = self.alpha(t);
                (1.0 - a * a).max(1e-24).sqrt()
            }
            Scheduler::Edm => edm_sigma(t),
        }
    }

    pub fn d_alpha(&self, t: f64) -> f64 {
        match self {
            Scheduler::CondOt => 1.0,
            Scheduler::Cosine => {
                std::f64::consts::FRAC_PI_2 * (std::f64::consts::FRAC_PI_2 * t).cos()
            }
            Scheduler::VarPres => {
                let s = 1.0 - t;
                -(Self::xi(s) * (-0.5 * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * VP_BETA_MIN))
            }
            Scheduler::Edm => 0.0,
        }
    }

    pub fn d_sigma(&self, t: f64) -> f64 {
        match self {
            Scheduler::CondOt => -1.0,
            Scheduler::Cosine => {
                -std::f64::consts::FRAC_PI_2 * (std::f64::consts::FRAC_PI_2 * t).sin()
            }
            Scheduler::VarPres => {
                let a = self.alpha(t);
                -a * self.d_alpha(t) / self.sigma(t)
            }
            Scheduler::Edm => d_edm_sigma(t),
        }
    }

    /// Signal-to-noise ratio snr(t) = alpha / sigma (strictly increasing).
    pub fn snr(&self, t: f64) -> f64 {
        self.alpha(t) / self.sigma(t)
    }

    pub fn log_snr(&self, t: f64) -> f64 {
        self.snr(t).ln()
    }

    /// Inverse of snr: the t with snr(t) = s. Analytic for OT/CS/VP.
    pub fn snr_inverse(&self, s: f64) -> f64 {
        match self {
            Scheduler::CondOt => s / (1.0 + s),
            Scheduler::Cosine => (2.0 / std::f64::consts::PI) * s.atan(),
            Scheduler::VarPres => {
                // alpha = s / sqrt(1 + s^2); alpha = xi(w), solve the
                // quadratic  (B-b)/4 w^2 + b/2 w + ln(alpha) = 0 for w >= 0.
                let alpha = (s / (1.0 + s * s).sqrt()).clamp(1e-300, 1.0);
                let a2 = 0.25 * (VP_BETA_MAX - VP_BETA_MIN);
                let a1 = 0.5 * VP_BETA_MIN;
                let c = alpha.ln();
                let w = (-a1 + (a1 * a1 - 4.0 * a2 * c).sqrt()) / (2.0 * a2);
                (1.0 - w).clamp(0.0, 1.0)
            }
            Scheduler::Edm => {
                // snr = 1 / sigma_edm(t): invert the rho-grid formula.
                let sigma = 1.0 / s;
                let a = EDM_SIGMA_MAX.powf(1.0 / EDM_RHO);
                let b = EDM_SIGMA_MIN.powf(1.0 / EDM_RHO);
                ((sigma.powf(1.0 / EDM_RHO) - a) / (b - a)).clamp(0.0, 1.0)
            }
        }
    }
}

/// EDM sigma(t) along Karras' rho-warped grid, reparameterized to t in
/// [0, 1] with t = 0 <-> sigma_max (noise) and t = 1 <-> sigma_min (data).
pub fn edm_sigma(t: f64) -> f64 {
    let a = EDM_SIGMA_MAX.powf(1.0 / EDM_RHO);
    let b = EDM_SIGMA_MIN.powf(1.0 / EDM_RHO);
    (a + t * (b - a)).powf(EDM_RHO)
}

fn d_edm_sigma(t: f64) -> f64 {
    let a = EDM_SIGMA_MAX.powf(1.0 / EDM_RHO);
    let b = EDM_SIGMA_MIN.powf(1.0 / EDM_RHO);
    EDM_RHO * (a + t * (b - a)).powf(EDM_RHO - 1.0) * (b - a)
}

/// The scale-time transform (t_r, s_r) that re-parameterizes the sampling
/// path of `source` into the path of `target` (paper eq. 31/32):
///
/// ```text
/// t_r = snr^-1_source(snr_target(r)),   s_r = sigma_target(r) / sigma_source(t_r)
/// ```
///
/// This is exactly how the paper casts DDIM / DPM-Solver / EDM as members
/// of the scale-time family; the Bespoke solver *learns* this map instead.
pub fn transfer_map(source: Scheduler, target: Scheduler, r: f64) -> (f64, f64) {
    // Clamp r away from the endpoints where snr is 0 / infinite.
    let rc = r.clamp(1e-5, 1.0 - 1e-5);
    let t = source.snr_inverse(target.snr(rc));
    let s = target.sigma(rc) / source.sigma(t).max(1e-12);
    (t, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Scheduler; 3] = [Scheduler::CondOt, Scheduler::Cosine, Scheduler::VarPres];

    #[test]
    fn boundary_conditions() {
        for s in ALL {
            assert!(s.alpha(0.0).abs() < 7e-3, "{s:?} alpha(0)");
            assert!((s.alpha(1.0) - 1.0).abs() < 1e-9, "{s:?} alpha(1)");
            assert!((s.sigma(0.0) - 1.0).abs() < 1e-4, "{s:?} sigma(0)");
            assert!(s.sigma(1.0).abs() < 1e-6, "{s:?} sigma(1)");
        }
    }

    #[test]
    fn snr_monotone_and_inverse_roundtrips() {
        for s in ALL {
            let mut prev = -1.0;
            for i in 1..100 {
                let t = i as f64 / 100.0;
                let v = s.snr(t);
                assert!(v > prev, "{s:?} snr not increasing at t={t}");
                prev = v;
                let t2 = s.snr_inverse(v);
                assert!((t2 - t).abs() < 1e-6, "{s:?} snr_inverse({v}) = {t2} != {t}");
            }
        }
    }

    #[test]
    fn derivatives_match_finite_differences() {
        let eps = 1e-6;
        for s in ALL {
            for i in 1..20 {
                let t = i as f64 / 20.0 * 0.98;
                let fd_a = (s.alpha(t + eps) - s.alpha(t - eps)) / (2.0 * eps);
                let fd_s = (s.sigma(t + eps) - s.sigma(t - eps)) / (2.0 * eps);
                assert!((s.d_alpha(t) - fd_a).abs() < 1e-4 * (1.0 + fd_a.abs()), "{s:?} d_alpha t={t}");
                assert!((s.d_sigma(t) - fd_s).abs() < 1e-4 * (1.0 + fd_s.abs()), "{s:?} d_sigma t={t}");
            }
        }
    }

    #[test]
    fn vp_variance_preserving() {
        let s = Scheduler::VarPres;
        for i in 0..=20 {
            let t = i as f64 / 20.0;
            let v = s.alpha(t).powi(2) + s.sigma(t).powi(2);
            assert!((v - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn transfer_map_identity_when_source_is_target() {
        for s in ALL {
            for i in 1..20 {
                let r = i as f64 / 20.0;
                let (t, scale) = transfer_map(s, s, r);
                assert!((t - r).abs() < 1e-6, "{s:?} t_r != r at {r}");
                assert!((scale - 1.0).abs() < 1e-6, "{s:?} s_r != 1 at {r}");
            }
        }
    }

    #[test]
    fn transfer_map_monotone_time() {
        for src in ALL {
            for tgt in ALL {
                let mut prev = -1.0;
                for i in 1..50 {
                    let r = i as f64 / 50.0;
                    let (t, s) = transfer_map(src, tgt, r);
                    assert!(t > prev, "{src:?}->{tgt:?} non-monotone at r={r}");
                    assert!(s > 0.0);
                    prev = t;
                }
            }
        }
    }

    #[test]
    fn edm_sigma_endpoints() {
        assert!((edm_sigma(0.0) - EDM_SIGMA_MAX).abs() / EDM_SIGMA_MAX < 1e-9);
        assert!((edm_sigma(1.0) - EDM_SIGMA_MIN).abs() / EDM_SIGMA_MIN < 1e-9);
        // monotone decreasing
        let mut prev = f64::INFINITY;
        for i in 0..=20 {
            let v = edm_sigma(i as f64 / 20.0);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn parse_names() {
        for n in ["ot", "cs", "vp", "edm"] {
            assert_eq!(Scheduler::parse(n).unwrap().name(), n);
        }
        assert!(Scheduler::parse("nope").is_err());
    }
}
