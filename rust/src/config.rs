//! Config system: JSON config files with environment overrides.
//!
//! One schema covers the launcher's subsystems (serving, training,
//! evaluation, experiments); `repro --config path.json <cmd>` merges the
//! file over built-in defaults, and individual CLI flags override both.

use std::path::Path;

use anyhow::{Context, Result};

use crate::json::Value;
use crate::models::Backend;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Max requests folded into one executable launch (<= model batch).
    pub max_batch: usize,
    /// Fusion gather window: how long the lead request of a fused batch
    /// waits for compatible batch-mates before the solve launches, in
    /// microseconds (DESIGN.md §10). 0 = no waiting (each flush takes only
    /// the jobs already queued). The legacy `max_wait_ms` config key is an
    /// alias (x1000).
    pub fuse_window_us: u64,
    /// Max rows from concurrent requests fused into one lockstep solve
    /// (clamped to `max_batch` and the model batch). 0 = auto (the clamp
    /// alone); 1 = cross-request fusion off — every request chunk solves
    /// in its own launch.
    pub fuse_max_rows: usize,
    /// Worker threads per (model, solver) route: concurrent requests to one
    /// route overlap solves across this many executors instead of queueing
    /// behind a single thread. Per-chunk RNG streams keep same-seed output
    /// identical for any pool size (see DESIGN.md §7).
    pub workers_per_route: usize,
    /// Compute threads for the row-parallel host kernels (analytic eval,
    /// batch statistics, Fréchet). 0 = auto: `BESPOKE_THREADS` env var or
    /// the machine's available parallelism.
    pub compute_threads: usize,
    /// Per-connection idle read timeout in ms (DESIGN.md §12): a client
    /// that sends nothing for this long gets a structured `timeout` error
    /// and a clean close, so abandoned connections can't pin threads
    /// forever. 0 = no timeout.
    pub idle_timeout_ms: u64,
    /// Graceful-drain grace window in ms: how long SIGTERM / `drain` waits
    /// for in-flight solves and running jobs before cancelling stragglers.
    pub drain_grace_ms: u64,
    /// Default compute backend for served models (DESIGN.md §15):
    /// `analytic` | `hlo` | `auto`. `auto` prefers the compiled HLO
    /// artifact and falls back to the analytic oracle for `ideal` models
    /// (recorded as a `backend_fallback` metrics event); `hlo` and
    /// `analytic` are strict — a missing artifact/oracle is an error, not
    /// a substitution.
    pub backend: Backend,
    /// Per-model backend overrides (`"backend_overrides": {"model": "hlo"}`
    /// in `[serve]`); models not listed use `backend`.
    pub backend_overrides: Vec<(String, Backend)>,
}

impl ServeConfig {
    /// The backend choice serving `model`: its override when present, else
    /// the global `backend` default.
    pub fn backend_for(&self, model: &str) -> Backend {
        self.backend_overrides
            .iter()
            .find(|(m, _)| m == model)
            .map(|&(_, b)| b)
            .unwrap_or(self.backend)
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7777".into(),
            max_batch: 64,
            fuse_window_us: 5_000,
            fuse_max_rows: 0,
            workers_per_route: 1,
            compute_threads: 0,
            idle_timeout_ms: 0,
            drain_grace_ms: 5_000,
            backend: Backend::Auto,
            backend_overrides: Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub iters: usize,
    pub lr: f32,
    pub seed: u64,
    /// GT trajectory pool: number of cached noise batches (paper's
    /// "pre-processing sampling paths" optimization; 1 = resample like
    /// Algorithm 2 every refresh_every iters).
    pub pool_batches: usize,
    /// Refresh one pool entry every this many iterations (0 = never).
    pub refresh_every: usize,
    /// DOPRI5 tolerance for GT paths.
    pub gt_tol: f64,
    /// Validation: number of fresh batches and iteration interval.
    pub val_batches: usize,
    pub val_every: usize,
    /// Ablation mode: "full" | "time-only" | "scale-only".
    pub ablation: String,
    /// Snapshot velocities u(x(t_i), t_i): "model" evaluates the model HLO
    /// (exact, n+1 launches/iter); "hermite" differentiates the dense GT
    /// interpolant (no launches; error O(h^2) << GT tol). §Perf knob.
    pub snap_velocity: String,
    /// Solver family to train: "stationary" (paper's Bespoke theta) |
    /// "bns" (per-step coefficients) | "multistep" (learned multistep).
    /// See DESIGN.md §11.
    pub family: String,
    /// History window for family = "multistep" (ignored otherwise).
    pub window: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 300,
            lr: 2e-3, // paper's Adam lr (Appendix F)
            seed: 17,
            pool_batches: 8,
            refresh_every: 0,
            gt_tol: 1e-5,
            val_batches: 4,
            val_every: 50,
            ablation: "full".into(),
            snap_velocity: "hermite".into(),
            family: "stationary".into(),
            window: 2,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Root directory of the solver artifact registry (DESIGN.md §8).
    pub root: String,
    /// Max concurrent in-server training jobs.
    pub max_jobs: usize,
    /// GC policy: `registry gc` keeps this many newest versions per
    /// artifact key (plus, always, the best-val-RMSE one).
    pub keep_last_k: usize,
    /// Max queued (not yet running) train jobs; over-limit submissions get
    /// a structured `overloaded` error. 0 = unbounded.
    pub max_pending: usize,
    /// Failed (non-cancelled, non-panicked) train jobs retry up to this
    /// many times with capped exponential backoff. 0 = no retries.
    pub retry_max_attempts: usize,
    /// First retry delay in ms (doubles per attempt).
    pub retry_base_ms: u64,
    /// Backoff ceiling in ms.
    pub retry_cap_ms: u64,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            root: "out/registry".into(),
            max_jobs: 1,
            keep_last_k: 3,
            max_pending: 0,
            retry_max_attempts: 0,
            retry_base_ms: 250,
            retry_cap_ms: 30_000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Number of samples for distribution metrics (Frechet / sliced W2).
    pub metric_samples: usize,
    /// DOPRI5 tolerance for ground-truth solutions.
    pub gt_tol: f64,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { metric_samples: 4096, gt_tol: 1e-5, seed: 1234 }
    }
}

#[derive(Clone, Debug)]
pub struct QualityConfig {
    /// Default step-count grid for `evaluate` sweeps of rk/transfer
    /// templates (a request's explicit `grid` overrides it).
    pub grid: Vec<usize>,
    /// Eval batches behind each scorecard cell (bounds the GT-solve cost
    /// of an in-server eval job; offline `repro eval` uses
    /// `eval.metric_samples` instead).
    pub eval_batches: usize,
    /// Max concurrent in-server eval jobs.
    pub max_eval_jobs: usize,
    /// Max queued (not yet running) eval jobs; over-limit submissions get
    /// a structured `overloaded` error. 0 = unbounded.
    pub max_pending: usize,
}

impl Default for QualityConfig {
    fn default() -> Self {
        QualityConfig { grid: vec![1, 2, 4, 8, 16], eval_batches: 4, max_eval_jobs: 1, max_pending: 0 }
    }
}

/// Minimal cron-like maintenance schedule (DESIGN.md §12): a server-side
/// tick thread that re-evals stale scorecards (coalescing keeps duplicate
/// submissions cheap) and garbage-collects the registry. Everything
/// defaults to off.
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    /// Scheduler tick interval in ms. 0 = scheduler off.
    pub tick_ms: u64,
    /// Re-submit an eval sweep for scorecards older than this many
    /// seconds. 0 = never.
    pub refresh_secs: u64,
    /// Run `registry gc` (with frontier pins) on every tick.
    pub gc: bool,
    /// Quality-drift sentinel (DESIGN.md §14): replay a tiny fixed-seed
    /// probe batch per served route every this many seconds, comparing
    /// sample digests against the pinned golden. 0 = sentinel off.
    /// Requires `tick_ms > 0` (the sentinel rides the scheduler thread).
    pub sentinel_secs: u64,
    /// Probe batch rows per sentinel replay.
    pub sentinel_rows: usize,
    /// Fixed RNG seed of the sentinel probe batch.
    pub sentinel_seed: u64,
    /// Relative val-RMSE tolerance for the post-hot-swap frontier
    /// regression check: alert when the freshly swapped artifact's
    /// val RMSE exceeds the previous one's by more than this fraction.
    pub sentinel_tol: f64,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            tick_ms: 0,
            refresh_secs: 0,
            gc: false,
            sentinel_secs: 0,
            sentinel_rows: 4,
            sentinel_seed: 0x5e17,
            sentinel_tol: 0.1,
        }
    }
}

/// Observability knobs (DESIGN.md §13): request tracing and the JSONL
/// lifecycle event sink. Tracing is on by default — recording is O(1) into
/// a preallocated ring and never touches sample bytes.
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Request tracing on/off. Off assigns no request ids and records no
    /// spans; sample bytes are identical either way.
    pub trace: bool,
    /// Span ring capacity (spans, not requests). Overflow overwrites the
    /// oldest span and bumps the `trace_dropped` counter.
    pub trace_ring: usize,
    /// Trace every Nth request (1 = all).
    pub trace_sample_n: u64,
    /// JSONL lifecycle event log path ("" = disabled).
    pub event_log: String,
    /// Rotate the event log (to `<name>.1`) past this size.
    pub event_log_max_bytes: u64,
    /// Solver flight recorder (DESIGN.md §14): per-step probe hook
    /// recording state/velocity magnitudes (and dopri5 accept/reject +
    /// error norms) per (route, step). Off by default — the probe reads
    /// every step's state, which costs more than span tracing.
    pub probe: bool,
    /// NaN/Inf quarantine guard: scan solve state at step boundaries and
    /// abort (+ quarantine the artifact) on non-finite rows. Scan-only —
    /// healthy-sample bytes are identical with the guard on or off.
    pub guard: bool,
    /// Kernel-phase timers in the fused solve path (stack_rng /
    /// model_eval / tensor_ops / scatter), for `server profile` and the
    /// `bespoke_solve_phase_ms` Prometheus histograms.
    pub phases: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: true,
            trace_ring: 4096,
            trace_sample_n: 1,
            event_log: String::new(),
            event_log_max_bytes: 1 << 20,
            probe: false,
            guard: false,
            phases: false,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Config {
    pub serve: ServeConfig,
    pub train: TrainConfig,
    pub eval: EvalConfig,
    pub registry: RegistryConfig,
    pub quality: QualityConfig,
    pub schedule: ScheduleConfig,
    pub obs: ObsConfig,
    /// Directory for trained thetas and experiment reports.
    pub out_dir: String,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = Value::parse(&text).context("parsing config JSON")?;
        let mut cfg = Config::default();
        cfg.apply(&v)?;
        Ok(cfg)
    }

    /// Merge a JSON object over the current values (missing keys keep
    /// defaults; unknown keys are rejected to catch typos).
    pub fn apply(&mut self, v: &Value) -> Result<()> {
        for (section, sv) in v.as_obj()? {
            match section.as_str() {
                "serve" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "addr" => self.serve.addr = val.as_str()?.to_string(),
                            "max_batch" => self.serve.max_batch = val.as_usize()?,
                            // "max_wait_ms" kept as an alias for old configs
                            "max_wait_ms" => {
                                self.serve.fuse_window_us = val.as_usize()? as u64 * 1000
                            }
                            "fuse_window_us" => {
                                self.serve.fuse_window_us = val.as_usize()? as u64
                            }
                            "fuse_max_rows" => self.serve.fuse_max_rows = val.as_usize()?,
                            // "workers" kept as an alias for old configs
                            "workers" | "workers_per_route" => {
                                self.serve.workers_per_route = val.as_usize()?
                            }
                            "compute_threads" => self.serve.compute_threads = val.as_usize()?,
                            "idle_timeout_ms" => {
                                self.serve.idle_timeout_ms = val.as_usize()? as u64
                            }
                            "drain_grace_ms" => {
                                self.serve.drain_grace_ms = val.as_usize()? as u64
                            }
                            "backend" => self.serve.backend = Backend::parse(val.as_str()?)?,
                            "backend_overrides" => {
                                let mut overrides = Vec::new();
                                for (model, b) in val.as_obj()? {
                                    overrides.push((model.clone(), Backend::parse(b.as_str()?)?));
                                }
                                self.serve.backend_overrides = overrides;
                            }
                            _ => anyhow::bail!("unknown serve key {k:?}"),
                        }
                    }
                }
                "train" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "iters" => self.train.iters = val.as_usize()?,
                            "lr" => self.train.lr = val.as_f64()? as f32,
                            "seed" => self.train.seed = val.as_usize()? as u64,
                            "pool_batches" => self.train.pool_batches = val.as_usize()?,
                            "refresh_every" => self.train.refresh_every = val.as_usize()?,
                            "gt_tol" => self.train.gt_tol = val.as_f64()?,
                            "val_batches" => self.train.val_batches = val.as_usize()?,
                            "val_every" => self.train.val_every = val.as_usize()?,
                            "ablation" => self.train.ablation = val.as_str()?.to_string(),
                            "snap_velocity" => {
                                self.train.snap_velocity = val.as_str()?.to_string()
                            }
                            "family" => self.train.family = val.as_str()?.to_string(),
                            "window" => self.train.window = val.as_usize()?,
                            _ => anyhow::bail!("unknown train key {k:?}"),
                        }
                    }
                }
                "eval" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "metric_samples" => self.eval.metric_samples = val.as_usize()?,
                            "gt_tol" => self.eval.gt_tol = val.as_f64()?,
                            "seed" => self.eval.seed = val.as_usize()? as u64,
                            _ => anyhow::bail!("unknown eval key {k:?}"),
                        }
                    }
                }
                "registry" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "root" => self.registry.root = val.as_str()?.to_string(),
                            "max_jobs" => self.registry.max_jobs = val.as_usize()?,
                            "keep_last_k" => self.registry.keep_last_k = val.as_usize()?,
                            "max_pending" => self.registry.max_pending = val.as_usize()?,
                            "retry_max_attempts" => {
                                self.registry.retry_max_attempts = val.as_usize()?
                            }
                            "retry_base_ms" => {
                                self.registry.retry_base_ms = val.as_usize()? as u64
                            }
                            "retry_cap_ms" => {
                                self.registry.retry_cap_ms = val.as_usize()? as u64
                            }
                            _ => anyhow::bail!("unknown registry key {k:?}"),
                        }
                    }
                }
                "quality" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "grid" => {
                                let mut grid = Vec::new();
                                for g in val.as_arr()? {
                                    let n = g.as_usize()?;
                                    if n == 0 {
                                        anyhow::bail!("quality grid entries must be >= 1");
                                    }
                                    grid.push(n);
                                }
                                if grid.is_empty() {
                                    anyhow::bail!("quality grid must be non-empty");
                                }
                                self.quality.grid = grid;
                            }
                            "eval_batches" => self.quality.eval_batches = val.as_usize()?,
                            "max_eval_jobs" => self.quality.max_eval_jobs = val.as_usize()?,
                            "max_pending" => self.quality.max_pending = val.as_usize()?,
                            _ => anyhow::bail!("unknown quality key {k:?}"),
                        }
                    }
                }
                "schedule" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "tick_ms" => self.schedule.tick_ms = val.as_usize()? as u64,
                            "refresh_secs" => self.schedule.refresh_secs = val.as_usize()? as u64,
                            "gc" => self.schedule.gc = val.as_bool()?,
                            "sentinel_secs" => {
                                self.schedule.sentinel_secs = val.as_usize()? as u64
                            }
                            "sentinel_rows" => {
                                let n = val.as_usize()?;
                                if n == 0 {
                                    anyhow::bail!("schedule sentinel_rows must be >= 1");
                                }
                                self.schedule.sentinel_rows = n;
                            }
                            "sentinel_seed" => {
                                self.schedule.sentinel_seed = val.as_usize()? as u64
                            }
                            "sentinel_tol" => {
                                let t = val.as_f64()?;
                                if !t.is_finite() || t < 0.0 {
                                    anyhow::bail!("schedule sentinel_tol must be finite and >= 0");
                                }
                                self.schedule.sentinel_tol = t;
                            }
                            _ => anyhow::bail!("unknown schedule key {k:?}"),
                        }
                    }
                }
                "obs" => {
                    for (k, val) in sv.as_obj()? {
                        match k.as_str() {
                            "trace" => self.obs.trace = val.as_bool()?,
                            "trace_ring" => {
                                let n = val.as_usize()?;
                                if n == 0 {
                                    anyhow::bail!("obs trace_ring must be >= 1");
                                }
                                self.obs.trace_ring = n;
                            }
                            "trace_sample_n" => {
                                let n = val.as_usize()? as u64;
                                if n == 0 {
                                    anyhow::bail!("obs trace_sample_n must be >= 1");
                                }
                                self.obs.trace_sample_n = n;
                            }
                            "event_log" => self.obs.event_log = val.as_str()?.to_string(),
                            "event_log_max_bytes" => {
                                self.obs.event_log_max_bytes = val.as_usize()? as u64
                            }
                            "probe" => self.obs.probe = val.as_bool()?,
                            "guard" => self.obs.guard = val.as_bool()?,
                            "phases" => self.obs.phases = val.as_bool()?,
                            _ => anyhow::bail!("unknown obs key {k:?}"),
                        }
                    }
                }
                "out_dir" => self.out_dir = sv.as_str()?.to_string(),
                _ => anyhow::bail!("unknown config section {section:?}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_override() {
        let mut cfg = Config::default();
        assert_eq!(cfg.train.lr, 2e-3);
        assert_eq!(cfg.registry.root, "out/registry");
        assert_eq!(cfg.registry.max_jobs, 1);
        assert_eq!(cfg.train.family, "stationary");
        assert_eq!(cfg.train.window, 2);
        let v = Value::parse(
            r#"{"train": {"iters": 42, "ablation": "time-only", "family": "bns", "window": 3},
                "serve": {"max_batch": 8, "workers_per_route": 4, "compute_threads": 2,
                          "fuse_window_us": 250, "fuse_max_rows": 16,
                          "idle_timeout_ms": 30000, "drain_grace_ms": 1500},
                "registry": {"root": "/tmp/reg", "max_jobs": 2, "keep_last_k": 5,
                             "max_pending": 16, "retry_max_attempts": 3,
                             "retry_base_ms": 100, "retry_cap_ms": 2000},
                "schedule": {"tick_ms": 60000, "refresh_secs": 3600, "gc": true},
                "out_dir": "/tmp/x"}"#,
        )
        .unwrap();
        cfg.apply(&v).unwrap();
        assert_eq!(cfg.train.iters, 42);
        assert_eq!(cfg.train.ablation, "time-only");
        assert_eq!(cfg.train.family, "bns");
        assert_eq!(cfg.train.window, 3);
        assert_eq!(cfg.serve.max_batch, 8);
        assert_eq!(cfg.serve.workers_per_route, 4);
        assert_eq!(cfg.serve.compute_threads, 2);
        assert_eq!(cfg.serve.fuse_window_us, 250);
        assert_eq!(cfg.serve.fuse_max_rows, 16);
        assert_eq!(cfg.serve.idle_timeout_ms, 30_000);
        assert_eq!(cfg.serve.drain_grace_ms, 1_500);
        assert_eq!(cfg.registry.max_pending, 16);
        assert_eq!(cfg.registry.retry_max_attempts, 3);
        assert_eq!(cfg.registry.retry_base_ms, 100);
        assert_eq!(cfg.registry.retry_cap_ms, 2_000);
        assert_eq!(cfg.schedule.tick_ms, 60_000);
        assert_eq!(cfg.schedule.refresh_secs, 3_600);
        assert!(cfg.schedule.gc);
        // legacy gather-window alias still parses (ms -> us)
        let v_wait = Value::parse(r#"{"serve": {"max_wait_ms": 3}}"#).unwrap();
        cfg.apply(&v_wait).unwrap();
        assert_eq!(cfg.serve.fuse_window_us, 3_000);
        assert_eq!(cfg.registry.root, "/tmp/reg");
        assert_eq!(cfg.registry.max_jobs, 2);
        assert_eq!(cfg.registry.keep_last_k, 5);
        // legacy alias still parses
        let v_alias = Value::parse(r#"{"serve": {"workers": 7}}"#).unwrap();
        cfg.apply(&v_alias).unwrap();
        assert_eq!(cfg.serve.workers_per_route, 7);
        assert_eq!(cfg.train.lr, 2e-3); // untouched default
        assert_eq!(cfg.out_dir, "/tmp/x");
    }

    #[test]
    fn unknown_keys_rejected() {
        let mut cfg = Config::default();
        let v = Value::parse(r#"{"train": {"learning_rate": 0.1}}"#).unwrap();
        assert!(cfg.apply(&v).is_err());
        let v2 = Value::parse(r#"{"bogus": {}}"#).unwrap();
        assert!(cfg.apply(&v2).is_err());
        let v3 = Value::parse(r#"{"registry": {"rootdir": "x"}}"#).unwrap();
        assert!(cfg.apply(&v3).is_err());
        let v4 = Value::parse(r#"{"quality": {"nfe_grid": [1]}}"#).unwrap();
        assert!(cfg.apply(&v4).is_err());
        let v5 = Value::parse(r#"{"schedule": {"cron": "* * * * *"}}"#).unwrap();
        assert!(cfg.apply(&v5).is_err());
        let v6 = Value::parse(r#"{"obs": {"ring": 8}}"#).unwrap();
        assert!(cfg.apply(&v6).is_err());
    }

    #[test]
    fn obs_section() {
        let mut cfg = Config::default();
        assert!(cfg.obs.trace);
        assert_eq!(cfg.obs.trace_ring, 4096);
        assert_eq!(cfg.obs.trace_sample_n, 1);
        assert!(cfg.obs.event_log.is_empty());
        // The numerics hooks default off: they are the only obs features
        // that touch the solve loop, so silence must be the default.
        assert!(!cfg.obs.probe && !cfg.obs.guard && !cfg.obs.phases);
        let v = Value::parse(
            r#"{"obs": {"trace": false, "trace_ring": 128, "trace_sample_n": 10,
                        "event_log": "/tmp/ev.jsonl", "event_log_max_bytes": 65536,
                        "probe": true, "guard": true, "phases": true}}"#,
        )
        .unwrap();
        cfg.apply(&v).unwrap();
        assert!(!cfg.obs.trace);
        assert_eq!(cfg.obs.trace_ring, 128);
        assert_eq!(cfg.obs.trace_sample_n, 10);
        assert_eq!(cfg.obs.event_log, "/tmp/ev.jsonl");
        assert_eq!(cfg.obs.event_log_max_bytes, 65_536);
        assert!(cfg.obs.probe && cfg.obs.guard && cfg.obs.phases);
        // Zero ring / sample_n are config errors, not silent clamps.
        for bad in [
            r#"{"obs": {"trace_ring": 0}}"#,
            r#"{"obs": {"trace_sample_n": 0}}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(cfg.apply(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn backend_selection_keys() {
        let mut cfg = Config::default();
        assert_eq!(cfg.serve.backend, Backend::Auto);
        assert!(cfg.serve.backend_overrides.is_empty());
        assert_eq!(cfg.serve.backend_for("anything"), Backend::Auto);
        let v = Value::parse(
            r#"{"serve": {"backend": "hlo",
                          "backend_overrides": {"checker2-ot": "analytic"}}}"#,
        )
        .unwrap();
        cfg.apply(&v).unwrap();
        assert_eq!(cfg.serve.backend, Backend::Hlo);
        assert_eq!(cfg.serve.backend_for("checker2-ot"), Backend::Analytic);
        assert_eq!(cfg.serve.backend_for("other"), Backend::Hlo);
        // invalid backend names are config errors, not clamps
        for bad in [
            r#"{"serve": {"backend": "gpu"}}"#,
            r#"{"serve": {"backend_overrides": {"m": "fast"}}}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(cfg.apply(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn lifecycle_defaults_are_off() {
        let cfg = Config::default();
        assert_eq!(cfg.serve.idle_timeout_ms, 0);
        assert_eq!(cfg.serve.drain_grace_ms, 5_000);
        assert_eq!(cfg.registry.max_pending, 0);
        assert_eq!(cfg.registry.retry_max_attempts, 0);
        assert_eq!(cfg.quality.max_pending, 0);
        assert_eq!(cfg.schedule.tick_ms, 0);
        assert_eq!(cfg.schedule.refresh_secs, 0);
        assert!(!cfg.schedule.gc);
        assert_eq!(cfg.schedule.sentinel_secs, 0);
    }

    #[test]
    fn sentinel_schedule_knobs() {
        let mut cfg = Config::default();
        assert_eq!(cfg.schedule.sentinel_rows, 4);
        assert!((cfg.schedule.sentinel_tol - 0.1).abs() < 1e-12);
        let v = Value::parse(
            r#"{"schedule": {"sentinel_secs": 30, "sentinel_rows": 2,
                             "sentinel_seed": 99, "sentinel_tol": 0.25}}"#,
        )
        .unwrap();
        cfg.apply(&v).unwrap();
        assert_eq!(cfg.schedule.sentinel_secs, 30);
        assert_eq!(cfg.schedule.sentinel_rows, 2);
        assert_eq!(cfg.schedule.sentinel_seed, 99);
        assert!((cfg.schedule.sentinel_tol - 0.25).abs() < 1e-12);
        for bad in [
            r#"{"schedule": {"sentinel_rows": 0}}"#,
            r#"{"schedule": {"sentinel_tol": -1.0}}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(cfg.apply(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn quality_section() {
        let mut cfg = Config::default();
        assert_eq!(cfg.quality.grid, vec![1, 2, 4, 8, 16]);
        assert_eq!(cfg.quality.eval_batches, 4);
        assert_eq!(cfg.quality.max_eval_jobs, 1);
        let v = Value::parse(
            r#"{"quality": {"grid": [2, 4], "eval_batches": 2, "max_eval_jobs": 3}}"#,
        )
        .unwrap();
        cfg.apply(&v).unwrap();
        assert_eq!(cfg.quality.grid, vec![2, 4]);
        assert_eq!(cfg.quality.eval_batches, 2);
        assert_eq!(cfg.quality.max_eval_jobs, 3);
        // zero grid entries and empty grids are config errors
        for bad in [r#"{"quality": {"grid": [0]}}"#, r#"{"quality": {"grid": []}}"#] {
            let v = Value::parse(bad).unwrap();
            assert!(cfg.apply(&v).is_err(), "should reject {bad}");
        }
    }
}
