//! The coordinator core: a per-(model, solver) **worker pool** with a
//! cross-request **fusion plane** over the fixed-shape HLO executables.
//! Each route owns one shared job queue (`Mutex<VecDeque> + Condvar`)
//! drained by `workers_per_route` threads, so concurrent requests to one
//! route overlap solves instead of serializing behind a single worker.
//!
//! Fusion (DESIGN.md §10): a fixed-grid Bespoke/RK/transfer step is
//! lockstep across rows, so concurrent requests on one route ride a single
//! fused model evaluation per stage. A worker that pops a job gathers
//! compatible batch-mates for up to `fuse_window_us`, stacks each
//! request's seed-derived noise rows into one tensor
//! ([`Tensor::stack_rows`]), drives a single reusable [`SolveSession`]
//! over the fused batch, and scatters the result rows back to each
//! waiting request. Adaptive solvers (dopri5) bypass fusion — their step
//! acceptance couples rows through the batch error norm — and mismatched
//! specs never meet (the route key *is* the resolved spec).
//!
//! The invariant that makes fusion safe: every kernel in the hot loop is
//! row-independent, so a request's samples are **byte-identical** whether
//! it was fused with neighbors or solved alone, for any fusion grouping
//! (pinned by `rust/tests/fusion_equivalence.rs`). Output is likewise
//! identical for any pool size: noise streams are forked per request
//! chunk, not per worker.
//!
//! Registry-resolved specs (`bespoke:model=M:n=8`) are re-resolved against
//! the artifact registry on every request; when a better artifact lands
//! (e.g. from an in-server training job) the stale route is retired and
//! the next request builds against the new checkpoint — hot-swap without a
//! restart (DESIGN.md §8).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::Metrics;
use crate::config::ServeConfig;
use crate::log_info;
use crate::models::{CountingModel, VelocityModel, Zoo};
use crate::quality::{Budget, Frontier, FrontierCache};
use crate::registry::Registry;
use crate::solvers::{Sampler, SessionProbe, SolveSession, SolverSpec, StepInfo};
use crate::tensor::Tensor;
use crate::util::numerics::{diff_rms, scan_non_finite, slice_rms, NumericError, Numerics};
use crate::util::obs::{Stage, Tracer};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct SampleRequest {
    pub model: String,
    /// Explicit solver spec; empty when `budget` drives solver selection.
    pub solver: String,
    pub n_samples: usize,
    pub seed: u64,
    pub return_samples: bool,
    /// Budget-aware routing: when set, the coordinator resolves the budget
    /// against the model's Pareto frontier to a concrete spec (DESIGN.md
    /// §9) instead of reading `solver`.
    pub budget: Option<Budget>,
}

/// A step-streamed trajectory request (see [`Coordinator::sample_traj`]).
#[derive(Clone, Debug)]
pub struct TrajRequest {
    pub model: String,
    pub solver: String,
    pub n_samples: usize,
    pub seed: u64,
    /// Emit every k-th step (k >= 1; the final step is always emitted).
    pub every: usize,
}

/// One emitted trajectory event: the solver state after a step.
#[derive(Clone, Debug)]
pub struct TrajStep {
    /// 0-based index of the completed solver step.
    pub step: usize,
    /// Total steps when known in advance (fixed-grid solvers).
    pub steps_total: Option<usize>,
    /// Integration time reached (solver-native axis).
    pub t: f32,
    /// Cumulative model evaluations so far.
    pub nfe_total: u64,
    pub done: bool,
    /// Per-sample state rows at this step.
    pub samples: Vec<Vec<f32>>,
}

#[derive(Clone, Debug)]
pub struct SampleResponse {
    pub n_samples: usize,
    /// Per-sample data rows (present when return_samples).
    pub samples: Option<Vec<Vec<f32>>>,
    pub nfe: u64,
    /// Model evaluations actually performed for this request, *including*
    /// attempts an adaptive error controller rejected. Equals the measured
    /// `nfe` (the counting wrapper sees every evaluation); kept as an
    /// explicit field so clients need no knowledge of which solvers reject.
    pub nfe_actual: u64,
    /// Solver step attempts rejected by the error controller (0 for
    /// fixed-grid solvers).
    pub steps_rejected: u64,
    /// Number of executable batches this request's rows were spread over.
    pub batches: u64,
    pub queue_ms: f64,
    pub latency_ms: f64,
    /// Wall time spent inside the solver (max over this request's
    /// launches) — the per-request share of `latency_ms` that was compute,
    /// not queueing/gathering.
    pub solve_ms: f64,
    /// Largest fused-launch row count this request's chunks rode in (its
    /// own rows included). Equal to the chunk size when it solved alone.
    pub fused_rows: u64,
}

/// One chunk of a request (<= model batch rows), awaiting a worker.
struct Job {
    rows: usize,
    rng: Rng,
    want_samples: bool,
    enqueued: Instant,
    /// Request trace id when this chunk's request is sampled for tracing
    /// (DESIGN.md §13). Observation only: carries no influence on RNG
    /// streams, chunking, or fusion grouping.
    trace_id: Option<u64>,
    reply: SyncSender<Result<ChunkDone>>,
}

struct ChunkDone {
    samples: Option<Vec<Vec<f32>>>,
    nfe: u64,
    /// Evaluations including rejected adaptive attempts (== `nfe`; see
    /// [`SampleResponse::nfe_actual`]).
    nfe_actual: u64,
    /// Rejected step attempts in this chunk's launch.
    steps_rejected: u64,
    queue_ms: f64,
    /// Solver wall time of the launch this chunk rode in.
    solve_ms: f64,
    /// Total request rows in that launch (this chunk's included).
    fused_rows: u64,
}

/// The one shutdown handshake for a route's worker pool: set `closed`,
/// wake every waiter. Workers drain remaining queued jobs, then exit.
fn close_route(q: &RouteQueue) {
    q.closed.store(true, Ordering::SeqCst);
    q.ready.notify_all();
}

/// Marker error: a request raced a route retirement (hot-swap) or worker
/// loss and should be retried against a freshly resolved route. `submit`
/// retries these internally up to a small bound; only a persistent
/// failure escapes to the client.
#[derive(Debug)]
struct RouteRetired(String);

impl std::fmt::Display for RouteRetired {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "workers for route {} are gone (retired or crashed)", self.0)
    }
}

impl std::error::Error for RouteRetired {}

/// A route's shared job queue: `submit` pushes and signals; the route's
/// worker pool drains with dynamic batching.
struct RouteQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
    /// Set when the coordinator drops so idle workers exit.
    closed: AtomicBool,
    /// Live workers draining this queue; decremented on worker exit (panic
    /// included) so submit() can fail fast instead of queueing forever.
    workers_alive: std::sync::atomic::AtomicUsize,
}

impl RouteQueue {
    fn push(&self, job: Job) {
        self.jobs.lock().unwrap().push_back(job);
        self.ready.notify_one();
    }
}

/// Decrements the route's live-worker count when a worker thread exits,
/// whether cleanly or by panic.
struct WorkerAliveGuard(Arc<RouteQueue>);

impl Drop for WorkerAliveGuard {
    fn drop(&mut self) {
        if self.0.workers_alive.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last worker out (clean shutdown or panic): drop any queued
            // jobs so their reply senders close and blocked submitters get
            // "worker dropped reply" instead of hanging forever.
            self.0.jobs.lock().unwrap().clear();
        }
    }
}

/// The request router + batching executor.
pub struct Coordinator {
    zoo: Arc<Zoo>,
    /// Behind a mutex so `{"cmd":"reload"}` / SIGHUP can swap `[serve]`
    /// knobs on a live server ([`Coordinator::reload_serve`]). Workers
    /// capture a copy at spawn; a reload retires every route so the next
    /// request respawns pools under the new knobs.
    cfg: Mutex<ServeConfig>,
    pub metrics: Arc<Metrics>,
    routes: Mutex<BTreeMap<String, Arc<RouteQueue>>>,
    /// Artifact registry for `bespoke:model=...` specs (None = registry
    /// specs are rejected).
    registry: Option<Arc<Registry>>,
    /// Per-model Pareto frontiers over the registry's scorecards, for
    /// budget-aware routing (None whenever `registry` is None).
    frontiers: Option<FrontierCache>,
    /// Hot-swap bookkeeping: `model/<registry spec>` -> currently resolved
    /// concrete spec. When a fresher artifact changes the resolution, the
    /// stale route is retired and the next request builds against the new
    /// checkpoint — no restart.
    resolved: Mutex<BTreeMap<String, String>>,
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for q in self.routes.lock().unwrap().values() {
            close_route(q);
        }
    }
}

impl Coordinator {
    pub fn new(zoo: Arc<Zoo>, cfg: ServeConfig) -> Coordinator {
        Coordinator {
            zoo,
            cfg: Mutex::new(cfg),
            metrics: Arc::new(Metrics::default()),
            routes: Mutex::new(BTreeMap::new()),
            registry: None,
            frontiers: None,
            resolved: Mutex::new(BTreeMap::new()),
        }
    }

    /// A copy of the live `[serve]` knobs.
    pub fn serve_cfg(&self) -> ServeConfig {
        self.cfg.lock().unwrap().clone()
    }

    /// Hot-reload the `[serve]` knobs (DESIGN.md §12): install the new
    /// config, then retire every live route so the next request respawns
    /// its worker pool under the new batching/fusion parameters. Retirement
    /// is the same mechanism hot-swap uses — retired workers drain their
    /// queued jobs before exiting and racing requests retry against the
    /// fresh route — so no in-flight request is dropped.
    pub fn reload_serve(&self, new_cfg: ServeConfig) {
        *self.cfg.lock().unwrap() = new_cfg;
        let keys: Vec<String> = self.routes.lock().unwrap().keys().cloned().collect();
        for key in &keys {
            self.retire_route(key);
        }
        self.metrics.record_event("serve_reloads");
        log_info!("serve config reloaded; retired {} route(s)", keys.len());
    }

    /// Drain for shutdown: close every route (workers finish queued jobs,
    /// then exit — the fusion-plane flush) and wait up to `grace` for all
    /// worker pools to wind down. Returns true when every worker exited in
    /// time.
    pub fn drain(&self, grace: Duration) -> bool {
        let queues: Vec<Arc<RouteQueue>> = {
            let mut routes = self.routes.lock().unwrap();
            let qs: Vec<Arc<RouteQueue>> = routes.values().cloned().collect();
            routes.clear();
            qs
        };
        for q in &queues {
            close_route(q);
        }
        let deadline = Instant::now() + grace;
        loop {
            let alive: usize =
                queues.iter().map(|q| q.workers_alive.load(Ordering::SeqCst)).sum();
            if alive == 0 {
                return true;
            }
            if Instant::now() >= deadline {
                log_info!("[drain] {alive} route worker(s) still busy after grace window");
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// A coordinator that can serve registry-resolved specs
    /// (`bespoke:model=M:n=8`) and budget-aware requests, hot-swapping
    /// freshly registered artifacts into live routes.
    pub fn with_registry(zoo: Arc<Zoo>, cfg: ServeConfig, registry: Arc<Registry>) -> Coordinator {
        let mut c = Coordinator::new(zoo, cfg);
        c.frontiers = Some(FrontierCache::new(registry.clone()));
        c.registry = Some(registry);
        c
    }

    /// The model's current Pareto frontier (for the `frontier` command).
    pub fn frontier(&self, model: &str) -> Result<Arc<Frontier>> {
        let fc = self.frontiers.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "this coordinator has no artifact registry attached; \
                 frontiers need registered scorecards"
            )
        })?;
        // Frontiers exist for known models only — a typo'd model name gets
        // an error, not an empty frontier.
        self.zoo.manifest().model(model)?;
        fc.frontier(model)
    }

    /// Resolve a budget against the model's frontier to a concrete spec.
    /// Records `budget_routed` / `budget_unsatisfiable` metric events.
    fn resolve_budget(&self, model: &str, budget: &Budget) -> Result<(String, SolverSpec)> {
        let fc = self.frontiers.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "request has a budget, but this coordinator has no artifact \
                 registry attached (budgets resolve against scorecard \
                 frontiers)"
            )
        })?;
        match fc.resolve(model, budget) {
            Ok(point) => {
                // Artifact-bound points are re-resolved against *this*
                // process's registry: the scorecard's stored path spelling
                // came from the eval host's registry root (possibly a
                // different cwd or machine), so the binding — not the
                // string — is authoritative. Baseline points parse their
                // stored spec directly. Either way the route key is the
                // resolved spec, shared with explicit-spec requests.
                let spec = match &point.artifact {
                    Some((key, version)) => {
                        let registry =
                            self.registry.as_ref().expect("frontiers imply a registry");
                        let rec = registry.find(key, *version).with_context(|| {
                            format!(
                                "frontier references {} v{version}, which is no \
                                 longer in the registry (gc without frontier pins?)",
                                key.label()
                            )
                        })?;
                        SolverSpec::Bespoke {
                            path: registry.theta_path(&rec).to_string_lossy().into_owned(),
                        }
                    }
                    None => SolverSpec::parse(&point.solver).with_context(|| {
                        format!("frontier point carries an unparseable spec {:?}", point.solver)
                    })?,
                };
                self.metrics.record_event("budget_routed");
                log_info!("budget {budget} for {model} -> {spec}");
                Ok((spec.to_string(), spec))
            }
            Err(e) => {
                self.metrics.record_event("budget_unsatisfiable");
                Err(e)
            }
        }
    }

    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }

    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    /// Canonicalize a request's solver spec. Registry-resolved bespoke
    /// specs are rewritten to the concrete `bespoke:path=...` of the
    /// current best artifact; when that resolution differs from the one a
    /// live route was built with, the stale route is retired (drained and
    /// shut down) so the next request hot-swaps the new artifact in.
    ///
    /// Returns the canonical route-key string and the buildable typed spec
    /// (the spec is threaded through to `route()` as a value, never
    /// re-parsed — checkpoint paths may contain characters the string
    /// grammar reserves, e.g. ':').
    ///
    /// The `resolved` lock is held across resolution + swap, so swaps are
    /// serialized and always compare against the freshest registry state.
    /// A request that resolved just before a swap may still recreate its
    /// (now retired) route; such a route serves that request with the
    /// artifact that was best at resolution time and then idles — bounded
    /// by the number of swaps, never served to post-swap requests.
    fn resolve_solver(&self, model: &str, solver: &str) -> Result<(String, SolverSpec)> {
        let spec = SolverSpec::parse(solver)?;
        if !spec.needs_registry() {
            return Ok((spec.to_string(), spec));
        }
        let registry = self.registry.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "solver {spec} is registry-resolved, but this coordinator \
                 has no artifact registry attached"
            )
        })?;
        let alias = format!("{model}/{spec}");
        let mut map = self.resolved.lock().unwrap();
        let resolved_spec = registry.resolve_spec(&spec)?;
        let resolved = resolved_spec.to_string();
        match map.get(&alias).cloned() {
            Some(old) if old == resolved => {}
            Some(old) => {
                let stale_key = format!("{model}/{old}");
                self.retire_route(&stale_key);
                self.metrics.record_event("hot_swap");
                log_info!("hot-swap {alias}: {old} -> {resolved}");
                map.insert(alias, resolved.clone());
            }
            None => {
                map.insert(alias, resolved.clone());
            }
        }
        Ok((resolved, resolved_spec))
    }

    /// Drop a route and tell its workers to drain and exit. Queued jobs are
    /// still executed (workers pop until empty before honoring `closed`);
    /// requests that race the retirement observe [`RouteRetired`] and are
    /// retried by `submit`.
    fn retire_route(&self, key: &str) {
        if let Some(q) = self.routes.lock().unwrap().remove(key) {
            close_route(&q);
        }
    }

    /// Retire `key` only if it still maps to `expected` — lets a submitter
    /// that observed a dead pool evict it (so the retry respawns workers)
    /// without racing a concurrent respawn under the same key.
    fn retire_route_if(&self, key: &str, expected: &Arc<RouteQueue>) {
        let mut routes = self.routes.lock().unwrap();
        if routes.get(key).is_some_and(|q| Arc::ptr_eq(q, expected)) {
            if let Some(q) = routes.remove(key) {
                close_route(&q);
            }
        }
    }

    /// Rows per request chunk for a model batch size. This is the RNG-stream
    /// unit: `submit` forks one noise stream per chunk, and `sample_traj`
    /// mirrors the same layout so a given seed yields bit-identical samples
    /// from both paths.
    fn chunk_rows(&self, model_batch: usize) -> usize {
        self.cfg.lock().unwrap().max_batch.min(model_batch).max(1)
    }

    /// Blocking submit: routes, batches, executes, gathers.
    ///
    /// A request that races a hot-swap route retirement (its route's
    /// workers exited between `route()` and job delivery) is retried
    /// against a freshly resolved route instead of surfacing the internal
    /// "workers are gone" state to the client.
    pub fn submit(&self, req: &SampleRequest) -> Result<SampleResponse> {
        self.submit_traced(req, None)
    }

    /// [`Coordinator::submit`] with a request trace id (assigned by the
    /// server at accept); the id rides each chunk so the fusion plane can
    /// record enqueue → fused-launch → solve → scatter spans for it.
    pub fn submit_traced(
        &self,
        req: &SampleRequest,
        trace_id: Option<u64>,
    ) -> Result<SampleResponse> {
        const MAX_ROUTE_RETRIES: usize = 3;
        let mut attempt = 0;
        loop {
            match self.submit_attempt(req, trace_id) {
                Err(e)
                    if e.downcast_ref::<RouteRetired>().is_some()
                        && attempt < MAX_ROUTE_RETRIES =>
                {
                    attempt += 1;
                    log_info!("retrying submit after route retirement ({attempt})");
                }
                other => return other,
            }
        }
    }

    fn submit_attempt(
        &self,
        req: &SampleRequest,
        trace_id: Option<u64>,
    ) -> Result<SampleResponse> {
        let started = Instant::now();
        let (solver, spec) = match &req.budget {
            Some(budget) => {
                if !req.solver.is_empty() {
                    bail!("request carries both a solver and a budget; give one");
                }
                self.resolve_budget(&req.model, budget)?
            }
            None => self.resolve_solver(&req.model, &req.solver)?,
        };
        let key = format!("{}/{}", req.model, solver);
        let queue = self.route(&key, &req.model, &spec)?;

        let model_batch = self.zoo.manifest().model(&req.model)?.batch;
        let chunk_rows = self.chunk_rows(model_batch);

        // Split the request into chunks and fan out to the worker.
        let mut pending = Vec::new();
        let mut root_rng = Rng::new(req.seed);
        let mut remaining = req.n_samples;
        let mut chunk_idx = 0u64;
        while remaining > 0 {
            let rows = remaining.min(chunk_rows);
            let (tx, rx) = sync_channel(1);
            let job = Job {
                rows,
                rng: root_rng.fork(chunk_idx),
                want_samples: req.return_samples,
                enqueued: Instant::now(),
                trace_id,
                reply: tx,
            };
            if queue.workers_alive.load(Ordering::SeqCst) == 0 {
                self.retire_route_if(&key, &queue);
                return Err(anyhow::Error::new(RouteRetired(key.clone())));
            }
            if let Some(id) = trace_id {
                self.metrics.tracer().record(id, Stage::Enqueue, chunk_idx, rows as u64);
            }
            queue.push(job);
            // Close the check-then-push race: if the last worker died after
            // the check above, drain what we just queued so no reply sender
            // lingers, and fail this attempt.
            if queue.workers_alive.load(Ordering::SeqCst) == 0 {
                queue.jobs.lock().unwrap().clear();
                self.retire_route_if(&key, &queue);
                return Err(anyhow::Error::new(RouteRetired(key.clone())));
            }
            pending.push(rx);
            remaining -= rows;
            chunk_idx += 1;
        }

        let mut samples = req.return_samples.then(Vec::new);
        let mut nfe = 0u64;
        let mut nfe_actual = 0u64;
        let mut steps_rejected = 0u64;
        let mut queue_ms = 0.0f64;
        let mut solve_ms = 0.0f64;
        let mut fused_rows = 0u64;
        let batches = pending.len() as u64;
        for rx in pending {
            // A dropped reply sender means the route's workers exited with
            // our job still queued (retirement or panic) — retryable.
            let done = rx.recv().map_err(|_| {
                self.retire_route_if(&key, &queue);
                anyhow::Error::new(RouteRetired(key.clone()))
            })?;
            let done = match done {
                Ok(d) => d,
                Err(e) => return Err(self.on_chunk_error(e, &spec, &key)),
            };
            nfe += done.nfe;
            nfe_actual += done.nfe_actual;
            steps_rejected += done.steps_rejected;
            queue_ms = queue_ms.max(done.queue_ms);
            solve_ms = solve_ms.max(done.solve_ms);
            fused_rows = fused_rows.max(done.fused_rows);
            if let (Some(acc), Some(got)) = (samples.as_mut(), done.samples) {
                acc.extend(got);
            }
        }
        let latency_ms = started.elapsed().as_secs_f64() * 1e3;
        self.metrics
            .record_request(&key, req.n_samples, latency_ms, queue_ms, solve_ms);
        Ok(SampleResponse {
            n_samples: req.n_samples,
            samples,
            nfe,
            nfe_actual,
            steps_rejected,
            batches,
            queue_ms,
            latency_ms,
            solve_ms,
            fused_rows,
        })
    }

    /// A chunk came back with an error. When it is the numeric guard
    /// tripping ([`NumericError`]), this is the quarantine state machine
    /// (DESIGN.md §14): bump the quarantine counters, quarantine the
    /// registry artifact the route serves (path-form learned specs name a
    /// checkpoint), raise a structured alert, retire the route so the next
    /// request re-resolves against healthy artifacts, and re-raise the
    /// typed error with artifact attribution so the protocol layer emits
    /// the coded `numeric` rejection. Every other error passes through
    /// untouched.
    fn on_chunk_error(&self, e: anyhow::Error, spec: &SolverSpec, key: &str) -> anyhow::Error {
        let Some(found) = e.downcast_ref::<NumericError>() else {
            return e;
        };
        let mut ne = found.clone();
        self.metrics.numerics().record_quarantine();
        self.metrics.record_event("numeric_quarantine");
        let path = match spec {
            SolverSpec::Bespoke { path }
            | SolverSpec::Bns { path }
            | SolverSpec::Multistep { path } => Some(path.as_str()),
            _ => None,
        };
        if let (Some(registry), Some(path)) = (self.registry.as_ref(), path) {
            if let Some(rec) = registry.find_by_theta_path(path) {
                match registry.quarantine(&rec.key, rec.version) {
                    Ok(changed) => {
                        if changed {
                            log_info!(
                                "quarantined artifact {} v{} after numeric guard trip",
                                rec.key.label(),
                                rec.version
                            );
                        }
                        ne.artifact = Some((rec.key.label(), rec.version));
                    }
                    Err(err) => {
                        log_info!("failed to quarantine {}: {err:#}", rec.key.label());
                    }
                }
            }
        }
        self.metrics.numerics().push_alert("numeric_quarantine", key, &ne.to_string());
        // Retire the poisoned route: quarantined versions are excluded from
        // `best`, so the respawn resolves to a healthy artifact (or fails
        // loudly when none exists) instead of re-serving this one.
        self.retire_route(key);
        anyhow::Error::new(ne).context("sampler failed")
    }

    /// Step-streamed trajectory sampling: drives a [`crate::solvers::SolveSession`]
    /// on the caller's thread and invokes `on_step` with the intermediate
    /// state after every `every`-th solver step (and always for the final
    /// one). Trajectory requests bypass the dynamic batcher — they need
    /// per-step access to the state, so they run as one dedicated
    /// fixed-shape launch sequence.
    pub fn sample_traj(
        &self,
        req: &TrajRequest,
        on_step: &mut dyn FnMut(TrajStep) -> Result<()>,
    ) -> Result<SampleResponse> {
        let started = Instant::now();
        if req.n_samples == 0 {
            bail!("n_samples must be positive");
        }
        let (solver, spec) = self.resolve_solver(&req.model, &req.solver)?;
        let resolved = self
            .zoo
            .serving_model_for(&req.model, self.serve_cfg().backend_for(&req.model))?;
        if resolved.fell_back {
            self.metrics.record_event("backend_fallback");
        }
        let model = resolved.model;
        let sched = self.zoo.scheduler(&req.model)?;
        let sampler = spec.build(sched)?;
        let (b, d) = (model.batch(), model.dim());
        if req.n_samples > b {
            bail!(
                "trajectory requests are unbatched: n_samples {} exceeds the \
                 model batch {b} (split the request client-side)",
                req.n_samples
            );
        }
        let every = req.every.max(1);

        // Noise rows for this request; padding rows are zero (discarded).
        // Mirror submit()'s per-chunk RNG streams so the same seed yields
        // bit-identical samples from `sample` and `sample_traj`.
        let chunk_rows = self.chunk_rows(b);
        let mut data = vec![0.0f32; b * d];
        let mut root_rng = Rng::new(req.seed);
        let mut offset = 0usize;
        let mut chunk_idx = 0u64;
        while offset < req.n_samples {
            let cnt = (req.n_samples - offset).min(chunk_rows);
            let mut rng = root_rng.fork(chunk_idx);
            rng.fill_normal(&mut data[offset * d..(offset + cnt) * d]);
            offset += cnt;
            chunk_idx += 1;
        }
        let x0 = Tensor::new(data, vec![b, d])?;

        let key = format!("{}/{solver}", req.model);
        self.metrics.record_backend(&key, resolved.backend.name());
        let numerics = self.metrics.numerics();
        // Trajectory solves run the same probe/guard hooks as the fused
        // plane (the loop is its own launch, so no fused-launch spans).
        let hooks = numerics.step_hooks_on().then(|| StepHooks {
            numerics,
            tracer: self.metrics.tracer(),
            route: &key,
            traced: Vec::new(),
            dim: d,
        });
        let counting = CountingModel::new(model.as_ref());
        let mut session = sampler.begin(&x0)?;
        let steps_total = session.steps_total();
        let mut samples = Vec::new();
        let mut scratch = StepScratch::default();
        let mut last: Option<StepInfo> = None;
        while !session.is_done() {
            let info = session.step(&counting)?;
            if let Some(h) = &hooks {
                h.observe(&*session, &info, &mut scratch)?;
            }
            last = Some(info);
            if info.done || info.step % every == 0 {
                let rows: Vec<Vec<f32>> = (0..req.n_samples)
                    .map(|r| session.state().row(r).to_vec())
                    .collect();
                if info.done {
                    samples = rows.clone();
                }
                on_step(TrajStep {
                    step: info.step,
                    steps_total,
                    t: info.t,
                    nfe_total: counting.nfe(),
                    done: info.done,
                    samples: rows,
                })?;
            }
        }
        let probe = match &last {
            Some(info) => session.probe(info),
            None => SessionProbe::default(),
        };
        let nfe = counting.nfe();
        let latency_ms = started.elapsed().as_secs_f64() * 1e3;
        self.metrics.record_batch(&key, req.n_samples, b, nfe);
        self.metrics
            .record_request(&key, req.n_samples, latency_ms, 0.0, latency_ms);
        Ok(SampleResponse {
            n_samples: req.n_samples,
            samples: Some(samples),
            nfe,
            nfe_actual: nfe,
            steps_rejected: probe.rejected,
            batches: 1,
            queue_ms: 0.0,
            latency_ms,
            solve_ms: latency_ms,
            fused_rows: req.n_samples as u64,
        })
    }

    /// Route keys with live worker pools — the quality-drift sentinel's
    /// probe set.
    pub fn served_routes(&self) -> Vec<String> {
        self.routes.lock().unwrap().keys().cloned().collect()
    }

    /// Get (or lazily spawn) the worker pool for a (model, solver) route.
    fn route(&self, key: &str, model: &str, spec: &SolverSpec) -> Result<Arc<RouteQueue>> {
        if let Some(q) = self.routes.lock().unwrap().get(key) {
            return Ok(q.clone());
        }
        // Validate + load outside the lock (compilation can take a moment).
        // The backend choice comes from `[serve] backend` (plus per-model
        // overrides); an `auto` fallback to the analytic oracle is recorded
        // as a `backend_fallback` event and the resolved backend lands in
        // the route's `profile` output (DESIGN.md §15).
        let resolved = self.zoo.serving_model_for(model, self.serve_cfg().backend_for(model))?;
        if resolved.fell_back {
            self.metrics.record_event("backend_fallback");
        }
        self.metrics.record_backend(key, resolved.backend.name());
        let served = resolved.model;
        let sched = self.zoo.scheduler(model)?;
        let sampler: Arc<dyn Sampler> = Arc::from(spec.build(sched)?);
        if served.dim() == 0 {
            bail!("model {model} has zero dim");
        }
        // Fixed-grid solvers (rk/bespoke/transfer/bns/multistep/ab) are
        // lockstep across rows and join the fusion plane — the non-
        // stationary families keep per-row state (history rings) strictly
        // row-independent, so fused and solo solves stay byte-identical.
        // Adaptive dopri5 couples rows through the batch error norm, so
        // its requests always solve alone.
        let lockstep = !matches!(spec, SolverSpec::Dopri5 { .. });

        let route_cfg = self.serve_cfg();
        let mut routes = self.routes.lock().unwrap();
        if let Some(q) = routes.get(key) {
            return Ok(q.clone());
        }
        let n_workers = route_cfg.workers_per_route.max(1);
        let queue = Arc::new(RouteQueue {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
            workers_alive: std::sync::atomic::AtomicUsize::new(n_workers),
        });
        for wi in 0..n_workers {
            let worker_queue = queue.clone();
            let model = served.clone();
            let sampler = sampler.clone();
            let metrics = self.metrics.clone();
            let cfg = route_cfg.clone();
            let key_owned = key.to_string();
            let spawned = std::thread::Builder::new()
                .name(format!("worker-{key}-{wi}"))
                .spawn(move || {
                    worker_loop(worker_queue, model, sampler, lockstep, cfg, metrics, key_owned)
                });
            if let Err(e) = spawned {
                // Partial pool: tell the already-spawned workers to exit
                // (the queue never enters the routes map, so Coordinator's
                // Drop would not reach them).
                close_route(&queue);
                return Err(e.into());
            }
        }
        routes.insert(key.to_string(), queue.clone());
        log_info!("spawned {n_workers} worker(s) for route {key}");
        Ok(queue)
    }
}

fn worker_loop(
    queue: Arc<RouteQueue>,
    model: Arc<dyn VelocityModel>,
    sampler: Arc<dyn Sampler>,
    lockstep: bool,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    key: String,
) {
    let _alive = WorkerAliveGuard(queue.clone());
    let b = model.batch();
    let d = model.dim();
    // Rows one fused launch may carry: the fixed HLO batch bounds it, the
    // config knobs tighten it. `fuse_max_rows = 1` — or a non-lockstep
    // solver — disables cross-request fusion: every chunk solves alone.
    let cap = if lockstep {
        let clamp = cfg.max_batch.min(b).max(1);
        if cfg.fuse_max_rows == 0 {
            clamp
        } else {
            clamp.min(cfg.fuse_max_rows)
        }
    } else {
        1
    };
    let window = Duration::from_micros(cfg.fuse_window_us);
    let sampler_ref: &dyn Sampler = sampler.as_ref();
    // One SolveSession reused across launches: every launch is the same
    // padded [b, d] shape, so `init()` rewinds without reallocating the
    // stage buffers (and `init` == fresh `begin` bitwise, pinned by the
    // solver session tests).
    let mut session: Option<Box<dyn SolveSession + '_>> = None;

    loop {
        // Block until a job arrives (or the coordinator shuts down).
        let first = {
            let mut q = queue.jobs.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if queue.closed.load(Ordering::SeqCst) {
                    return;
                }
                q = queue.ready.wait(q).unwrap();
            }
        };

        let group = gather_mates(&queue, first, cap, window);
        if group.len() > 1 {
            let fused: usize = group.iter().map(|j| j.rows).sum();
            metrics.record_event("fuse_flush");
            metrics.record_event_add("fused_rows", fused as u64);
        }
        execute_fused(model.as_ref(), sampler_ref, &mut session, &metrics, &key, b, d, group);
    }
}

/// The fusion gather: collect batch-mates for `first` until the fused row
/// cap is reached or the gather window closes. A job whose rows would
/// overflow the cap stays queued for the next launch — jobs are never
/// split across launches. The queue lock is held only while peeking/
/// popping (and inside the condvar wait), never while executing, so
/// pool-mates drain the queue concurrently.
fn gather_mates(queue: &RouteQueue, first: Job, cap: usize, window: Duration) -> VecDeque<Job> {
    let mut group = VecDeque::new();
    let mut rows = first.rows;
    group.push_back(first);
    let deadline = Instant::now() + window;
    'gather: while rows < cap {
        let mut q = queue.jobs.lock().unwrap();
        loop {
            let take = match q.front() {
                Some(j) if rows + j.rows <= cap => true,
                Some(_) => {
                    // Next job would overflow the fused cap: flush now. The
                    // wake-up that delivered this job was consumed without a
                    // pop — re-signal so an idle pool-mate picks it up
                    // instead of it waiting out this worker's entire solve.
                    queue.ready.notify_one();
                    break 'gather;
                }
                None => false,
            };
            if take {
                let j = q.pop_front().expect("front() said non-empty");
                drop(q);
                rows += j.rows;
                group.push_back(j);
                continue 'gather;
            }
            if queue.closed.load(Ordering::SeqCst) {
                break 'gather;
            }
            let now = Instant::now();
            if now >= deadline {
                break 'gather;
            }
            let (guard, _timed_out) = queue.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }
    group
}

/// Run one fused group through a single solve: stack each job's
/// seed-derived noise rows into one zero-padded [b, d] batch
/// ([`stack_noise`]), drive the worker's reusable session to completion,
/// then scatter the result rows back to each waiting request.
/// Every job's noise comes from its own RNG stream — the exact bytes the
/// chunk would get solving alone — and every hot-loop kernel is
/// row-independent, so fusion never changes a request's samples.
#[allow(clippy::too_many_arguments)]
fn execute_fused<'s>(
    model: &dyn VelocityModel,
    sampler: &'s dyn Sampler,
    session: &mut Option<Box<dyn SolveSession + 's>>,
    metrics: &Metrics,
    key: &str,
    b: usize,
    d: usize,
    mut jobs: VecDeque<Job>,
) {
    let used: usize = jobs.iter().map(|j| j.rows).sum();

    // Fused-launch spans: every traced member records the launch under one
    // shared group id — the shared id is how a trace query reconstructs
    // which peer requests rode the same launch (DESIGN.md §13).
    let tracer = metrics.tracer();
    let launch_group = jobs
        .iter()
        .any(|j| j.trace_id.is_some())
        .then(|| tracer.next_group_id());
    if let Some(group) = launch_group {
        for j in jobs.iter() {
            if let Some(id) = j.trace_id {
                tracer.record(id, Stage::FuseLaunch, group, used as u64);
            }
        }
    }

    let numerics = metrics.numerics();
    let phases_on = numerics.phases_on();
    // Phase-timer shim: only interposed when `[obs] phases` is on, so the
    // default path keeps the bare model (no per-stage clock reads).
    let timed = TimedModel { inner: model, eval_ns: AtomicU64::new(0) };
    let base: &dyn VelocityModel = if phases_on { &timed } else { model };
    let counting = CountingModel::new(base);
    let hooks = numerics.step_hooks_on().then(|| StepHooks {
        numerics,
        tracer,
        route: key,
        traced: match launch_group {
            Some(group) => {
                jobs.iter().filter_map(|j| j.trace_id.map(|id| (id, group))).collect()
            }
            None => Vec::new(),
        },
        dim: d,
    });

    let solve_started = Instant::now();
    let stacked = stack_noise(&mut jobs, b, d);
    let stack_ms = solve_started.elapsed().as_secs_f64() * 1e3;
    let drive_started = Instant::now();
    let result =
        stacked.and_then(|x0| drive_session(sampler, session, &counting, &x0, hooks.as_ref()));
    let drive_ms = drive_started.elapsed().as_secs_f64() * 1e3;
    let solve_ms = solve_started.elapsed().as_secs_f64() * 1e3;
    let nfe = counting.nfe();
    metrics.record_batch(key, used.min(b), b, nfe);
    if phases_on {
        let eval_ms = timed.eval_ns.load(Ordering::Relaxed) as f64 / 1e6;
        numerics.record_phase(key, "stack_rng", stack_ms);
        numerics.record_phase(key, "model_eval", eval_ms);
        numerics.record_phase(key, "tensor_ops", (drive_ms - eval_ms).max(0.0));
    }

    if let Some(group) = launch_group {
        for j in jobs.iter() {
            if let Some(id) = j.trace_id {
                tracer.record(id, Stage::Solve, group, (solve_ms * 1e3) as u64);
            }
        }
    }

    match result {
        Ok((out, probe)) => {
            let scatter_started = Instant::now();
            let mut offset = 0usize;
            for j in jobs {
                let queue_ms = j.enqueued.elapsed().as_secs_f64() * 1e3;
                let samples = j.want_samples.then(|| {
                    (offset..offset + j.rows)
                        .map(|r| out.row(r).to_vec())
                        .collect::<Vec<_>>()
                });
                offset += j.rows;
                let trace = j.trace_id;
                let rows = j.rows;
                let _ = j.reply.send(Ok(ChunkDone {
                    samples,
                    nfe,
                    nfe_actual: nfe,
                    steps_rejected: probe.rejected,
                    queue_ms,
                    solve_ms,
                    fused_rows: used as u64,
                }));
                if let (Some(id), Some(group)) = (trace, launch_group) {
                    tracer.record(id, Stage::Scatter, group, rows as u64);
                }
            }
            if phases_on {
                numerics.record_phase(
                    key,
                    "scatter",
                    scatter_started.elapsed().as_secs_f64() * 1e3,
                );
            }
        }
        Err(e) => {
            // A failed solve may leave the reused session mid-flight;
            // rebuild it on the next launch.
            *session = None;
            // Guard trips travel typed so the submit layer can attribute +
            // quarantine and the protocol layer can emit the coded
            // rejection; everything else flattens to a message as before.
            let numeric = e.downcast_ref::<NumericError>().cloned();
            let msg = format!("{e:#}");
            for j in jobs {
                let err = match &numeric {
                    Some(ne) => anyhow::Error::new(ne.clone()).context("sampler failed"),
                    None => anyhow::anyhow!("sampler failed: {msg}"),
                };
                let _ = j.reply.send(Err(err));
            }
        }
    }
}

/// Phase-profiling shim around the route's model: forwards `eval` /
/// `eval_into` unchanged (bitwise-transparent), accumulating the wall time
/// spent inside the model — the `model_eval` kernel phase (DESIGN.md §14).
struct TimedModel<'a> {
    inner: &'a dyn VelocityModel,
    eval_ns: AtomicU64,
}

impl VelocityModel for TimedModel<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, x: &Tensor, t: f32) -> Result<Tensor> {
        let started = Instant::now();
        let out = self.inner.eval(x, t);
        self.eval_ns.fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    fn eval_into(&self, x: &Tensor, t: f32, out: &mut Tensor) -> Result<()> {
        let started = Instant::now();
        let r = self.inner.eval_into(x, t, out);
        self.eval_ns.fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        r
    }
}

/// Per-step observation context for [`drive_session`]: present only when
/// the flight-recorder probe or the NaN/Inf guard is on.
struct StepHooks<'a> {
    numerics: &'a Numerics,
    tracer: &'a Tracer,
    /// Route key `model/solver` — the flight-recorder bucket.
    route: &'a str,
    /// Traced `(request id, launch group)` pairs riding this launch, for
    /// `solve_step` trace spans.
    traced: Vec<(u64, u64)>,
    dim: usize,
}

/// Mutable per-solve scratch for the hooks: cumulative session-probe
/// counters (so per-step deltas can be derived) and the previous state
/// copy the velocity-magnitude proxy diffs against.
#[derive(Default)]
struct StepScratch {
    prev_probe: SessionProbe,
    prev_state: Vec<f32>,
}

impl StepHooks<'_> {
    /// Observe one completed step: guard scan first (a poisoned state must
    /// abort before it is recorded as if healthy), then flight-recorder
    /// stats and `solve_step` trace spans. Read-only with respect to the
    /// session — hooks on or off cannot change sample bytes.
    fn observe(
        &self,
        s: &dyn SolveSession,
        info: &StepInfo,
        scratch: &mut StepScratch,
    ) -> Result<()> {
        let state = s.state().data();
        if self.numerics.guard_on() {
            if let Some((row, _col)) = scan_non_finite(state, self.dim) {
                let solver = self.route.split_once('/').map_or(self.route, |(_, sp)| sp);
                return Err(anyhow::Error::new(NumericError {
                    step: info.step,
                    row,
                    solver: solver.to_string(),
                    artifact: None,
                }));
            }
        }
        if self.numerics.probe_on() {
            let probe = s.probe(info);
            let v_rms = (info.step > 0 && scratch.prev_state.len() == state.len())
                .then(|| diff_rms(state, &scratch.prev_state));
            self.numerics.record_step(
                self.route,
                info.step,
                slice_rms(state),
                v_rms,
                probe.err_norm,
                probe.accepted.saturating_sub(scratch.prev_probe.accepted),
                probe.rejected.saturating_sub(scratch.prev_probe.rejected),
            );
            scratch.prev_probe = probe;
            scratch.prev_state.clear();
            scratch.prev_state.extend_from_slice(state);
            for &(id, group) in &self.traced {
                self.tracer.record(id, Stage::SolveStep, group, info.step as u64);
            }
        }
        Ok(())
    }
}

/// The fused-batch gather: one zero-padded [b, d] noise tensor with each
/// job's rows filled in place from its own RNG stream — the in-place twin
/// of [`Tensor::stack_rows`] (which the equivalence tests use to rebuild
/// this layout), kept to a single allocation per launch.
fn stack_noise(jobs: &mut VecDeque<Job>, b: usize, d: usize) -> Result<Tensor> {
    let total: usize = jobs.iter().map(|j| j.rows).sum();
    if total > b {
        bail!("fused group of {total} rows exceeds the launch batch {b}");
    }
    let mut x0 = Tensor::zeros(&[b, d]);
    let mut offset = 0usize;
    for j in jobs.iter_mut() {
        j.rng.fill_normal(&mut x0.data_mut()[offset * d..(offset + j.rows) * d]);
        offset += j.rows;
    }
    Ok(x0)
}

/// Drive the worker's persistent session over `x0`: the first launch opens
/// it via [`Sampler::begin`], later launches rewind with
/// [`SolveSession::init`] and reuse its pre-allocated stage buffers.
/// Returns the final state plus the session's end-of-solve probe (for
/// `steps_rejected`; reading it is a few loads, so it is unconditional).
fn drive_session<'s>(
    sampler: &'s dyn Sampler,
    slot: &mut Option<Box<dyn SolveSession + 's>>,
    model: &dyn VelocityModel,
    x0: &Tensor,
    hooks: Option<&StepHooks<'_>>,
) -> Result<(Tensor, SessionProbe)> {
    match slot {
        Some(s) => s.init(x0)?,
        None => *slot = Some(sampler.begin(x0)?),
    }
    let s = slot.as_mut().expect("session just installed");
    let mut last: Option<StepInfo> = None;
    match hooks {
        // Passive fast path: with probe and guard off this is exactly the
        // pre-observability loop (plus one Copy of the small StepInfo).
        None => {
            while !s.is_done() {
                last = Some(s.step(model)?);
            }
        }
        Some(h) => {
            let mut scratch = StepScratch::default();
            while !s.is_done() {
                let info = s.step(model)?;
                h.observe(&**s, &info, &mut scratch)?;
                last = Some(info);
            }
        }
    }
    let probe = match &last {
        Some(info) => s.probe(info),
        None => SessionProbe::default(),
    };
    Ok((s.state().clone(), probe))
}
