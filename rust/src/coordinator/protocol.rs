//! JSONL wire protocol for the sampling server.
//!
//! Request (one JSON object per line):
//! ```json
//! {"cmd": "sample", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "n_samples": 64, "seed": 7, "return_samples": true}
//! {"cmd": "metrics"}
//! {"cmd": "list"}
//! {"cmd": "ping"}
//! ```
//!
//! Response: `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.

use anyhow::{bail, Result};

use super::batcher::{SampleRequest, SampleResponse};
use crate::json::Value;

#[derive(Debug)]
pub enum Command {
    Sample(SampleRequest),
    Metrics,
    List,
    Ping,
}

pub fn parse_command(line: &str) -> Result<Command> {
    let v = Value::parse(line)?;
    match v.get("cmd")?.as_str()? {
        "sample" => {
            let req = SampleRequest {
                model: v.get("model")?.as_str()?.to_string(),
                solver: v.get("solver")?.as_str()?.to_string(),
                n_samples: v.get("n_samples")?.as_usize()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.unwrap_or(0) as u64,
                return_samples: v
                    .get_opt("return_samples")
                    .map(|s| s.as_bool())
                    .transpose()?
                    .unwrap_or(false),
            };
            if req.n_samples == 0 {
                bail!("n_samples must be positive");
            }
            Ok(Command::Sample(req))
        }
        "metrics" => Ok(Command::Metrics),
        "list" => Ok(Command::List),
        "ping" => Ok(Command::Ping),
        other => bail!("unknown cmd {other:?}"),
    }
}

pub fn response_to_json(resp: &SampleResponse) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("n_samples", Value::Num(resp.n_samples as f64)),
        ("nfe", Value::Num(resp.nfe as f64)),
        ("batches", Value::Num(resp.batches as f64)),
        ("queue_ms", Value::Num(resp.queue_ms)),
        ("latency_ms", Value::Num(resp.latency_ms)),
    ];
    if let Some(s) = &resp.samples {
        fields.push((
            "samples",
            Value::Arr(s.iter().map(|row| Value::from_f32s(row)).collect()),
        ));
    }
    Value::obj(fields)
}

pub fn error_json(msg: &str) -> Value {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.into()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_command() {
        let c = parse_command(
            r#"{"cmd":"sample","model":"m","solver":"rk2:n=4","n_samples":8,"seed":3}"#,
        )
        .unwrap();
        match c {
            Command::Sample(r) => {
                assert_eq!(r.model, "m");
                assert_eq!(r.n_samples, 8);
                assert_eq!(r.seed, 3);
                assert!(!r.return_samples);
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn rejects_bad_commands() {
        assert!(parse_command("{}").is_err());
        assert!(parse_command(r#"{"cmd":"nope"}"#).is_err());
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","solver":"s","n_samples":0}"#
        )
        .is_err());
    }

    #[test]
    fn other_commands() {
        assert!(matches!(parse_command(r#"{"cmd":"ping"}"#).unwrap(), Command::Ping));
        assert!(matches!(parse_command(r#"{"cmd":"list"}"#).unwrap(), Command::List));
        assert!(matches!(parse_command(r#"{"cmd":"metrics"}"#).unwrap(), Command::Metrics));
    }
}
