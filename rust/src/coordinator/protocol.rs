//! JSONL wire protocol for the sampling server.
//!
//! Request (one JSON object per line):
//! ```json
//! {"cmd": "sample", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "n_samples": 64, "seed": 7, "return_samples": true}
//! {"cmd": "sample_traj", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "n_samples": 4, "seed": 7, "every": 2}
//! {"cmd": "metrics"}
//! {"cmd": "list"}
//! {"cmd": "ping"}
//! {"cmd": "train", "model": "checker2-ot", "n": 8, "base": "rk2",
//!  "ablation": "full", "iters": 300, "seed": 17}
//! {"cmd": "train", "model": "checker2-ot", "n": 8, "family": "bns"}
//! {"cmd": "train", "model": "checker2-ot", "n": 8, "base": "rk1",
//!  "family": "multistep", "window": 3}
//! {"cmd": "job_status", "job_id": 1}
//! {"cmd": "jobs"}
//! {"cmd": "evaluate", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "grid": [2, 4, 8], "seed": 7}
//! {"cmd": "eval_status", "job_id": 1}
//! {"cmd": "frontier", "model": "checker2-ot"}
//! {"cmd": "cancel_job", "job_id": 1, "kind": "train"}
//! {"cmd": "reload"}
//! {"cmd": "drain"}
//! {"cmd": "trace"}
//! {"cmd": "trace", "id": 42, "limit": 64}
//! {"cmd": "metrics_prom"}
//! {"cmd": "profile"}
//! {"cmd": "alerts"}
//! {"cmd": "alerts", "clear": true}
//! ```
//!
//! Response: `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.
//! Lifecycle rejections additionally carry a machine-readable `"code"`
//! (`"overloaded"`, `"draining"`, `"timeout"`, `"cancelled"`) so clients
//! can distinguish back-pressure from real failures (DESIGN.md §12). A
//! numeric-guard abort carries `"code": "numeric"` plus the trip site
//! (`step`, `row`, `solver`, and — when the route served a registry
//! checkpoint — `artifact` / `artifact_version`; DESIGN.md §14).
//!
//! `cancel_job` stops a queued/retrying job immediately or a running job at
//! its next checkpoint (`kind` selects the train or eval plane; default
//! train). `reload` re-reads the server's config file and atomically
//! applies the `[serve]`/`[quality]`/`[registry]` knobs; `drain` puts the
//! server into draining mode and begins a graceful shutdown.
//!
//! `sample` takes either a `solver` spec or a `budget` — an object with
//! exactly one of `{"nfe_max": N}`, `{"latency_ms": X}`,
//! `{"quality": "rmse<=X"}` — which the coordinator resolves against the
//! model's Pareto frontier to a concrete solver before routing (DESIGN.md
//! §9). `evaluate` enqueues an asynchronous scorecard sweep (poll with
//! `eval_status`); `frontier` returns the model's current Pareto frontier.
//!
//! `sample_traj` is the streaming command: the server emits one
//! `{"ok": true, "event": "step", ...}` line per solver step (subsampled by
//! `every`) with the intermediate states, then a final
//! `{"ok": true, "event": "done", ...}` summary line.
//!
//! `trace` returns recent request spans from the tracer ring (DESIGN.md
//! §13): with `"id"` it filters to one request (and reports the peer
//! request ids that shared its fused launches); `"limit"` caps the span
//! count (default 256). `metrics_prom` returns the Prometheus text
//! exposition as a single JSON line (`{"ok": true, "body": "..."}`).
//!
//! `profile` returns the numerical-plane observability state (DESIGN.md
//! §14): toggle flags, per-route kernel-phase timings, and the solver
//! flight recorder. `alerts` returns the structured alert ring the
//! quarantine guard and quality-drift sentinel feed; `"clear": true`
//! empties the ring after snapshotting. Both work while draining.
//!
//! `train` enqueues an asynchronous training job (`base`, `ablation`,
//! `family`, `window`, `iters`, `seed` optional; defaults rk2 / full /
//! stationary / server TrainConfig) and
//! replies immediately with `{"ok": true, "job_id": N, "state": "queued",
//! "coalesced": false}`; poll with `job_status`. Once `"state"` is
//! `"done"`, `{"cmd": "sample", "solver": "bespoke:model=M:n=K"}` resolves
//! the freshly registered artifact — no restart.

use anyhow::{bail, Result};

use super::batcher::{SampleRequest, SampleResponse, TrajRequest, TrajStep};
use crate::json::Value;
use crate::quality::{Budget, EvalJobSnapshot, EvalJobSpec, Frontier};
use crate::registry::{ArtifactRecord, EvalRecord, JobId, TrainJobSnapshot, TrainJobSpec};
use crate::solvers::theta::{Base, Family};

/// Which job plane a `cancel_job` addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Train,
    Eval,
}

#[derive(Debug)]
pub enum Command {
    Sample(SampleRequest),
    SampleTraj(TrajRequest),
    Metrics,
    List,
    Ping,
    Train(TrainJobSpec),
    JobStatus(JobId),
    Jobs,
    Evaluate(EvalJobSpec),
    EvalStatus(JobId),
    Frontier(String),
    CancelJob { id: JobId, kind: JobKind },
    Reload,
    Drain,
    /// Recent request spans, optionally filtered to one request id.
    Trace { id: Option<u64>, limit: usize },
    /// Prometheus text exposition of the metrics snapshot.
    MetricsProm,
    /// Numerical-plane observability snapshot: toggles, kernel-phase
    /// timings, flight recorder (DESIGN.md §14).
    Profile,
    /// Structured alert ring (quarantines, sentinel drift); `clear` empties
    /// the ring after snapshotting.
    Alerts { clear: bool },
}

pub fn parse_command(line: &str) -> Result<Command> {
    let v = Value::parse(line)?;
    match v.get("cmd")?.as_str()? {
        "sample" => {
            let budget = v.get_opt("budget").map(Budget::from_json).transpose()?;
            let solver = v
                .get_opt("solver")
                .map(|s| s.as_str())
                .transpose()?
                .unwrap_or("")
                .to_string();
            match (&budget, solver.is_empty()) {
                (None, true) => bail!("sample needs a solver spec or a budget"),
                (Some(_), false) => {
                    bail!("sample takes either solver or budget, not both")
                }
                _ => {}
            }
            let req = SampleRequest {
                model: v.get("model")?.as_str()?.to_string(),
                solver,
                n_samples: v.get("n_samples")?.as_usize()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.unwrap_or(0) as u64,
                return_samples: v
                    .get_opt("return_samples")
                    .map(|s| s.as_bool())
                    .transpose()?
                    .unwrap_or(false),
                budget,
            };
            if req.n_samples == 0 {
                bail!("n_samples must be positive");
            }
            Ok(Command::Sample(req))
        }
        "sample_traj" => {
            let req = TrajRequest {
                model: v.get("model")?.as_str()?.to_string(),
                solver: v.get("solver")?.as_str()?.to_string(),
                n_samples: v.get("n_samples")?.as_usize()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.unwrap_or(0) as u64,
                every: v.get_opt("every").map(|s| s.as_usize()).transpose()?.unwrap_or(1),
            };
            if req.n_samples == 0 {
                bail!("n_samples must be positive");
            }
            if req.every == 0 {
                bail!("every must be >= 1");
            }
            Ok(Command::SampleTraj(req))
        }
        "metrics" => Ok(Command::Metrics),
        "list" => Ok(Command::List),
        "ping" => Ok(Command::Ping),
        "train" => {
            let spec = TrainJobSpec {
                model: v.get("model")?.as_str()?.to_string(),
                base: Base::parse(
                    v.get_opt("base").map(|b| b.as_str()).transpose()?.unwrap_or("rk2"),
                )?,
                n: v.get("n")?.as_usize()?,
                ablation: v
                    .get_opt("ablation")
                    .map(|a| a.as_str())
                    .transpose()?
                    .unwrap_or("full")
                    .to_string(),
                family: match v.get_opt("family") {
                    Some(f) => Family::parse(f.as_str()?)?,
                    None => Family::Stationary,
                },
                window: v.get_opt("window").map(|s| s.as_usize()).transpose()?,
                iters: v.get_opt("iters").map(|s| s.as_usize()).transpose()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.map(|s| s as u64),
            };
            if spec.n == 0 {
                bail!("n must be >= 1");
            }
            if spec.iters == Some(0) {
                bail!("iters must be >= 1");
            }
            if spec.window == Some(0) {
                bail!("window must be >= 1");
            }
            Ok(Command::Train(spec))
        }
        "job_status" => Ok(Command::JobStatus(v.get("job_id")?.as_usize()? as JobId)),
        "jobs" => Ok(Command::Jobs),
        "evaluate" => {
            let mut grid = Vec::new();
            if let Some(gv) = v.get_opt("grid") {
                for g in gv.as_arr()? {
                    let n = g.as_usize()?;
                    if n == 0 {
                        bail!("grid entries must be >= 1");
                    }
                    grid.push(n);
                }
            }
            Ok(Command::Evaluate(EvalJobSpec {
                model: v.get("model")?.as_str()?.to_string(),
                solver: v.get("solver")?.as_str()?.to_string(),
                grid,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.map(|s| s as u64),
            }))
        }
        "eval_status" => Ok(Command::EvalStatus(v.get("job_id")?.as_usize()? as JobId)),
        "frontier" => Ok(Command::Frontier(v.get("model")?.as_str()?.to_string())),
        "cancel_job" => {
            let kind = match v.get_opt("kind").map(|k| k.as_str()).transpose()? {
                None | Some("train") => JobKind::Train,
                Some("eval") => JobKind::Eval,
                Some(other) => bail!("unknown job kind {other:?} (train or eval)"),
            };
            Ok(Command::CancelJob { id: v.get("job_id")?.as_usize()? as JobId, kind })
        }
        "reload" => Ok(Command::Reload),
        "drain" => Ok(Command::Drain),
        "trace" => {
            let limit =
                v.get_opt("limit").map(|s| s.as_usize()).transpose()?.unwrap_or(256);
            if limit == 0 {
                bail!("limit must be >= 1");
            }
            Ok(Command::Trace {
                id: v.get_opt("id").map(|s| s.as_usize()).transpose()?.map(|s| s as u64),
                limit,
            })
        }
        "metrics_prom" => Ok(Command::MetricsProm),
        "profile" => Ok(Command::Profile),
        "alerts" => Ok(Command::Alerts {
            clear: v.get_opt("clear").map(|c| c.as_bool()).transpose()?.unwrap_or(false),
        }),
        other => bail!("unknown cmd {other:?}"),
    }
}

/// NaN-safe number: non-finite -> JSON null (shared codec helper).
fn num_or_null(x: f64) -> Value {
    Value::num_or_null(x)
}

/// Compact artifact reference embedded in job/list responses.
pub fn artifact_json(rec: &ArtifactRecord) -> Value {
    Value::obj(vec![
        ("model", Value::Str(rec.key.model.clone())),
        ("base", Value::Str(rec.key.base.name().into())),
        ("n", Value::Num(rec.key.n as f64)),
        ("ablation", Value::Str(rec.key.ablation.clone())),
        ("family", Value::Str(rec.family.name().into())),
        ("version", Value::Num(rec.version as f64)),
        ("file", Value::Str(rec.file.clone())),
        ("content_hash", Value::Str(rec.content_hash.clone())),
        ("val_rmse", num_or_null(rec.val_rmse as f64)),
        ("gt_nfe", Value::Num(rec.gt_nfe as f64)),
        ("created_at", Value::Num(rec.created_at as f64)),
    ])
}

/// Per-attempt timeline of a job's lifecycle (queued → running → retrying
/// → done, with backoff waits), for `job_status` / `eval_status`.
fn timeline_json(events: &[crate::registry::AttemptEvent]) -> Value {
    Value::Arr(
        events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("event", Value::Str(e.event.into())),
                    ("attempt", Value::Num(e.attempt as f64)),
                    ("at_secs", Value::Num(e.at_secs)),
                ];
                if e.backoff_ms > 0.0 {
                    fields.push(("backoff_ms", Value::Num(e.backoff_ms)));
                }
                Value::obj(fields)
            })
            .collect(),
    )
}

/// One training job's status for `job_status` / `jobs` responses.
pub fn job_json(s: &TrainJobSnapshot) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("job_id", Value::Num(s.id as f64)),
        ("model", Value::Str(s.spec.model.clone())),
        ("base", Value::Str(s.spec.base.name().into())),
        ("n", Value::Num(s.spec.n as f64)),
        ("ablation", Value::Str(s.spec.ablation.clone())),
        ("family", Value::Str(s.spec.family.name().into())),
        ("state", Value::Str(s.state.name().into())),
        ("iters_done", Value::Num(s.iters_done as f64)),
        ("iters_total", Value::Num(s.iters_total as f64)),
        ("loss", num_or_null(s.loss as f64)),
        ("val_rmse", num_or_null(s.val_rmse as f64)),
        ("wall_secs", Value::Num(s.wall_secs)),
        ("attempts", Value::Num(s.attempts as f64)),
        ("cancel_requested", Value::Bool(s.cancel_requested)),
        ("timeline", timeline_json(&s.timeline)),
        ("loss_tail", Value::from_f32s(&s.tail)),
    ];
    if let Some(e) = &s.error {
        fields.push(("error", Value::Str(e.clone())));
    }
    if let Some(rec) = &s.artifact {
        fields.push(("artifact", artifact_json(rec)));
    }
    Value::obj(fields)
}

/// Scorecard reference embedded in eval-job responses — the manifest
/// serializer verbatim, so wire and store can't drift.
pub fn eval_record_json(rec: &EvalRecord) -> Value {
    rec.to_json()
}

/// One eval job's status for `eval_status` responses. Mirrors `job_json`;
/// `cells_done`/`cells_total` count scorecard cells, `last_rmse` is the
/// most recent cell's RMSE.
pub fn eval_job_json(s: &EvalJobSnapshot) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("job_id", Value::Num(s.id as f64)),
        ("model", Value::Str(s.spec.model.clone())),
        ("solver", Value::Str(s.spec.solver.clone())),
        (
            "grid",
            Value::Arr(s.spec.grid.iter().map(|&n| Value::Num(n as f64)).collect()),
        ),
        ("state", Value::Str(s.state.name().into())),
        ("cells_done", Value::Num(s.iters_done as f64)),
        ("cells_total", Value::Num(s.iters_total as f64)),
        ("last_rmse", num_or_null(s.val_rmse as f64)),
        ("wall_secs", Value::Num(s.wall_secs)),
        ("attempts", Value::Num(s.attempts as f64)),
        ("cancel_requested", Value::Bool(s.cancel_requested)),
        ("timeline", timeline_json(&s.timeline)),
        ("rmse_tail", Value::from_f32s(&s.tail)),
    ];
    if let Some(e) = &s.error {
        fields.push(("error", Value::Str(e.clone())));
    }
    if let Some(rec) = &s.artifact {
        fields.push(("scorecard", eval_record_json(rec)));
    }
    Value::obj(fields)
}

/// The `frontier` command response: the frontier JSON plus the `ok` flag.
pub fn frontier_json(f: &Frontier) -> Value {
    match f.to_json() {
        Value::Obj(mut m) => {
            m.insert("ok".to_string(), Value::Bool(true));
            Value::Obj(m)
        }
        other => other,
    }
}

/// One streamed `sample_traj` step event.
pub fn traj_step_json(s: &TrajStep) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("event", Value::Str("step".into())),
        ("step", Value::Num(s.step as f64)),
        ("t", Value::Num(s.t as f64)),
        ("nfe", Value::Num(s.nfe_total as f64)),
        ("done", Value::Bool(s.done)),
        (
            "samples",
            Value::Arr(s.samples.iter().map(|row| Value::from_f32s(row)).collect()),
        ),
    ];
    if let Some(total) = s.steps_total {
        fields.push(("steps_total", Value::Num(total as f64)));
    }
    Value::obj(fields)
}

/// The final `sample_traj` summary line (no sample payload; the last step
/// event already carried the final states).
pub fn traj_done_json(resp: &SampleResponse) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("event", Value::Str("done".into())),
        ("n_samples", Value::Num(resp.n_samples as f64)),
        ("nfe", Value::Num(resp.nfe as f64)),
        ("latency_ms", Value::Num(resp.latency_ms)),
    ])
}

pub fn response_to_json(resp: &SampleResponse) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("n_samples", Value::Num(resp.n_samples as f64)),
        ("nfe", Value::Num(resp.nfe as f64)),
        ("nfe_actual", Value::Num(resp.nfe_actual as f64)),
        ("steps_rejected", Value::Num(resp.steps_rejected as f64)),
        ("batches", Value::Num(resp.batches as f64)),
        ("queue_ms", Value::Num(resp.queue_ms)),
        ("latency_ms", Value::Num(resp.latency_ms)),
        ("solve_ms", Value::Num(resp.solve_ms)),
        ("fused_rows", Value::Num(resp.fused_rows as f64)),
    ];
    if let Some(s) = &resp.samples {
        fields.push((
            "samples",
            Value::Arr(s.iter().map(|row| Value::from_f32s(row)).collect()),
        ));
    }
    Value::obj(fields)
}

pub fn error_json(msg: &str) -> Value {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.into()))])
}

/// Error with a machine-readable code (`"overloaded"`, `"draining"`,
/// `"timeout"`, `"cancelled"`): lifecycle back-pressure that clients can
/// branch on without parsing the human-readable message.
pub fn error_json_coded(code: &str, msg: &str) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("code", Value::Str(code.into())),
        ("error", Value::Str(msg.into())),
    ])
}

/// The coded `numeric` rejection a guard trip produces (DESIGN.md §14):
/// the machine-readable trip site rides beside the human-readable message.
pub fn numeric_error_json(e: &crate::util::NumericError) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(false)),
        ("code", Value::Str("numeric".into())),
        ("error", Value::Str(format!("sampler failed: {e}"))),
        ("step", Value::Num(e.step as f64)),
        ("row", Value::Num(e.row as f64)),
        ("solver", Value::Str(e.solver.clone())),
    ];
    if let Some((key, ver)) = &e.artifact {
        fields.push(("artifact", Value::Str(key.clone())));
        fields.push(("artifact_version", Value::Num(*ver as f64)));
    }
    Value::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_command() {
        let c = parse_command(
            r#"{"cmd":"sample","model":"m","solver":"rk2:n=4","n_samples":8,"seed":3}"#,
        )
        .unwrap();
        match c {
            Command::Sample(r) => {
                assert_eq!(r.model, "m");
                assert_eq!(r.n_samples, 8);
                assert_eq!(r.seed, 3);
                assert!(!r.return_samples);
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn rejects_bad_commands() {
        assert!(parse_command("{}").is_err());
        assert!(parse_command(r#"{"cmd":"nope"}"#).is_err());
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","solver":"s","n_samples":0}"#
        )
        .is_err());
    }

    #[test]
    fn parses_sample_traj_command() {
        let c = parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"rk2:n=4","n_samples":2,"every":2}"#,
        )
        .unwrap();
        match c {
            Command::SampleTraj(r) => {
                assert_eq!(r.model, "m");
                assert_eq!(r.n_samples, 2);
                assert_eq!(r.every, 2);
                assert_eq!(r.seed, 0);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"s","n_samples":0}"#
        )
        .is_err());
        assert!(parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"s","n_samples":1,"every":0}"#
        )
        .is_err());
    }

    #[test]
    fn traj_events_serialize() {
        let step = TrajStep {
            step: 3,
            steps_total: Some(8),
            t: 0.5,
            nfe_total: 8,
            done: false,
            samples: vec![vec![1.0, 2.0]],
        };
        let v = traj_step_json(&step);
        assert_eq!(v.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("steps_total").unwrap().as_usize().unwrap(), 8);
        assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 1);
        // round-trips through the JSON writer/parser
        let back = Value::parse(&v.to_string_compact()).unwrap();
        assert!(!back.get("done").unwrap().as_bool().unwrap());
    }

    #[test]
    fn other_commands() {
        assert!(matches!(parse_command(r#"{"cmd":"ping"}"#).unwrap(), Command::Ping));
        assert!(matches!(parse_command(r#"{"cmd":"list"}"#).unwrap(), Command::List));
        assert!(matches!(parse_command(r#"{"cmd":"metrics"}"#).unwrap(), Command::Metrics));
        assert!(matches!(parse_command(r#"{"cmd":"jobs"}"#).unwrap(), Command::Jobs));
    }

    #[test]
    fn parses_train_command_with_defaults() {
        let c = parse_command(r#"{"cmd":"train","model":"m","n":8}"#).unwrap();
        match c {
            Command::Train(s) => {
                assert_eq!(s.model, "m");
                assert_eq!(s.n, 8);
                assert_eq!(s.base, Base::Rk2);
                assert_eq!(s.ablation, "full");
                assert_eq!(s.family, Family::Stationary);
                assert_eq!(s.window, None);
                assert_eq!(s.iters, None);
                assert_eq!(s.seed, None);
            }
            _ => panic!("wrong command"),
        }
        let c = parse_command(
            r#"{"cmd":"train","model":"m","n":4,"base":"rk1","ablation":"time-only","iters":50,"seed":3}"#,
        )
        .unwrap();
        match c {
            Command::Train(s) => {
                assert_eq!(s.base, Base::Rk1);
                assert_eq!(s.ablation, "time-only");
                assert_eq!(s.iters, Some(50));
                assert_eq!(s.seed, Some(3));
            }
            _ => panic!("wrong command"),
        }
        // non-stationary families and the multistep window parse through
        let c = parse_command(
            r#"{"cmd":"train","model":"m","n":4,"base":"rk1","family":"multistep","window":3}"#,
        )
        .unwrap();
        match c {
            Command::Train(s) => {
                assert_eq!(s.family, Family::Multistep);
                assert_eq!(s.window, Some(3));
            }
            _ => panic!("wrong command"),
        }
        match parse_command(r#"{"cmd":"train","model":"m","n":4,"family":"bns"}"#).unwrap() {
            Command::Train(s) => {
                assert_eq!(s.family, Family::Bns);
                assert_eq!(s.window, None);
            }
            _ => panic!("wrong command"),
        }
        // rejections: missing model/n, bad base, zero n/iters/window,
        // unknown family
        assert!(parse_command(r#"{"cmd":"train","n":4}"#).is_err());
        assert!(parse_command(r#"{"cmd":"train","model":"m"}"#).is_err());
        assert!(parse_command(r#"{"cmd":"train","model":"m","n":0}"#).is_err());
        assert!(parse_command(r#"{"cmd":"train","model":"m","n":4,"base":"rk9"}"#).is_err());
        assert!(parse_command(r#"{"cmd":"train","model":"m","n":4,"iters":0}"#).is_err());
        assert!(parse_command(r#"{"cmd":"train","model":"m","n":4,"family":"warp"}"#).is_err());
        assert!(parse_command(
            r#"{"cmd":"train","model":"m","n":4,"family":"multistep","window":0}"#
        )
        .is_err());
    }

    #[test]
    fn parses_lifecycle_commands() {
        match parse_command(r#"{"cmd":"cancel_job","job_id":4}"#).unwrap() {
            Command::CancelJob { id, kind } => {
                assert_eq!(id, 4);
                assert_eq!(kind, JobKind::Train);
            }
            _ => panic!("wrong command"),
        }
        match parse_command(r#"{"cmd":"cancel_job","job_id":2,"kind":"eval"}"#).unwrap() {
            Command::CancelJob { id, kind } => {
                assert_eq!(id, 2);
                assert_eq!(kind, JobKind::Eval);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"cancel_job"}"#).is_err());
        assert!(parse_command(r#"{"cmd":"cancel_job","job_id":1,"kind":"solve"}"#).is_err());
        assert!(matches!(parse_command(r#"{"cmd":"reload"}"#).unwrap(), Command::Reload));
        assert!(matches!(parse_command(r#"{"cmd":"drain"}"#).unwrap(), Command::Drain));
    }

    #[test]
    fn parses_trace_and_metrics_prom_commands() {
        match parse_command(r#"{"cmd":"trace"}"#).unwrap() {
            Command::Trace { id, limit } => {
                assert_eq!(id, None);
                assert_eq!(limit, 256);
            }
            _ => panic!("wrong command"),
        }
        match parse_command(r#"{"cmd":"trace","id":42,"limit":8}"#).unwrap() {
            Command::Trace { id, limit } => {
                assert_eq!(id, Some(42));
                assert_eq!(limit, 8);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"trace","limit":0}"#).is_err());
        assert!(matches!(
            parse_command(r#"{"cmd":"metrics_prom"}"#).unwrap(),
            Command::MetricsProm
        ));
    }

    #[test]
    fn parses_profile_and_alerts_commands() {
        assert!(matches!(parse_command(r#"{"cmd":"profile"}"#).unwrap(), Command::Profile));
        match parse_command(r#"{"cmd":"alerts"}"#).unwrap() {
            Command::Alerts { clear } => assert!(!clear),
            _ => panic!("wrong command"),
        }
        match parse_command(r#"{"cmd":"alerts","clear":true}"#).unwrap() {
            Command::Alerts { clear } => assert!(clear),
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"alerts","clear":3}"#).is_err());
    }

    #[test]
    fn numeric_errors_carry_the_trip_site() {
        use crate::util::NumericError;
        let e = NumericError {
            step: 2,
            row: 5,
            solver: "bespoke:path=p".into(),
            artifact: Some(("m/rk2/n4/full".into(), 3)),
        };
        let v = numeric_error_json(&e);
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "numeric");
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.get("row").unwrap().as_usize().unwrap(), 5);
        assert_eq!(v.get("artifact").unwrap().as_str().unwrap(), "m/rk2/n4/full");
        assert_eq!(v.get("artifact_version").unwrap().as_usize().unwrap(), 3);
        // without attribution the artifact fields are absent
        let bare = numeric_error_json(&NumericError {
            step: 0,
            row: 0,
            solver: "rk2:n=4".into(),
            artifact: None,
        });
        assert!(bare.get_opt("artifact").is_none());
    }

    #[test]
    fn coded_errors_carry_the_code() {
        let v = error_json_coded("draining", "server is draining");
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "draining");
        let back = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back.get("code").unwrap().as_str().unwrap(), "draining");
        // the plain error shape stays code-free
        assert!(error_json("x").get_opt("code").is_none());
    }

    #[test]
    fn parses_job_status_command() {
        match parse_command(r#"{"cmd":"job_status","job_id":7}"#).unwrap() {
            Command::JobStatus(id) => assert_eq!(id, 7),
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"job_status"}"#).is_err());
    }

    #[test]
    fn parses_budget_sample_command() {
        let c = parse_command(
            r#"{"cmd":"sample","model":"m","budget":{"nfe_max":8},"n_samples":4}"#,
        )
        .unwrap();
        match c {
            Command::Sample(r) => {
                assert_eq!(r.budget, Some(Budget::NfeMax(8)));
                assert!(r.solver.is_empty());
            }
            _ => panic!("wrong command"),
        }
        let c = parse_command(
            r#"{"cmd":"sample","model":"m","budget":{"quality":"rmse<=0.05"},"n_samples":4}"#,
        )
        .unwrap();
        match c {
            Command::Sample(r) => assert_eq!(r.budget, Some(Budget::RmseMax(0.05))),
            _ => panic!("wrong command"),
        }
        // solver and budget are mutually exclusive; one is required
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","solver":"rk2:n=4","budget":{"nfe_max":8},"n_samples":4}"#
        )
        .is_err());
        assert!(parse_command(r#"{"cmd":"sample","model":"m","n_samples":4}"#).is_err());
        // malformed budgets fail at parse time
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","budget":{"nfe_max":0},"n_samples":4}"#
        )
        .is_err());
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","budget":{"steps":4},"n_samples":4}"#
        )
        .is_err());
    }

    #[test]
    fn parses_evaluate_and_frontier_commands() {
        let c = parse_command(
            r#"{"cmd":"evaluate","model":"m","solver":"rk2:n=8","grid":[2,4,8],"seed":7}"#,
        )
        .unwrap();
        match c {
            Command::Evaluate(s) => {
                assert_eq!(s.model, "m");
                assert_eq!(s.solver, "rk2:n=8");
                assert_eq!(s.grid, vec![2, 4, 8]);
                assert_eq!(s.seed, Some(7));
            }
            _ => panic!("wrong command"),
        }
        // grid + seed optional
        match parse_command(r#"{"cmd":"evaluate","model":"m","solver":"dopri5"}"#).unwrap() {
            Command::Evaluate(s) => {
                assert!(s.grid.is_empty());
                assert_eq!(s.seed, None);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"evaluate","model":"m","solver":"s","grid":[0]}"#).is_err());
        assert!(parse_command(r#"{"cmd":"evaluate","model":"m"}"#).is_err());
        match parse_command(r#"{"cmd":"eval_status","job_id":3}"#).unwrap() {
            Command::EvalStatus(id) => assert_eq!(id, 3),
            _ => panic!("wrong command"),
        }
        match parse_command(r#"{"cmd":"frontier","model":"m"}"#).unwrap() {
            Command::Frontier(m) => assert_eq!(m, "m"),
            _ => panic!("wrong command"),
        }
        assert!(parse_command(r#"{"cmd":"frontier"}"#).is_err());
    }

    #[test]
    fn eval_job_json_shape() {
        use crate::quality::{EvalJobSnapshot, EvalJobSpec};
        use crate::registry::JobState;
        let snap = EvalJobSnapshot {
            id: 2,
            spec: EvalJobSpec {
                model: "m".into(),
                solver: "rk2:n=4".into(),
                grid: vec![2, 4],
                seed: None,
            },
            state: JobState::Running,
            iters_done: 1,
            iters_total: 2,
            loss: f32::NAN,
            val_rmse: 0.25,
            error: None,
            artifact: None,
            wall_secs: 0.5,
            attempts: 0,
            cancel_requested: false,
            timeline: vec![],
            tail: vec![],
        };
        let v = eval_job_json(&snap);
        assert_eq!(v.get("state").unwrap().as_str().unwrap(), "running");
        assert_eq!(v.get("cells_done").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("cells_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.get("grid").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("last_rmse").unwrap().as_f64().unwrap(), 0.25);
        // round-trips through the writer/parser
        let back = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back.get("job_id").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn job_json_is_nan_safe() {
        use crate::registry::{JobSnapshot, JobState, TrainJobSpec};
        let snap = JobSnapshot {
            id: 3,
            spec: TrainJobSpec {
                model: "m".into(),
                base: Base::Rk2,
                n: 4,
                ablation: "full".into(),
                family: Family::Stationary,
                window: None,
                iters: None,
                seed: None,
            },
            state: JobState::Queued,
            iters_done: 0,
            iters_total: 0,
            loss: f32::NAN,
            val_rmse: f32::NAN,
            error: None,
            artifact: None,
            wall_secs: 0.0,
            attempts: 0,
            cancel_requested: false,
            timeline: vec![crate::registry::AttemptEvent {
                event: "queued",
                attempt: 0,
                at_secs: 0.0,
                backoff_ms: 0.0,
            }],
            tail: vec![0.5, 0.25],
        };
        let v = job_json(&snap);
        assert_eq!(v.get("state").unwrap().as_str().unwrap(), "queued");
        assert!(matches!(v.get("loss").unwrap(), Value::Null));
        let tl = v.get("timeline").unwrap().as_arr().unwrap();
        assert_eq!(tl[0].get("event").unwrap().as_str().unwrap(), "queued");
        assert_eq!(v.get("loss_tail").unwrap().as_f32_vec().unwrap(), vec![0.5, 0.25]);
        // round-trips through the writer/parser
        let back = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back.get("job_id").unwrap().as_usize().unwrap(), 3);
    }
}
