//! JSONL wire protocol for the sampling server.
//!
//! Request (one JSON object per line):
//! ```json
//! {"cmd": "sample", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "n_samples": 64, "seed": 7, "return_samples": true}
//! {"cmd": "sample_traj", "model": "checker2-ot", "solver": "rk2:n=8",
//!  "n_samples": 4, "seed": 7, "every": 2}
//! {"cmd": "metrics"}
//! {"cmd": "list"}
//! {"cmd": "ping"}
//! ```
//!
//! Response: `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.
//!
//! `sample_traj` is the streaming command: the server emits one
//! `{"ok": true, "event": "step", ...}` line per solver step (subsampled by
//! `every`) with the intermediate states, then a final
//! `{"ok": true, "event": "done", ...}` summary line.

use anyhow::{bail, Result};

use super::batcher::{SampleRequest, SampleResponse, TrajRequest, TrajStep};
use crate::json::Value;

#[derive(Debug)]
pub enum Command {
    Sample(SampleRequest),
    SampleTraj(TrajRequest),
    Metrics,
    List,
    Ping,
}

pub fn parse_command(line: &str) -> Result<Command> {
    let v = Value::parse(line)?;
    match v.get("cmd")?.as_str()? {
        "sample" => {
            let req = SampleRequest {
                model: v.get("model")?.as_str()?.to_string(),
                solver: v.get("solver")?.as_str()?.to_string(),
                n_samples: v.get("n_samples")?.as_usize()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.unwrap_or(0) as u64,
                return_samples: v
                    .get_opt("return_samples")
                    .map(|s| s.as_bool())
                    .transpose()?
                    .unwrap_or(false),
            };
            if req.n_samples == 0 {
                bail!("n_samples must be positive");
            }
            Ok(Command::Sample(req))
        }
        "sample_traj" => {
            let req = TrajRequest {
                model: v.get("model")?.as_str()?.to_string(),
                solver: v.get("solver")?.as_str()?.to_string(),
                n_samples: v.get("n_samples")?.as_usize()?,
                seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.unwrap_or(0) as u64,
                every: v.get_opt("every").map(|s| s.as_usize()).transpose()?.unwrap_or(1),
            };
            if req.n_samples == 0 {
                bail!("n_samples must be positive");
            }
            if req.every == 0 {
                bail!("every must be >= 1");
            }
            Ok(Command::SampleTraj(req))
        }
        "metrics" => Ok(Command::Metrics),
        "list" => Ok(Command::List),
        "ping" => Ok(Command::Ping),
        other => bail!("unknown cmd {other:?}"),
    }
}

/// One streamed `sample_traj` step event.
pub fn traj_step_json(s: &TrajStep) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("event", Value::Str("step".into())),
        ("step", Value::Num(s.step as f64)),
        ("t", Value::Num(s.t as f64)),
        ("nfe", Value::Num(s.nfe_total as f64)),
        ("done", Value::Bool(s.done)),
        (
            "samples",
            Value::Arr(s.samples.iter().map(|row| Value::from_f32s(row)).collect()),
        ),
    ];
    if let Some(total) = s.steps_total {
        fields.push(("steps_total", Value::Num(total as f64)));
    }
    Value::obj(fields)
}

/// The final `sample_traj` summary line (no sample payload; the last step
/// event already carried the final states).
pub fn traj_done_json(resp: &SampleResponse) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("event", Value::Str("done".into())),
        ("n_samples", Value::Num(resp.n_samples as f64)),
        ("nfe", Value::Num(resp.nfe as f64)),
        ("latency_ms", Value::Num(resp.latency_ms)),
    ])
}

pub fn response_to_json(resp: &SampleResponse) -> Value {
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("n_samples", Value::Num(resp.n_samples as f64)),
        ("nfe", Value::Num(resp.nfe as f64)),
        ("batches", Value::Num(resp.batches as f64)),
        ("queue_ms", Value::Num(resp.queue_ms)),
        ("latency_ms", Value::Num(resp.latency_ms)),
    ];
    if let Some(s) = &resp.samples {
        fields.push((
            "samples",
            Value::Arr(s.iter().map(|row| Value::from_f32s(row)).collect()),
        ));
    }
    Value::obj(fields)
}

pub fn error_json(msg: &str) -> Value {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.into()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_command() {
        let c = parse_command(
            r#"{"cmd":"sample","model":"m","solver":"rk2:n=4","n_samples":8,"seed":3}"#,
        )
        .unwrap();
        match c {
            Command::Sample(r) => {
                assert_eq!(r.model, "m");
                assert_eq!(r.n_samples, 8);
                assert_eq!(r.seed, 3);
                assert!(!r.return_samples);
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn rejects_bad_commands() {
        assert!(parse_command("{}").is_err());
        assert!(parse_command(r#"{"cmd":"nope"}"#).is_err());
        assert!(parse_command(
            r#"{"cmd":"sample","model":"m","solver":"s","n_samples":0}"#
        )
        .is_err());
    }

    #[test]
    fn parses_sample_traj_command() {
        let c = parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"rk2:n=4","n_samples":2,"every":2}"#,
        )
        .unwrap();
        match c {
            Command::SampleTraj(r) => {
                assert_eq!(r.model, "m");
                assert_eq!(r.n_samples, 2);
                assert_eq!(r.every, 2);
                assert_eq!(r.seed, 0);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"s","n_samples":0}"#
        )
        .is_err());
        assert!(parse_command(
            r#"{"cmd":"sample_traj","model":"m","solver":"s","n_samples":1,"every":0}"#
        )
        .is_err());
    }

    #[test]
    fn traj_events_serialize() {
        let step = TrajStep {
            step: 3,
            steps_total: Some(8),
            t: 0.5,
            nfe_total: 8,
            done: false,
            samples: vec![vec![1.0, 2.0]],
        };
        let v = traj_step_json(&step);
        assert_eq!(v.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("steps_total").unwrap().as_usize().unwrap(), 8);
        assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 1);
        // round-trips through the JSON writer/parser
        let back = Value::parse(&v.to_string_compact()).unwrap();
        assert!(!back.get("done").unwrap().as_bool().unwrap());
    }

    #[test]
    fn other_commands() {
        assert!(matches!(parse_command(r#"{"cmd":"ping"}"#).unwrap(), Command::Ping));
        assert!(matches!(parse_command(r#"{"cmd":"list"}"#).unwrap(), Command::List));
        assert!(matches!(parse_command(r#"{"cmd":"metrics"}"#).unwrap(), Command::Metrics));
    }
}
