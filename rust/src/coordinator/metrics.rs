//! Serving metrics: per-(model, solver) counters and latency distributions.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Value;
use crate::util::timer::Percentiles;

#[derive(Default)]
struct Entry {
    requests: u64,
    samples: u64,
    batches: u64,
    /// Sum over batches of rows actually used (fill = used / capacity).
    rows_used: u64,
    rows_capacity: u64,
    nfe: u64,
    latency: Percentiles,
    queue: Percentiles,
    /// Per-request solver wall time (the compute share of latency; the
    /// fused-launch time the request's slowest chunk rode in).
    solve: Percentiles,
}

pub struct Metrics {
    started: Instant,
    inner: Mutex<BTreeMap<String, Entry>>,
    /// Named lifecycle counters (train_jobs_submitted/coalesced/done/failed,
    /// hot_swap, ...), surfaced under `"events"` in the snapshot.
    events: Mutex<BTreeMap<String, u64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            inner: Mutex::new(BTreeMap::new()),
            events: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    /// Bump a named lifecycle counter.
    pub fn record_event(&self, name: &str) {
        self.record_event_add(name, 1);
    }

    /// Add `n` to a named counter (e.g. `fused_rows` grows by the fused
    /// batch's row count per flush, not by 1).
    pub fn record_event_add(&self, name: &str, n: u64) {
        *self.events.lock().unwrap().entry(name.to_string()).or_default() += n;
    }

    /// Current value of a named counter (0 if never recorded).
    pub fn event_count(&self, name: &str) -> u64 {
        self.events.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn record_batch(&self, key: &str, rows_used: usize, capacity: usize, nfe: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(key.to_string()).or_default();
        e.batches += 1;
        e.rows_used += rows_used as u64;
        e.rows_capacity += capacity as u64;
        e.nfe += nfe;
    }

    pub fn record_request(
        &self,
        key: &str,
        n_samples: usize,
        latency_ms: f64,
        queue_ms: f64,
        solve_ms: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(key.to_string()).or_default();
        e.requests += 1;
        e.samples += n_samples as u64;
        e.latency.record(latency_ms);
        e.queue.record(queue_ms);
        e.solve.record(solve_ms);
    }

    pub fn snapshot(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64();
        let mut per_key = Vec::new();
        for (k, e) in g.iter() {
            let fill = if e.rows_capacity > 0 {
                e.rows_used as f64 / e.rows_capacity as f64
            } else {
                0.0
            };
            per_key.push((
                k.as_str(),
                Value::obj(vec![
                    ("requests", Value::Num(e.requests as f64)),
                    ("samples", Value::Num(e.samples as f64)),
                    ("batches", Value::Num(e.batches as f64)),
                    ("batch_fill", Value::Num(fill)),
                    ("nfe", Value::Num(e.nfe as f64)),
                    ("samples_per_sec", Value::Num(e.samples as f64 / uptime.max(1e-9))),
                    ("latency_p50_ms", Value::Num(e.latency.quantile(0.5))),
                    ("latency_p99_ms", Value::Num(e.latency.quantile(0.99))),
                    ("queue_p50_ms", Value::Num(e.queue.quantile(0.5))),
                    ("solve_p50_ms", Value::Num(e.solve.quantile(0.5))),
                    ("solve_p99_ms", Value::Num(e.solve.quantile(0.99))),
                ]),
            ));
        }
        let events = self.events.lock().unwrap();
        let events_json: Vec<(&str, Value)> = events
            .iter()
            .map(|(k, &v)| (k.as_str(), Value::Num(v as f64)))
            .collect();
        Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("uptime_secs", Value::Num(uptime)),
            ("per_route", Value::obj(per_key)),
            ("events", Value::obj(events_json)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch("m/rk2", 48, 64, 16);
        m.record_batch("m/rk2", 64, 64, 16);
        m.record_request("m/rk2", 48, 12.0, 1.0, 9.0);
        m.record_request("m/rk2", 64, 8.0, 0.5, 6.0);
        let snap = m.snapshot();
        let route = snap.get("per_route").unwrap().get("m/rk2").unwrap();
        assert_eq!(route.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(route.get("batches").unwrap().as_usize().unwrap(), 2);
        let fill = route.get("batch_fill").unwrap().as_f64().unwrap();
        assert!((fill - 112.0 / 128.0).abs() < 1e-9);
        assert!(route.get("latency_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(route.get("solve_p50_ms").unwrap().as_f64().unwrap() >= 6.0);
    }

    #[test]
    fn event_counters() {
        let m = Metrics::default();
        assert_eq!(m.event_count("hot_swap"), 0);
        m.record_event("hot_swap");
        m.record_event("hot_swap");
        m.record_event("train_jobs_done");
        m.record_event_add("fused_rows", 7);
        m.record_event_add("fused_rows", 3);
        assert_eq!(m.event_count("hot_swap"), 2);
        assert_eq!(m.event_count("fused_rows"), 10);
        let snap = m.snapshot();
        let ev = snap.get("events").unwrap();
        assert_eq!(ev.get("hot_swap").unwrap().as_usize().unwrap(), 2);
        assert_eq!(ev.get("train_jobs_done").unwrap().as_usize().unwrap(), 1);
    }
}
