//! Serving metrics: per-(model, solver) counters, bounded latency
//! histograms, windowed throughput, the request tracer, and exposition
//! (JSON + Prometheus text + optional JSONL lifecycle event sink).
//!
//! Memory is bounded by construction: each route holds three fixed-size
//! [`Histogram`]s and one [`WindowCounter`] — no per-request growth
//! (DESIGN.md §13).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::ObsConfig;
use crate::json::Value;
use crate::util::numerics::Numerics;
use crate::util::obs::{EventLog, Histogram, Tracer, WindowCounter};

#[derive(Default)]
struct Entry {
    requests: u64,
    samples: u64,
    batches: u64,
    /// Sum over batches of rows actually used (fill = used / capacity).
    rows_used: u64,
    rows_capacity: u64,
    nfe: u64,
    latency: Histogram,
    queue: Histogram,
    /// Per-request solver wall time (the compute share of latency; the
    /// fused-launch time the request's slowest chunk rode in).
    solve: Histogram,
    /// Samples completed per one-second slot, for windowed rates.
    sample_rate: WindowCounter,
}

/// Route totals used by loadgen's post-run reconciliation (client-side
/// accounting must match these deltas exactly — zero silent drops).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Totals {
    pub requests: u64,
    pub samples: u64,
    /// Rows actually solved across all batch launches (pad rows excluded);
    /// every requested row is solved exactly once, so this tracks samples.
    pub rows_used: u64,
}

pub struct Metrics {
    started: Instant,
    inner: Mutex<BTreeMap<String, Entry>>,
    /// Named lifecycle counters (train_jobs_submitted/coalesced/done/failed,
    /// hot_swap, ...), surfaced under `"events"` in the snapshot.
    events: Mutex<BTreeMap<String, u64>>,
    /// Request tracer (span ring). Lives here because `Metrics` is the one
    /// handle shared by the server, the coordinator and the job planes.
    tracer: Tracer,
    /// Optional JSONL sink for lifecycle events (drain / reload / retry /
    /// cancel / hot-swap), attached via the `[obs]` config table.
    event_log: Mutex<Option<Arc<EventLog>>>,
    /// Numerical-plane observability: flight recorder, quarantine guard
    /// toggles/counter, kernel-phase timers, alert ring (DESIGN.md §14).
    numerics: Numerics,
    /// Resolved compute backend per route (`"hlo"` / `"analytic"`),
    /// recorded when a route spawns and surfaced by `profile` and the
    /// snapshot (DESIGN.md §15).
    backends: Mutex<BTreeMap<String, &'static str>>,
}

/// Lifecycle events mirrored to the JSONL sink when one is attached.
fn is_lifecycle_event(name: &str) -> bool {
    matches!(
        name,
        "server_drains" | "serve_reloads" | "hot_swap" | "numeric_quarantine" | "sentinel_alert"
    ) || name.ends_with("_jobs_retried")
        || name.ends_with("_jobs_cancelled")
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            inner: Mutex::new(BTreeMap::new()),
            events: Mutex::new(BTreeMap::new()),
            tracer: Tracer::default(),
            event_log: Mutex::new(None),
            numerics: Numerics::default(),
            backends: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    /// The request tracer (span ring) shared by server, coordinator and
    /// job planes.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The numerical-plane observability block (DESIGN.md §14) shared by
    /// the workers (recording), the guard (quarantine counter), the
    /// sentinel (alerts) and the `profile`/`alerts` commands (exposition).
    pub fn numerics(&self) -> &Numerics {
        &self.numerics
    }

    /// Apply the `[obs]` config table: tracer on/off, ring size, sampling,
    /// numerics toggles, and the optional JSONL event sink. Safe to call
    /// again on reload.
    pub fn apply_obs(&self, cfg: &ObsConfig) -> Result<()> {
        self.tracer.configure(cfg.trace, cfg.trace_ring, cfg.trace_sample_n);
        self.numerics.configure(cfg.probe, cfg.guard, cfg.phases);
        let sink = if cfg.event_log.is_empty() {
            None
        } else {
            Some(Arc::new(EventLog::open(
                std::path::Path::new(&cfg.event_log),
                cfg.event_log_max_bytes,
            )?))
        };
        *self.event_log.lock().unwrap() = sink;
        Ok(())
    }

    fn now_sec(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Bump a named lifecycle counter.
    pub fn record_event(&self, name: &str) {
        self.record_event_add(name, 1);
    }

    /// Add `n` to a named counter (e.g. `fused_rows` grows by the fused
    /// batch's row count per flush, not by 1). Lifecycle events are
    /// mirrored to the JSONL sink when one is attached.
    pub fn record_event_add(&self, name: &str, n: u64) {
        *self.events.lock().unwrap().entry(name.to_string()).or_default() += n;
        if is_lifecycle_event(name) {
            let sink = self.event_log.lock().unwrap().clone();
            if let Some(log) = sink {
                log.log(name, &[("n", Value::Num(n as f64))]);
            }
        }
    }

    /// Current value of a named counter (0 if never recorded).
    pub fn event_count(&self, name: &str) -> u64 {
        self.events.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Record which compute backend serves a route (`"hlo"`/`"analytic"`,
    /// DESIGN.md §15). Last write wins: a hot-swap or reload that changes
    /// the resolution simply overwrites the entry.
    pub fn record_backend(&self, key: &str, backend: &'static str) {
        self.backends.lock().unwrap().insert(key.to_string(), backend);
    }

    /// The resolved backend for a route, if one was recorded.
    pub fn backend_for(&self, key: &str) -> Option<&'static str> {
        self.backends.lock().unwrap().get(key).copied()
    }

    pub fn record_batch(&self, key: &str, rows_used: usize, capacity: usize, nfe: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(key.to_string()).or_default();
        e.batches += 1;
        e.rows_used += rows_used as u64;
        e.rows_capacity += capacity as u64;
        e.nfe += nfe;
    }

    pub fn record_request(
        &self,
        key: &str,
        n_samples: usize,
        latency_ms: f64,
        queue_ms: f64,
        solve_ms: f64,
    ) {
        let now = self.now_sec();
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(key.to_string()).or_default();
        e.requests += 1;
        e.samples += n_samples as u64;
        e.latency.record_ms(latency_ms);
        e.queue.record_ms(queue_ms);
        e.solve.record_ms(solve_ms);
        e.sample_rate.record_at(now, n_samples as u64);
    }

    /// Request/sample/row totals summed across routes (reconciliation).
    pub fn totals(&self) -> Totals {
        let g = self.inner.lock().unwrap();
        let mut t = Totals::default();
        for e in g.values() {
            t.requests += e.requests;
            t.samples += e.samples;
            t.rows_used += e.rows_used;
        }
        t
    }

    /// JSON snapshot. The pre-§13 keys keep their exact names and meaning,
    /// except `samples_per_sec`, which now reports the trailing-60 s
    /// windowed rate (the lifetime average was meaningless after any idle
    /// stretch). Additions: `samples_per_sec_5m`, `latency_mean_ms`,
    /// `latency_max_ms`, `latency_buckets` (`[le_ms, count]` pairs), and a
    /// top-level `obs` section with tracer state.
    pub fn snapshot(&self) -> Value {
        let now = self.now_sec();
        let mut g = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64();
        let mut per_key = Vec::new();
        for (k, e) in g.iter_mut() {
            let fill = if e.rows_capacity > 0 {
                e.rows_used as f64 / e.rows_capacity as f64
            } else {
                0.0
            };
            per_key.push((
                k.clone(),
                Value::obj(vec![
                    ("requests", Value::Num(e.requests as f64)),
                    ("samples", Value::Num(e.samples as f64)),
                    ("batches", Value::Num(e.batches as f64)),
                    ("batch_fill", Value::Num(fill)),
                    ("nfe", Value::Num(e.nfe as f64)),
                    ("samples_per_sec", Value::Num(e.sample_rate.rate_at(now, 60))),
                    ("samples_per_sec_5m", Value::Num(e.sample_rate.rate_at(now, 300))),
                    ("latency_p50_ms", Value::Num(e.latency.quantile_ms(0.5))),
                    ("latency_p99_ms", Value::Num(e.latency.quantile_ms(0.99))),
                    ("latency_mean_ms", Value::Num(e.latency.mean_ms())),
                    ("latency_max_ms", Value::Num(e.latency.max_ms())),
                    ("latency_buckets", e.latency.buckets_json()),
                    ("queue_p50_ms", Value::Num(e.queue.quantile_ms(0.5))),
                    ("solve_p50_ms", Value::Num(e.solve.quantile_ms(0.5))),
                    ("solve_p99_ms", Value::Num(e.solve.quantile_ms(0.99))),
                ]),
            ));
        }
        let per_key_refs: Vec<(&str, Value)> =
            per_key.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        let events = self.events.lock().unwrap();
        let events_json: Vec<(&str, Value)> = events
            .iter()
            .map(|(k, &v)| (k.as_str(), Value::Num(v as f64)))
            .collect();
        let obs = Value::obj(vec![
            ("trace_enabled", Value::Bool(self.tracer.enabled())),
            ("trace_ring", Value::Num(self.tracer.ring_cap() as f64)),
            ("trace_sample_n", Value::Num(self.tracer.sample_n() as f64)),
            ("trace_spans", Value::Num(self.tracer.span_count() as f64)),
            ("trace_dropped", Value::Num(self.tracer.dropped() as f64)),
            ("numerics", self.numerics.flags_json()),
            ("numeric_quarantines", Value::Num(self.numerics.quarantines() as f64)),
            ("alerts_active", Value::Num(self.numerics.alerts_active() as f64)),
            ("alerts_total", Value::Num(self.numerics.alerts_total() as f64)),
        ]);
        let backends = self.backends_json();
        Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("uptime_secs", Value::Num(uptime)),
            ("per_route", Value::obj(per_key_refs)),
            ("backends", backends),
            ("events", Value::obj(events_json)),
            ("obs", obs),
        ])
    }

    /// Route → resolved backend name, as a JSON object (DESIGN.md §15).
    fn backends_json(&self) -> Value {
        let g = self.backends.lock().unwrap();
        let pairs: Vec<(&str, Value)> =
            g.iter().map(|(k, &b)| (k.as_str(), Value::Str(b.to_string()))).collect();
        Value::obj(pairs)
    }

    /// Prometheus text exposition (served by `metrics_prom` /
    /// `repro server metrics --format prom`). Histogram buckets are
    /// cumulative with a trailing `+Inf`, per the exposition format.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        fn esc(label: &str) -> String {
            label.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
        }
        fn hist(out: &mut String, name: &str, route: &str, h: &Histogram) {
            let _ = writeln!(out, "# TYPE {name} histogram");
            let mut cum = 0u64;
            for (le, c) in h.nonzero_buckets() {
                cum += c;
                let _ = writeln!(out, "{name}_bucket{{route=\"{route}\",le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(
                out,
                "{name}_bucket{{route=\"{route}\",le=\"+Inf\"}} {}",
                h.count()
            );
            let _ = writeln!(out, "{name}_sum{{route=\"{route}\"}} {}", h.sum_ms());
            let _ = writeln!(out, "{name}_count{{route=\"{route}\"}} {}", h.count());
        }
        let now = self.now_sec();
        let mut out = String::new();
        let _ = writeln!(out, "# TYPE bespoke_uptime_seconds gauge");
        let _ = writeln!(
            out,
            "bespoke_uptime_seconds {}",
            self.started.elapsed().as_secs_f64()
        );
        {
            let mut g = self.inner.lock().unwrap();
            for (counter, get) in [
                ("bespoke_requests_total", 0usize),
                ("bespoke_samples_total", 1),
                ("bespoke_batches_total", 2),
                ("bespoke_nfe_total", 3),
            ] {
                let _ = writeln!(out, "# TYPE {counter} counter");
                for (k, e) in g.iter() {
                    let v = match get {
                        0 => e.requests,
                        1 => e.samples,
                        2 => e.batches,
                        _ => e.nfe,
                    };
                    let _ = writeln!(out, "{counter}{{route=\"{}\"}} {v}", esc(k));
                }
            }
            let _ = writeln!(out, "# TYPE bespoke_batch_fill_ratio gauge");
            for (k, e) in g.iter() {
                let fill = if e.rows_capacity > 0 {
                    e.rows_used as f64 / e.rows_capacity as f64
                } else {
                    0.0
                };
                let _ = writeln!(out, "bespoke_batch_fill_ratio{{route=\"{}\"}} {fill}", esc(k));
            }
            let _ = writeln!(out, "# TYPE bespoke_samples_per_sec gauge");
            for (k, e) in g.iter_mut() {
                let _ = writeln!(
                    out,
                    "bespoke_samples_per_sec{{route=\"{}\"}} {}",
                    esc(k),
                    e.sample_rate.rate_at(now, 60)
                );
            }
            for (name, pick) in [
                ("bespoke_request_latency_ms", 0usize),
                ("bespoke_queue_ms", 1),
                ("bespoke_solve_ms", 2),
            ] {
                for (k, e) in g.iter() {
                    let h = match pick {
                        0 => &e.latency,
                        1 => &e.queue,
                        _ => &e.solve,
                    };
                    hist(&mut out, name, &esc(k), h);
                }
            }
        }
        let _ = writeln!(out, "# TYPE bespoke_events_total counter");
        for (k, v) in self.events.lock().unwrap().iter() {
            let _ = writeln!(out, "bespoke_events_total{{event=\"{}\"}} {v}", esc(k));
        }
        let _ = writeln!(out, "# TYPE bespoke_trace_dropped_total counter");
        let _ = writeln!(out, "bespoke_trace_dropped_total {}", self.tracer.dropped());
        // Numerical-plane exposition (DESIGN.md §14): quarantine counter,
        // alert gauge/counter, per-route rejected adaptive steps, and the
        // kernel-phase wall-time histograms.
        let _ = writeln!(out, "# TYPE bespoke_numeric_quarantine_total counter");
        let _ = writeln!(out, "bespoke_numeric_quarantine_total {}", self.numerics.quarantines());
        let _ = writeln!(out, "# TYPE bespoke_alerts_active gauge");
        let _ = writeln!(out, "bespoke_alerts_active {}", self.numerics.alerts_active());
        let _ = writeln!(out, "# TYPE bespoke_alerts_total counter");
        let _ = writeln!(out, "bespoke_alerts_total {}", self.numerics.alerts_total());
        let rejected = self.numerics.rejected_by_route();
        if !rejected.is_empty() {
            let _ = writeln!(out, "# TYPE bespoke_steps_rejected_total counter");
            for (route, n) in rejected {
                let _ =
                    writeln!(out, "bespoke_steps_rejected_total{{route=\"{}\"}} {n}", esc(&route));
            }
        }
        let phases = self.numerics.phase_hist_snapshot();
        if !phases.is_empty() {
            let name = "bespoke_solve_phase_ms";
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (route, phase, h) in phases {
                let labels = format!("route=\"{}\",phase=\"{phase}\"", esc(&route));
                let mut cum = 0u64;
                for (le, c) in h.nonzero_buckets() {
                    cum += c;
                    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {cum}");
                }
                let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
                let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum_ms());
                let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
            }
        }
        out
    }

    /// The `{"cmd":"profile"}` payload: numerics toggle state, the kernel-
    /// phase breakdown per route, the flight-recorder per-step stats, and
    /// the resolved compute backend per route (DESIGN.md §15).
    pub fn profile_json(&self) -> Value {
        Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("numerics", self.numerics.flags_json()),
            ("phases", self.numerics.phases_json()),
            ("flight", self.numerics.flight_json()),
            ("backends", self.backends_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch("m/rk2", 48, 64, 16);
        m.record_batch("m/rk2", 64, 64, 16);
        m.record_request("m/rk2", 48, 12.0, 1.0, 9.0);
        m.record_request("m/rk2", 64, 8.0, 0.5, 6.0);
        let snap = m.snapshot();
        let route = snap.get("per_route").unwrap().get("m/rk2").unwrap();
        assert_eq!(route.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(route.get("batches").unwrap().as_usize().unwrap(), 2);
        let fill = route.get("batch_fill").unwrap().as_f64().unwrap();
        assert!((fill - 112.0 / 128.0).abs() < 1e-9);
        assert!(route.get("latency_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(route.get("solve_p50_ms").unwrap().as_f64().unwrap() >= 6.0);
        // §13 additions ride alongside the backward-compatible keys.
        assert!(!route.get("latency_buckets").unwrap().as_arr().unwrap().is_empty());
        assert!(snap.get("obs").unwrap().get("trace_enabled").is_ok());
    }

    #[test]
    fn samples_per_sec_is_windowed_not_lifetime() {
        let m = Metrics::default();
        m.record_request("m/rk2", 120, 1.0, 0.1, 0.5);
        let snap = m.snapshot();
        let route = snap.get("per_route").unwrap().get("m/rk2").unwrap();
        // 120 samples in the first (partial) second of a fresh counter:
        // the windowed rate reports ~120/s, not 120/uptime→∞ or a
        // lifetime-diluted figure.
        let rate = route.get("samples_per_sec").unwrap().as_f64().unwrap();
        assert!((rate - 120.0).abs() < 1e-9, "rate {rate}");
    }

    #[test]
    fn event_counters() {
        let m = Metrics::default();
        assert_eq!(m.event_count("hot_swap"), 0);
        m.record_event("hot_swap");
        m.record_event("hot_swap");
        m.record_event("train_jobs_done");
        m.record_event_add("fused_rows", 7);
        m.record_event_add("fused_rows", 3);
        assert_eq!(m.event_count("hot_swap"), 2);
        assert_eq!(m.event_count("fused_rows"), 10);
        let snap = m.snapshot();
        let ev = snap.get("events").unwrap();
        assert_eq!(ev.get("hot_swap").unwrap().as_usize().unwrap(), 2);
        assert_eq!(ev.get("train_jobs_done").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn totals_sum_routes() {
        let m = Metrics::default();
        m.record_request("a", 4, 1.0, 0.1, 0.5);
        m.record_request("b", 6, 1.0, 0.1, 0.5);
        m.record_batch("a", 4, 8, 10);
        m.record_batch("b", 6, 8, 10);
        let t = m.totals();
        assert_eq!((t.requests, t.samples, t.rows_used), (2, 10, 10));
    }

    #[test]
    fn prometheus_text_parses() {
        let m = Metrics::default();
        m.record_request("m/rk2:n=4", 8, 3.5, 0.2, 2.0);
        m.record_batch("m/rk2:n=4", 8, 8, 32);
        m.record_event("hot_swap");
        let text = m.prometheus_text();
        // Minimal format check: every non-comment line is `name{...} value`
        // or `name value`, values parse as f64, histograms end with +Inf.
        let mut saw_inf = false;
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# TYPE "), "bad comment: {line}");
                continue;
            }
            let (name_part, value) = line.rsplit_once(' ').expect("name value");
            assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
            if name_part.contains('{') {
                assert!(name_part.ends_with('}'), "bad labels in: {line}");
            }
            if name_part.contains("le=\"+Inf\"") {
                saw_inf = true;
            }
        }
        assert!(saw_inf, "histogram without +Inf bucket");
        assert!(text.contains("bespoke_requests_total{route=\"m/rk2:n=4\"} 1"));
    }

    #[test]
    fn backend_recording_rides_snapshot_and_profile() {
        let m = Metrics::default();
        assert_eq!(m.backend_for("m/rk2"), None);
        m.record_backend("m/rk2", "analytic");
        m.record_backend("m/rk2", "hlo"); // last write wins (hot-swap)
        m.record_backend("n/midpoint", "analytic");
        assert_eq!(m.backend_for("m/rk2"), Some("hlo"));
        let snap = m.snapshot();
        let b = snap.get("backends").unwrap();
        assert_eq!(b.get("m/rk2").unwrap().as_str().unwrap(), "hlo");
        assert_eq!(b.get("n/midpoint").unwrap().as_str().unwrap(), "analytic");
        let prof = m.profile_json();
        assert_eq!(
            prof.get("backends").unwrap().get("m/rk2").unwrap().as_str().unwrap(),
            "hlo"
        );
    }

    #[test]
    fn numerics_exposition_rides_snapshot_and_prometheus() {
        let m = Metrics::default();
        m.numerics().record_quarantine();
        m.numerics().push_alert("numeric_quarantine", "m/rk2:n=4", "nan at step 1");
        m.numerics().record_phase("m/rk2:n=4", "model_eval", 2.0);
        m.numerics().record_step("m/rk2:n=4", 0, 1.0, None, Some(0.4), 3, 2);
        let snap = m.snapshot();
        let obs = snap.get("obs").unwrap();
        assert_eq!(obs.get("numeric_quarantines").unwrap().as_usize().unwrap(), 1);
        assert_eq!(obs.get("alerts_active").unwrap().as_usize().unwrap(), 1);
        assert!(!obs.get("numerics").unwrap().get("guard").unwrap().as_bool().unwrap());
        let text = m.prometheus_text();
        assert!(text.contains("bespoke_numeric_quarantine_total 1"), "{text}");
        assert!(text.contains("bespoke_alerts_active 1"), "{text}");
        assert!(text.contains("bespoke_steps_rejected_total{route=\"m/rk2:n=4\"} 2"), "{text}");
        assert!(
            text.contains("bespoke_solve_phase_ms_count{route=\"m/rk2:n=4\",phase=\"model_eval\"} 1"),
            "{text}"
        );
        let prof = m.profile_json();
        assert!(prof.get("phases").unwrap().get("m/rk2:n=4").is_ok());
        assert!(prof.get("flight").unwrap().get("m/rk2:n=4").is_ok());
    }
}
