//! The serving coordinator (L3): request routing, dynamic batching, worker
//! execution, metrics — the vLLM-router-shaped layer that makes the learned
//! Bespoke solvers a deployable serving feature rather than a script.
//!
//! Data flow:
//!
//! ```text
//! client --JSONL--> server --+--> (model, solver) queue --> worker thread
//!                            |        dynamic batcher        |  sampler
//!                            +<------ reply channel <--------+  over HLO
//! ```
//!
//! * The fusion plane folds concurrent lockstep requests into one
//!   fixed-shape executable launch (HLO batch sizes are static; remainders
//!   are padded and the pad rows discarded): per route, a gather window
//!   (`fuse_window_us` / `fuse_max_rows`) coalesces compatible `sample`
//!   requests into one stacked solve whose rows are byte-identical to the
//!   solo solves — adaptive dopri5 bypasses fusion (DESIGN.md §10).
//! * One worker thread per (model, solver) pair, created on demand; the
//!   PJRT CPU client is shared and thread-safe.
//! * Every response carries NFE + queue/latency breakdowns; `metrics`
//!   aggregates p50/p99 latency, throughput, and batch-fill factor.
//! * Trajectory requests (`sample_traj`) drive a step-wise
//!   [`crate::solvers::SolveSession`] and stream one event per solver step
//!   — intermediate states, per-step progress, cumulative NFE.
//! * Registry-resolved specs (`bespoke:model=M:n=8`) re-resolve against the
//!   solver artifact registry per request; `train` / `job_status` / `jobs`
//!   commands drive the in-server training jobs that feed it, and freshly
//!   registered artifacts hot-swap into live routes (DESIGN.md §8).
//! * Budget-aware requests (`sample` with `budget: {nfe_max | latency_ms |
//!   quality}`) resolve against the model's Pareto frontier over registered
//!   scorecards; `evaluate` / `eval_status` / `frontier` commands drive the
//!   eval jobs that measure it (DESIGN.md §9).

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{Coordinator, SampleRequest, SampleResponse, TrajRequest, TrajStep};
pub use metrics::Metrics;
pub use server::{
    handle_line, perform_reload, sentinel_tick, serve, serve_daemon, spawn_scheduler, Lifecycle,
    SentinelGolden, ServerState,
};
