//! JSONL-over-TCP sampling server: thread-per-connection on top of the
//! batching [`Coordinator`]. Python never appears anywhere near this path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use super::batcher::Coordinator;
use super::protocol::{error_json, parse_command, response_to_json, Command};
use crate::json::Value;
use crate::log_info;

/// Serve forever on `addr` (blocks). Each accepted connection gets its own
/// thread; requests on one connection are handled sequentially, batching
/// happens across connections inside the coordinator.
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log_info!("serving on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_info!("accept error: {e}");
                continue;
            }
        };
        let coord = coord.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(coord, stream) {
                log_info!("connection ended: {e:#}");
            }
        });
    }
    Ok(())
}

pub fn handle_connection(coord: Arc<Coordinator>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&coord, &line);
        writer.write_all(reply.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    log_info!("peer {peer:?} disconnected");
    Ok(())
}

pub fn handle_line(coord: &Coordinator, line: &str) -> Value {
    match parse_command(line) {
        Ok(Command::Ping) => Value::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]),
        Ok(Command::List) => {
            let names = coord
                .zoo()
                .model_names()
                .into_iter()
                .map(Value::Str)
                .collect();
            Value::obj(vec![("ok", Value::Bool(true)), ("models", Value::Arr(names))])
        }
        Ok(Command::Metrics) => coord.metrics.snapshot(),
        Ok(Command::Sample(req)) => match coord.submit(&req) {
            Ok(resp) => response_to_json(&resp),
            Err(e) => error_json(&format!("{e:#}")),
        },
        Err(e) => error_json(&format!("bad request: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_line_rejects_garbage() {
        // A coordinator is only needed for valid commands; bad JSON fails
        // in parse_command before any routing, so a throwaway zoo-less call
        // is safe via parse error path.
        let v = parse_command("not json");
        assert!(v.is_err());
        let e = error_json("boom");
        assert_eq!(e.get("ok").unwrap().as_bool().unwrap(), false);
    }
}
