//! JSONL-over-TCP sampling server: thread-per-connection on top of the
//! batching [`Coordinator`]. Python never appears anywhere near this path.
//!
//! The server serves three planes from one socket: the sampling plane
//! (`sample` — with optional budget routing — and `sample_traj`), the
//! training plane (`train`, `job_status`, `jobs`) backed by an optional
//! [`TrainJobManager`], and the quality plane (`evaluate`, `eval_status`,
//! `frontier`) backed by an optional [`EvalJobManager`] — a server started
//! without either (no registry configured) cleanly rejects those commands
//! instead of panicking.
//!
//! Daemon lifecycle (DESIGN.md §12): a shared [`Lifecycle`] latch drives
//! graceful drain — once flipped (SIGTERM/SIGINT via [`serve_daemon`], or
//! the in-band `{"cmd":"drain"}`), new work commands get a structured
//! `draining` error, in-flight requests finish behind an inflight counter,
//! the fusion plane flushes, and the job planes persist interrupted specs
//! for pickup on restart. `{"cmd":"reload"}` (or SIGHUP) re-reads the
//! config file and atomically installs the `[serve]`/`[quality]`/
//! `[registry]` knobs without dropping a request.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Max accepted request-line length. Longer lines get a structured JSON
/// error (and are discarded up to the next newline) instead of an
/// unbounded buffer or a dropped connection.
pub const MAX_LINE_BYTES: usize = 4 << 20;

use anyhow::{anyhow, Result};

use super::batcher::{Coordinator, SampleRequest};
use super::protocol::{
    artifact_json, error_json, error_json_coded, eval_job_json, frontier_json, job_json,
    numeric_error_json, parse_command, response_to_json, traj_done_json, traj_step_json, Command,
    JobKind,
};
use crate::config::{Config, RegistryConfig, ScheduleConfig};
use crate::json::Value;
use crate::log_info;
use crate::quality::{frontier_pins, EvalJobManager, EvalJobSpec, EvalRunner};
use crate::registry::meta::unix_now;
use crate::registry::{is_overloaded_err, TrainJobManager};
use crate::util::lifecycle::{signals, DrainGate};
use crate::util::numerics::diff_rms;
use crate::util::obs::{span_json, Stage};
use crate::util::NumericError;

/// Shared daemon-lifecycle state: the draining latch, the in-flight
/// request counter the drain waits on, the wake address used to unstick a
/// blocked `accept` (glibc `signal` is SA_RESTART), and the reloadable
/// bits the dispatcher needs (config path, current `[registry]` knobs).
#[derive(Default)]
pub struct Lifecycle {
    gate: DrainGate,
    inflight: AtomicUsize,
    config_path: Mutex<Option<PathBuf>>,
    wake_addr: Mutex<Option<SocketAddr>>,
    registry_cfg: Mutex<RegistryConfig>,
}

impl Lifecycle {
    pub fn new() -> Lifecycle {
        Lifecycle::default()
    }

    /// Register the config file `{"cmd":"reload"}` / SIGHUP re-reads.
    pub fn set_config_path(&self, path: PathBuf) {
        *self.config_path.lock().unwrap() = Some(path);
    }

    pub fn set_registry_cfg(&self, cfg: RegistryConfig) {
        *self.registry_cfg.lock().unwrap() = cfg;
    }

    /// Current `[registry]` knobs (hot-reloadable; the scheduler reads
    /// `keep_last_k` from here each GC tick).
    pub fn registry_cfg(&self) -> RegistryConfig {
        self.registry_cfg.lock().unwrap().clone()
    }

    pub fn is_draining(&self) -> bool {
        self.gate.is_draining()
    }

    /// Flip the draining latch and wake the accept loop so it observes it.
    pub fn request_drain(&self) {
        self.gate.begin_drain();
        // Self-connect defeats SA_RESTART on the blocked accept(2).
        if let Some(addr) = *self.wake_addr.lock().unwrap() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }

    fn set_wake_addr(&self, addr: SocketAddr) {
        *self.wake_addr.lock().unwrap() = Some(addr);
    }

    fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// RAII in-flight marker: drain waits for the count to reach zero
    /// before flushing the fusion plane.
    fn enter(self: &Arc<Self>) -> InflightGuard {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        InflightGuard { lc: self.clone() }
    }
}

struct InflightGuard {
    lc: Arc<Lifecycle>,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.lc.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Everything a connection handler needs: the sampling coordinator, the
/// (optional) in-server training- and eval-job managers, the concrete
/// eval runner (for hot-reloading `[quality]` knobs), and the shared
/// lifecycle latch.
#[derive(Clone)]
pub struct ServerState {
    pub coord: Arc<Coordinator>,
    pub jobs: Option<Arc<TrainJobManager>>,
    pub eval_jobs: Option<Arc<EvalJobManager>>,
    pub eval_runner: Option<Arc<EvalRunner>>,
    pub lifecycle: Arc<Lifecycle>,
}

impl ServerState {
    /// Sampling only: training and quality commands are rejected.
    pub fn sampling_only(coord: Arc<Coordinator>) -> ServerState {
        ServerState {
            coord,
            jobs: None,
            eval_jobs: None,
            eval_runner: None,
            lifecycle: Arc::new(Lifecycle::new()),
        }
    }

    pub fn with_jobs(coord: Arc<Coordinator>, jobs: Arc<TrainJobManager>) -> ServerState {
        ServerState { jobs: Some(jobs), ..ServerState::sampling_only(coord) }
    }

    /// Enable the quality plane (`evaluate` / `eval_status`).
    pub fn with_eval_jobs(mut self, eval_jobs: Arc<EvalJobManager>) -> ServerState {
        self.eval_jobs = Some(eval_jobs);
        self
    }

    /// Register the concrete eval runner so `reload` can hot-swap its
    /// `[quality]` knobs (the manager only sees the erased trait object).
    pub fn with_eval_runner(mut self, runner: Arc<EvalRunner>) -> ServerState {
        self.eval_runner = Some(runner);
        self
    }
}

/// Serve on `addr` until drained (blocks). Each accepted connection gets
/// its own thread; requests on one connection are handled sequentially,
/// batching happens across connections inside the coordinator. Returns
/// after a graceful drain (`{"cmd":"drain"}` or [`Lifecycle::request_drain`]);
/// without one it serves forever.
pub fn serve(state: ServerState, addr: &str) -> Result<()> {
    serve_inner(state, addr, false)
}

/// [`serve`] plus process-signal handling: installs SIGTERM/SIGINT →
/// drain and SIGHUP → reload handlers and a watcher thread that acts on
/// them. Only the daemon entrypoint uses this — the signal flags are
/// process-global, so embedding tests use [`serve`] with the in-band
/// `drain`/`reload` commands instead.
pub fn serve_daemon(state: ServerState, addr: &str) -> Result<()> {
    signals::install();
    serve_inner(state, addr, true)
}

fn serve_inner(state: ServerState, addr: &str, watch_signals: bool) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    state.lifecycle.set_wake_addr(listener.local_addr()?);
    log_info!("serving on {addr}");
    if watch_signals {
        let watcher = state.clone();
        std::thread::spawn(move || loop {
            if signals::take_reload_request() {
                match perform_reload(&watcher) {
                    Ok(path) => log_info!("SIGHUP: reloaded config from {path}"),
                    Err(e) => log_info!("SIGHUP: reload failed: {e:#}"),
                }
            }
            if signals::drain_requested() || watcher.lifecycle.is_draining() {
                watcher.lifecycle.request_drain();
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        });
    }
    for stream in listener.incoming() {
        if state.lifecycle.is_draining() {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_info!("accept error: {e}");
                continue;
            }
        };
        state.coord.metrics.record_event("connections");
        // Per-connection idle read timeout ([serve] idle_timeout_ms;
        // re-read per accept so `reload` applies to new connections).
        let idle_ms = state.coord.serve_cfg().idle_timeout_ms;
        if idle_ms > 0 {
            let _ = stream.set_read_timeout(Some(Duration::from_millis(idle_ms)));
        }
        let state = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(state, stream) {
                log_info!("connection ended: {e:#}");
            }
        });
    }
    finish_drain(&state)
}

/// Re-read the registered config file and atomically install the
/// reloadable knobs: `[serve]` via coordinator route retirement (live
/// requests finish on the old routes — drop-free), `[quality]` into the
/// eval runner, `[registry]` into the lifecycle (scheduler GC). Returns
/// the path reloaded from.
pub fn perform_reload(state: &ServerState) -> Result<String> {
    let path = state
        .lifecycle
        .config_path
        .lock()
        .unwrap()
        .clone()
        .ok_or_else(|| anyhow!("no config file registered (server started without --config)"))?;
    let cfg = Config::load(&path)?;
    state.coord.reload_serve(cfg.serve.clone());
    if let Some(runner) = &state.eval_runner {
        runner.set_quality(cfg.quality.clone());
    }
    state.lifecycle.set_registry_cfg(cfg.registry.clone());
    // `[obs]` hot-reload: tracer knobs + event-log sink. Resets the span
    // ring (a reconfigured ring cannot keep old spans coherently).
    state.coord.metrics.apply_obs(&cfg.obs)?;
    Ok(path.display().to_string())
}

/// Graceful-drain tail, run after the accept loop stops: wait out
/// in-flight requests (bounded by `[serve] drain_grace_ms`), flush the
/// fusion plane, then drain both job planes and persist their interrupted
/// specs so a restarted server resumes them.
fn finish_drain(state: &ServerState) -> Result<()> {
    let grace = Duration::from_millis(state.coord.serve_cfg().drain_grace_ms.max(1));
    let deadline = Instant::now() + grace;
    while state.lifecycle.inflight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    let flushed = state.coord.drain(grace);
    if let Some(jobs) = &state.jobs {
        let specs = jobs.drain(grace);
        if let Err(e) = jobs.persist_interrupted(&specs) {
            log_info!("drain: persisting interrupted train jobs failed: {e:#}");
        }
    }
    if let Some(jobs) = &state.eval_jobs {
        let specs = jobs.drain(grace);
        if let Err(e) = jobs.persist_interrupted(&specs) {
            log_info!("drain: persisting interrupted eval jobs failed: {e:#}");
        }
    }
    state.coord.metrics.record_event("server_drains");
    log_info!("drain complete (fusion flushed: {flushed})");
    Ok(())
}

/// Spawn the minimal cron-like maintenance thread (`[schedule]`): every
/// `tick_ms` it re-evals scorecards staler than `refresh_secs` (job
/// coalescing dedupes ones already in flight), when `gc` is set runs
/// registry GC pinned to the quality frontiers, and when `sentinel_secs`
/// is set runs the quality-drift sentinel ([`sentinel_tick`]) at that
/// cadence (lower-bounded by the tick itself). Returns `None` when
/// `tick_ms == 0` (scheduling off — the sentinel therefore requires a
/// live tick). The thread exits when drain begins.
pub fn spawn_scheduler(
    state: &ServerState,
    schedule: &ScheduleConfig,
) -> Option<std::thread::JoinHandle<()>> {
    if schedule.tick_ms == 0 {
        return None;
    }
    let state = state.clone();
    let schedule = schedule.clone();
    Some(std::thread::spawn(move || {
        // Sentinel goldens live for the scheduler thread's lifetime: pinned
        // on first sight of a route, re-pinned across hot-swaps.
        let mut goldens: BTreeMap<String, SentinelGolden> = BTreeMap::new();
        let mut last_sentinel = Instant::now();
        loop {
            let mut slept = 0u64;
            while slept < schedule.tick_ms {
                if state.lifecycle.is_draining() {
                    return;
                }
                let step = (schedule.tick_ms - slept).min(100);
                std::thread::sleep(Duration::from_millis(step));
                slept += step;
            }
            if state.lifecycle.is_draining() {
                return;
            }
            let tick_start = Instant::now();
            scheduler_tick(&state, &schedule);
            if schedule.sentinel_secs > 0
                && last_sentinel.elapsed().as_secs() >= schedule.sentinel_secs
            {
                last_sentinel = Instant::now();
                sentinel_tick(&state, &schedule, &mut goldens);
            }
            // Tick stats: how often maintenance runs and its cumulative cost.
            state.coord.metrics.record_event("schedule_ticks");
            state
                .coord
                .metrics
                .record_event_add("schedule_tick_us", tick_start.elapsed().as_micros() as u64);
        }
    }))
}

/// One pinned sentinel golden (DESIGN.md §14): the fixed-seed probe's
/// flattened sample rows plus, for artifact-backed routes, the
/// `(label, version, val_rmse)` binding the pin was taken under.
pub struct SentinelGolden {
    /// Flattened probe sample rows (public so tests can force a drift).
    pub rows: Vec<f32>,
    /// `(label, version, val_rmse)` for artifact-backed routes.
    pub artifact: Option<(String, u64, f32)>,
}

/// Registry binding of a path-form learned solver spec: the artifact whose
/// checkpoint the route serves, as `(label, version, val_rmse)`.
fn artifact_binding(state: &ServerState, solver: &str) -> Option<(String, u64, f32)> {
    let path = solver
        .strip_prefix("bespoke:path=")
        .or_else(|| solver.strip_prefix("bns:path="))
        .or_else(|| solver.strip_prefix("multistep:path="))?;
    let rec = state.coord.registry()?.find_by_theta_path(path)?;
    Some((rec.key.label(), rec.version, rec.val_rmse))
}

/// One quality-drift sentinel pass (DESIGN.md §14): replay a tiny
/// fixed-seed probe batch on every live route and compare against the
/// pinned golden. First sight of a route (or artifact key) pins it. An
/// artifact hot-swap re-pins under the new version after checking the
/// registry's `val_rmse` did not regress past `sentinel_tol`
/// (`frontier_regression`); a same-version mismatch means nondeterminism
/// or a corrupted checkpoint (`digest_drift`). Alerts land in the
/// structured ring (`{"cmd":"alerts"}`), the `sentinel_alert` event
/// counter, and the JSONL event log. Public so tests can drive passes
/// without waiting out the scheduler cadence.
pub fn sentinel_tick(
    state: &ServerState,
    schedule: &ScheduleConfig,
    goldens: &mut BTreeMap<String, SentinelGolden>,
) {
    for route in state.coord.served_routes() {
        let Some((model, solver)) = route.split_once('/') else {
            continue;
        };
        let alert = |kind: &str, msg: &str| {
            state.coord.metrics.numerics().push_alert(kind, &route, msg);
            state.coord.metrics.record_event("sentinel_alert");
            log_info!("sentinel [{kind}] {route}: {msg}");
        };
        let req = SampleRequest {
            model: model.to_string(),
            solver: solver.to_string(),
            n_samples: schedule.sentinel_rows.max(1),
            seed: schedule.sentinel_seed,
            return_samples: true,
            budget: None,
        };
        let resp = match state.coord.submit(&req) {
            Ok(r) => r,
            Err(e) => {
                alert("probe_failed", &format!("{e:#}"));
                continue;
            }
        };
        let flat: Vec<f32> =
            resp.samples.unwrap_or_default().into_iter().flatten().collect();
        // Artifact-backed routes pin per artifact *key* (stable across
        // hot-swaps) so a version change is visible as such, not as a
        // brand-new route.
        let artifact = artifact_binding(state, solver);
        let key = match &artifact {
            Some((label, _, _)) => format!("{model}/{label}"),
            None => route.clone(),
        };
        match goldens.get_mut(&key) {
            None => {
                goldens.insert(key, SentinelGolden { rows: flat, artifact });
            }
            Some(g) => {
                let swapped = g.artifact.as_ref().map(|(_, v, _)| *v)
                    != artifact.as_ref().map(|(_, v, _)| *v);
                if swapped {
                    if let (Some((label, old_v, old_rmse)), Some((_, new_v, new_rmse))) =
                        (&g.artifact, &artifact)
                    {
                        if (*new_rmse as f64)
                            > (*old_rmse as f64) * (1.0 + schedule.sentinel_tol)
                        {
                            alert(
                                "frontier_regression",
                                &format!(
                                    "{label}: v{old_v} val_rmse {old_rmse} -> v{new_v} \
                                     val_rmse {new_rmse} (tol {})",
                                    schedule.sentinel_tol
                                ),
                            );
                        }
                    }
                    *g = SentinelGolden { rows: flat, artifact };
                } else if g.rows != flat {
                    let drift = diff_rms(&flat, &g.rows);
                    alert(
                        "digest_drift",
                        &format!(
                            "fixed-seed probe drifted (rms {drift:.3e}) — \
                             nondeterminism or a corrupted checkpoint"
                        ),
                    );
                    // Re-pin to the drifted output: the alert is the
                    // record, re-alerting every tick would be a storm.
                    g.rows = flat;
                }
            }
        }
    }
}

fn scheduler_tick(state: &ServerState, schedule: &ScheduleConfig) {
    let Some(registry) = state.coord.registry() else {
        return;
    };
    if schedule.refresh_secs > 0 {
        if let Some(eval_jobs) = &state.eval_jobs {
            // Latest scorecard per (model, solver); only the newest copy
            // decides staleness.
            let mut latest: BTreeMap<(String, String), u64> = BTreeMap::new();
            for rec in registry.eval_records() {
                let at = latest.entry((rec.model, rec.solver)).or_insert(0);
                *at = (*at).max(rec.created_at);
            }
            let now = unix_now();
            for ((model, solver), created_at) in latest {
                if now.saturating_sub(created_at) < schedule.refresh_secs {
                    continue;
                }
                let spec = EvalJobSpec { model, solver, grid: Vec::new(), seed: None };
                match eval_jobs.submit(spec) {
                    Ok((_, false)) => state.coord.metrics.record_event("schedule_evals_refreshed"),
                    Ok((_, true)) => {} // already in flight
                    Err(e) => log_info!("schedule: eval refresh rejected: {e:#}"),
                }
            }
        }
    }
    if schedule.gc {
        let keep = state.lifecycle.registry_cfg().keep_last_k;
        if keep > 0 {
            let pins = frontier_pins(registry).unwrap_or_default();
            match registry.gc_with_pins(keep, &pins) {
                Ok(removed) if !removed.is_empty() => {
                    state
                        .coord
                        .metrics
                        .record_event_add("schedule_gc_removed", removed.len() as u64);
                    log_info!("schedule: gc removed {} artifacts", removed.len());
                }
                Ok(_) => {}
                Err(e) => log_info!("schedule: gc failed: {e:#}"),
            }
        }
    }
}

fn write_event<W: Write>(writer: &mut W, v: &Value) -> Result<()> {
    writer.write_all(v.to_string_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// One request line read off the wire.
enum LineRead {
    /// A complete line (without the trailing newline), lossily decoded —
    /// invalid UTF-8 becomes a JSON parse error downstream, not a dropped
    /// connection.
    Line(String),
    /// The line exceeded [`MAX_LINE_BYTES`]; the excess was discarded up
    /// to the next newline.
    TooLong(usize),
    Eof,
}

/// Read one newline-terminated line with a hard length cap, so a
/// malicious or buggy client cannot balloon the server's line buffer.
fn read_line_capped(reader: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a capped (oversized) partial line still reports TooLong.
            return Ok(match (buf.is_empty(), dropped) {
                (true, 0) => LineRead::Eof,
                (_, 0) => LineRead::Line(String::from_utf8_lossy(&buf).into_owned()),
                _ => LineRead::TooLong(buf.len() + dropped),
            });
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let upto = newline.map(|i| i + 1).unwrap_or(chunk.len());
        let take = &chunk[..upto.min(chunk.len())];
        let body = match newline {
            Some(i) => &take[..i],
            None => take,
        };
        if dropped == 0 && buf.len() + body.len() <= MAX_LINE_BYTES {
            buf.extend_from_slice(body);
        } else {
            dropped += body.len();
        }
        let done = newline.is_some();
        reader.consume(upto);
        if done {
            return Ok(if dropped == 0 {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            } else {
                LineRead::TooLong(buf.len() + dropped)
            });
        }
    }
}

fn is_idle_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

pub fn handle_connection(state: ServerState, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_capped(&mut reader) {
            Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLong(n)) => {
                write_event(
                    &mut writer,
                    &error_json(&format!(
                        "request line of {n} bytes exceeds the {MAX_LINE_BYTES}-byte limit"
                    )),
                )?;
                continue;
            }
            Ok(LineRead::Line(l)) => l,
            // Idle timeout ([serve] idle_timeout_ms): tell the client
            // why, then close cleanly instead of holding the slot.
            Err(e) if is_idle_timeout(&e) => {
                let _ = write_event(
                    &mut writer,
                    &error_json_coded("timeout", "idle timeout exceeded; closing connection"),
                );
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_command(&line) {
            // The streaming command writes multiple lines per request; all
            // other commands reply with exactly one line.
            Ok(Command::SampleTraj(req)) => {
                let _inflight = state.lifecycle.enter();
                if state.lifecycle.is_draining() {
                    state.coord.metrics.record_event("rejected_draining");
                    write_event(
                        &mut writer,
                        &error_json_coded("draining", "server is draining; new work not accepted"),
                    )?;
                    continue;
                }
                let result = state.coord.sample_traj(&req, &mut |step| {
                    write_event(&mut writer, &traj_step_json(&step))
                });
                match result {
                    Ok(resp) => write_event(&mut writer, &traj_done_json(&resp))?,
                    Err(e) => {
                        let v = match e.downcast_ref::<NumericError>() {
                            Some(ne) => numeric_error_json(ne),
                            None => error_json(&format!("{e:#}")),
                        };
                        write_event(&mut writer, &v)?
                    }
                }
            }
            Ok(cmd) => {
                let _inflight = state.lifecycle.enter();
                write_event(&mut writer, &dispatch(&state, cmd))?
            }
            Err(e) => write_event(&mut writer, &error_json(&format!("bad request: {e:#}")))?,
        }
    }
    log_info!("peer {peer:?} disconnected");
    Ok(())
}

/// True for the commands a draining server refuses (new work);
/// introspection, cancel, reload and drain stay available to the end.
fn rejected_while_draining(cmd: &Command) -> bool {
    matches!(
        cmd,
        Command::Sample(_) | Command::SampleTraj(_) | Command::Train(_) | Command::Evaluate(_)
    )
}

/// Execute a single-response command.
fn dispatch(state: &ServerState, cmd: Command) -> Value {
    if state.lifecycle.is_draining() && rejected_while_draining(&cmd) {
        state.coord.metrics.record_event("rejected_draining");
        return error_json_coded("draining", "server is draining; new work not accepted");
    }
    let coord = &state.coord;
    match cmd {
        Command::Ping => Value::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]),
        Command::List => {
            let names = coord
                .zoo()
                .model_names()
                .into_iter()
                .map(Value::Str)
                .collect();
            // Registry-aware listing: alongside the model zoo, the trained
            // solver artifacts currently resolvable by bespoke:model=... specs.
            let artifacts = coord
                .registry()
                .map(|r| r.list().iter().map(artifact_json).collect())
                .unwrap_or_default();
            Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("models", Value::Arr(names)),
                ("artifacts", Value::Arr(artifacts)),
            ])
        }
        Command::Metrics => coord.metrics.snapshot(),
        Command::MetricsProm => Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("format", Value::Str("prometheus".into())),
            ("body", Value::Str(coord.metrics.prometheus_text())),
        ]),
        Command::Trace { id, limit } => {
            let tracer = coord.metrics.tracer();
            let spans = tracer.snapshot(id, limit);
            let mut pairs = vec![
                ("ok", Value::Bool(true)),
                ("enabled", Value::Bool(tracer.enabled())),
                ("dropped", Value::Num(tracer.dropped() as f64)),
                ("spans", Value::Arr(spans.iter().map(span_json).collect())),
            ];
            if let Some(id) = id {
                let peers = tracer
                    .fuse_peers(id)
                    .into_iter()
                    .map(|p| Value::Num(p as f64))
                    .collect();
                pairs.push(("peers", Value::Arr(peers)));
            }
            Value::obj(pairs)
        }
        Command::Sample(req) => {
            // Tracing is observation only: the id rides alongside the
            // request and never reaches RNG or batching decisions, so
            // sample bytes are identical with tracing on or off.
            let tracer = coord.metrics.tracer();
            let tid = tracer.begin_request();
            if let Some(id) = tid {
                tracer.record(id, Stage::Accept, 0, req.n_samples as u64);
            }
            let accepted = Instant::now();
            match coord.submit_traced(&req, tid) {
                Ok(resp) => {
                    let mut v = response_to_json(&resp);
                    if let Some(id) = tid {
                        tracer.record(id, Stage::Respond, 0, accepted.elapsed().as_micros() as u64);
                        if let Value::Obj(map) = &mut v {
                            map.insert("request_id".to_string(), Value::Num(id as f64));
                        }
                    }
                    v
                }
                // Guard trips surface as the coded `numeric` rejection with
                // the machine-readable trip site (DESIGN.md §14).
                Err(e) => match e.downcast_ref::<NumericError>() {
                    Some(ne) => numeric_error_json(ne),
                    None => error_json(&format!("{e:#}")),
                },
            }
        }
        Command::SampleTraj(_) => {
            error_json("sample_traj is a streaming command; it is handled per-connection")
        }
        Command::Train(spec) => match &state.jobs {
            None => error_json(
                "training jobs are not enabled on this server \
                 (start `repro serve` with a [registry] config)",
            ),
            Some(jobs) => match jobs.submit(spec) {
                Ok((id, coalesced)) => {
                    let state_name = jobs
                        .status(id)
                        .map(|s| s.state.name())
                        .unwrap_or("queued");
                    Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("job_id", Value::Num(id as f64)),
                        ("state", Value::Str(state_name.into())),
                        ("coalesced", Value::Bool(coalesced)),
                    ])
                }
                Err(e) if is_overloaded_err(&e) => error_json_coded("overloaded", &format!("{e:#}")),
                Err(e) => error_json(&format!("{e:#}")),
            },
        },
        Command::JobStatus(id) => match &state.jobs {
            None => error_json("training jobs are not enabled on this server"),
            Some(jobs) => match jobs.status(id) {
                Some(snap) => job_json(&snap),
                None => error_json(&format!("unknown job_id {id}")),
            },
        },
        Command::Jobs => match &state.jobs {
            None => error_json("training jobs are not enabled on this server"),
            Some(jobs) => Value::obj(vec![
                ("ok", Value::Bool(true)),
                (
                    "jobs",
                    Value::Arr(jobs.jobs().iter().map(job_json).collect()),
                ),
            ]),
        },
        Command::Evaluate(spec) => match &state.eval_jobs {
            None => error_json(
                "eval jobs are not enabled on this server \
                 (start `repro serve` with a [registry] config)",
            ),
            Some(jobs) => match jobs.submit(spec) {
                Ok((id, coalesced)) => {
                    let state_name = jobs
                        .status(id)
                        .map(|s| s.state.name())
                        .unwrap_or("queued");
                    Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("job_id", Value::Num(id as f64)),
                        ("state", Value::Str(state_name.into())),
                        ("coalesced", Value::Bool(coalesced)),
                    ])
                }
                Err(e) if is_overloaded_err(&e) => error_json_coded("overloaded", &format!("{e:#}")),
                Err(e) => error_json(&format!("{e:#}")),
            },
        },
        Command::EvalStatus(id) => match &state.eval_jobs {
            None => error_json("eval jobs are not enabled on this server"),
            Some(jobs) => match jobs.status(id) {
                Some(snap) => eval_job_json(&snap),
                None => error_json(&format!("unknown eval job_id {id}")),
            },
        },
        Command::Frontier(model) => match coord.frontier(&model) {
            Ok(f) => frontier_json(&f),
            Err(e) => error_json(&format!("{e:#}")),
        },
        Command::CancelJob { id, kind } => {
            let result = match kind {
                JobKind::Train => state.jobs.as_ref().map(|j| j.cancel(id)),
                JobKind::Eval => state.eval_jobs.as_ref().map(|j| j.cancel(id)),
            };
            match result {
                None => error_json("jobs of that kind are not enabled on this server"),
                Some(Ok(new_state)) => Value::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("job_id", Value::Num(id as f64)),
                    ("state", Value::Str(new_state.name().into())),
                ]),
                Some(Err(e)) => error_json(&format!("{e:#}")),
            }
        }
        Command::Reload => match perform_reload(state) {
            Ok(path) => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("reloaded", Value::Bool(true)),
                ("config", Value::Str(path)),
            ]),
            Err(e) => error_json_coded("reload", &format!("{e:#}")),
        },
        Command::Drain => {
            // The latch also stops the accept loop; this connection's ack
            // still goes out because its handler thread is independent.
            state.lifecycle.request_drain();
            Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("draining", Value::Bool(true)),
            ])
        }
        // Observability introspection — both stay available while draining.
        Command::Profile => coord.metrics.profile_json(),
        Command::Alerts { clear } => match coord.metrics.numerics().alerts_json(clear) {
            Value::Obj(mut m) => {
                m.insert("ok".to_string(), Value::Bool(true));
                Value::Obj(m)
            }
            other => other,
        },
    }
}

/// One-line-in, one-value-out handler (used by tests and non-streaming
/// embedders; the TCP loop handles `sample_traj` separately so it can
/// stream multiple event lines).
pub fn handle_line(state: &ServerState, line: &str) -> Value {
    match parse_command(line) {
        Ok(cmd) => dispatch(state, cmd),
        Err(e) => error_json(&format!("bad request: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_line_rejects_garbage() {
        // A coordinator is only needed for valid commands; bad JSON fails
        // in parse_command before any routing, so a throwaway zoo-less call
        // is safe via parse error path.
        let v = parse_command("not json");
        assert!(v.is_err());
        let e = error_json("boom");
        assert!(!e.get("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn inflight_guard_counts_and_releases() {
        let lc = Arc::new(Lifecycle::new());
        assert_eq!(lc.inflight(), 0);
        {
            let _a = lc.enter();
            let _b = lc.enter();
            assert_eq!(lc.inflight(), 2);
        }
        assert_eq!(lc.inflight(), 0);
        assert!(!lc.is_draining());
        lc.gate.begin_drain(); // no wake addr registered: latch only
        assert!(lc.is_draining());
    }

    #[test]
    fn draining_rejects_work_commands_only() {
        let work =
            parse_command(r#"{"cmd":"sample","model":"m","solver":"s","n_samples":1}"#).unwrap();
        let ping = parse_command(r#"{"cmd":"ping"}"#).unwrap();
        let drain = parse_command(r#"{"cmd":"drain"}"#).unwrap();
        assert!(rejected_while_draining(&work));
        assert!(!rejected_while_draining(&ping));
        assert!(!rejected_while_draining(&drain));
    }
}
