//! JSONL-over-TCP sampling server: thread-per-connection on top of the
//! batching [`Coordinator`]. Python never appears anywhere near this path.
//!
//! The server serves three planes from one socket: the sampling plane
//! (`sample` — with optional budget routing — and `sample_traj`), the
//! training plane (`train`, `job_status`, `jobs`) backed by an optional
//! [`TrainJobManager`], and the quality plane (`evaluate`, `eval_status`,
//! `frontier`) backed by an optional [`EvalJobManager`] — a server started
//! without either (no registry configured) cleanly rejects those commands
//! instead of panicking.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Max accepted request-line length. Longer lines get a structured JSON
/// error (and are discarded up to the next newline) instead of an
/// unbounded buffer or a dropped connection.
pub const MAX_LINE_BYTES: usize = 4 << 20;

use anyhow::Result;

use super::batcher::Coordinator;
use super::protocol::{
    artifact_json, error_json, eval_job_json, frontier_json, job_json, parse_command,
    response_to_json, traj_done_json, traj_step_json, Command,
};
use crate::json::Value;
use crate::log_info;
use crate::quality::EvalJobManager;
use crate::registry::TrainJobManager;

/// Everything a connection handler needs: the sampling coordinator plus the
/// (optional) in-server training- and eval-job managers.
#[derive(Clone)]
pub struct ServerState {
    pub coord: Arc<Coordinator>,
    pub jobs: Option<Arc<TrainJobManager>>,
    pub eval_jobs: Option<Arc<EvalJobManager>>,
}

impl ServerState {
    /// Sampling only: training and quality commands are rejected.
    pub fn sampling_only(coord: Arc<Coordinator>) -> ServerState {
        ServerState { coord, jobs: None, eval_jobs: None }
    }

    pub fn with_jobs(coord: Arc<Coordinator>, jobs: Arc<TrainJobManager>) -> ServerState {
        ServerState { coord, jobs: Some(jobs), eval_jobs: None }
    }

    /// Enable the quality plane (`evaluate` / `eval_status`).
    pub fn with_eval_jobs(mut self, eval_jobs: Arc<EvalJobManager>) -> ServerState {
        self.eval_jobs = Some(eval_jobs);
        self
    }
}

/// Serve forever on `addr` (blocks). Each accepted connection gets its own
/// thread; requests on one connection are handled sequentially, batching
/// happens across connections inside the coordinator.
pub fn serve(state: ServerState, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log_info!("serving on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_info!("accept error: {e}");
                continue;
            }
        };
        let state = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(state, stream) {
                log_info!("connection ended: {e:#}");
            }
        });
    }
    Ok(())
}

fn write_event<W: Write>(writer: &mut W, v: &Value) -> Result<()> {
    writer.write_all(v.to_string_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// One request line read off the wire.
enum LineRead {
    /// A complete line (without the trailing newline), lossily decoded —
    /// invalid UTF-8 becomes a JSON parse error downstream, not a dropped
    /// connection.
    Line(String),
    /// The line exceeded [`MAX_LINE_BYTES`]; the excess was discarded up
    /// to the next newline.
    TooLong(usize),
    Eof,
}

/// Read one newline-terminated line with a hard length cap, so a
/// malicious or buggy client cannot balloon the server's line buffer.
fn read_line_capped(reader: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a capped (oversized) partial line still reports TooLong.
            return Ok(match (buf.is_empty(), dropped) {
                (true, 0) => LineRead::Eof,
                (_, 0) => LineRead::Line(String::from_utf8_lossy(&buf).into_owned()),
                _ => LineRead::TooLong(buf.len() + dropped),
            });
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let upto = newline.map(|i| i + 1).unwrap_or(chunk.len());
        let take = &chunk[..upto.min(chunk.len())];
        let body = match newline {
            Some(i) => &take[..i],
            None => take,
        };
        if dropped == 0 && buf.len() + body.len() <= MAX_LINE_BYTES {
            buf.extend_from_slice(body);
        } else {
            dropped += body.len();
        }
        let done = newline.is_some();
        reader.consume(upto);
        if done {
            return Ok(if dropped == 0 {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            } else {
                LineRead::TooLong(buf.len() + dropped)
            });
        }
    }
}

pub fn handle_connection(state: ServerState, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_capped(&mut reader)? {
            LineRead::Eof => break,
            LineRead::TooLong(n) => {
                write_event(
                    &mut writer,
                    &error_json(&format!(
                        "request line of {n} bytes exceeds the {MAX_LINE_BYTES}-byte limit"
                    )),
                )?;
                continue;
            }
            LineRead::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_command(&line) {
            // The streaming command writes multiple lines per request; all
            // other commands reply with exactly one line.
            Ok(Command::SampleTraj(req)) => {
                let result = state.coord.sample_traj(&req, &mut |step| {
                    write_event(&mut writer, &traj_step_json(&step))
                });
                match result {
                    Ok(resp) => write_event(&mut writer, &traj_done_json(&resp))?,
                    Err(e) => write_event(&mut writer, &error_json(&format!("{e:#}")))?,
                }
            }
            Ok(cmd) => write_event(&mut writer, &dispatch(&state, cmd))?,
            Err(e) => write_event(&mut writer, &error_json(&format!("bad request: {e:#}")))?,
        }
    }
    log_info!("peer {peer:?} disconnected");
    Ok(())
}

/// Execute a single-response command.
fn dispatch(state: &ServerState, cmd: Command) -> Value {
    let coord = &state.coord;
    match cmd {
        Command::Ping => Value::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]),
        Command::List => {
            let names = coord
                .zoo()
                .model_names()
                .into_iter()
                .map(Value::Str)
                .collect();
            // Registry-aware listing: alongside the model zoo, the trained
            // solver artifacts currently resolvable by bespoke:model=... specs.
            let artifacts = coord
                .registry()
                .map(|r| r.list().iter().map(artifact_json).collect())
                .unwrap_or_default();
            Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("models", Value::Arr(names)),
                ("artifacts", Value::Arr(artifacts)),
            ])
        }
        Command::Metrics => coord.metrics.snapshot(),
        Command::Sample(req) => match coord.submit(&req) {
            Ok(resp) => response_to_json(&resp),
            Err(e) => error_json(&format!("{e:#}")),
        },
        Command::SampleTraj(_) => {
            error_json("sample_traj is a streaming command; it is handled per-connection")
        }
        Command::Train(spec) => match &state.jobs {
            None => error_json(
                "training jobs are not enabled on this server \
                 (start `repro serve` with a [registry] config)",
            ),
            Some(jobs) => match jobs.submit(spec) {
                Ok((id, coalesced)) => {
                    let state_name = jobs
                        .status(id)
                        .map(|s| s.state.name())
                        .unwrap_or("queued");
                    Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("job_id", Value::Num(id as f64)),
                        ("state", Value::Str(state_name.into())),
                        ("coalesced", Value::Bool(coalesced)),
                    ])
                }
                Err(e) => error_json(&format!("{e:#}")),
            },
        },
        Command::JobStatus(id) => match &state.jobs {
            None => error_json("training jobs are not enabled on this server"),
            Some(jobs) => match jobs.status(id) {
                Some(snap) => job_json(&snap),
                None => error_json(&format!("unknown job_id {id}")),
            },
        },
        Command::Jobs => match &state.jobs {
            None => error_json("training jobs are not enabled on this server"),
            Some(jobs) => Value::obj(vec![
                ("ok", Value::Bool(true)),
                (
                    "jobs",
                    Value::Arr(jobs.jobs().iter().map(job_json).collect()),
                ),
            ]),
        },
        Command::Evaluate(spec) => match &state.eval_jobs {
            None => error_json(
                "eval jobs are not enabled on this server \
                 (start `repro serve` with a [registry] config)",
            ),
            Some(jobs) => match jobs.submit(spec) {
                Ok((id, coalesced)) => {
                    let state_name = jobs
                        .status(id)
                        .map(|s| s.state.name())
                        .unwrap_or("queued");
                    Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("job_id", Value::Num(id as f64)),
                        ("state", Value::Str(state_name.into())),
                        ("coalesced", Value::Bool(coalesced)),
                    ])
                }
                Err(e) => error_json(&format!("{e:#}")),
            },
        },
        Command::EvalStatus(id) => match &state.eval_jobs {
            None => error_json("eval jobs are not enabled on this server"),
            Some(jobs) => match jobs.status(id) {
                Some(snap) => eval_job_json(&snap),
                None => error_json(&format!("unknown eval job_id {id}")),
            },
        },
        Command::Frontier(model) => match coord.frontier(&model) {
            Ok(f) => frontier_json(&f),
            Err(e) => error_json(&format!("{e:#}")),
        },
    }
}

/// One-line-in, one-value-out handler (used by tests and non-streaming
/// embedders; the TCP loop handles `sample_traj` separately so it can
/// stream multiple event lines).
pub fn handle_line(state: &ServerState, line: &str) -> Value {
    match parse_command(line) {
        Ok(cmd) => dispatch(state, cmd),
        Err(e) => error_json(&format!("bad request: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_line_rejects_garbage() {
        // A coordinator is only needed for valid commands; bad JSON fails
        // in parse_command before any routing, so a throwaway zoo-less call
        // is safe via parse error path.
        let v = parse_command("not json");
        assert!(v.is_err());
        let e = error_json("boom");
        assert_eq!(e.get("ok").unwrap().as_bool().unwrap(), false);
    }
}
