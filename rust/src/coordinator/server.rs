//! JSONL-over-TCP sampling server: thread-per-connection on top of the
//! batching [`Coordinator`]. Python never appears anywhere near this path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use super::batcher::Coordinator;
use super::protocol::{
    error_json, parse_command, response_to_json, traj_done_json, traj_step_json, Command,
};
use crate::json::Value;
use crate::log_info;

/// Serve forever on `addr` (blocks). Each accepted connection gets its own
/// thread; requests on one connection are handled sequentially, batching
/// happens across connections inside the coordinator.
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log_info!("serving on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_info!("accept error: {e}");
                continue;
            }
        };
        let coord = coord.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(coord, stream) {
                log_info!("connection ended: {e:#}");
            }
        });
    }
    Ok(())
}

fn write_event<W: Write>(writer: &mut W, v: &Value) -> Result<()> {
    writer.write_all(v.to_string_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

pub fn handle_connection(coord: Arc<Coordinator>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_command(&line) {
            // The streaming command writes multiple lines per request; all
            // other commands reply with exactly one line.
            Ok(Command::SampleTraj(req)) => {
                let result = coord.sample_traj(&req, &mut |step| {
                    write_event(&mut writer, &traj_step_json(&step))
                });
                match result {
                    Ok(resp) => write_event(&mut writer, &traj_done_json(&resp))?,
                    Err(e) => write_event(&mut writer, &error_json(&format!("{e:#}")))?,
                }
            }
            Ok(cmd) => write_event(&mut writer, &dispatch(&coord, cmd))?,
            Err(e) => write_event(&mut writer, &error_json(&format!("bad request: {e:#}")))?,
        }
    }
    log_info!("peer {peer:?} disconnected");
    Ok(())
}

/// Execute a single-response command.
fn dispatch(coord: &Coordinator, cmd: Command) -> Value {
    match cmd {
        Command::Ping => Value::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]),
        Command::List => {
            let names = coord
                .zoo()
                .model_names()
                .into_iter()
                .map(Value::Str)
                .collect();
            Value::obj(vec![("ok", Value::Bool(true)), ("models", Value::Arr(names))])
        }
        Command::Metrics => coord.metrics.snapshot(),
        Command::Sample(req) => match coord.submit(&req) {
            Ok(resp) => response_to_json(&resp),
            Err(e) => error_json(&format!("{e:#}")),
        },
        Command::SampleTraj(_) => {
            error_json("sample_traj is a streaming command; it is handled per-connection")
        }
    }
}

/// One-line-in, one-value-out handler (used by tests and non-streaming
/// embedders; the TCP loop handles `sample_traj` separately so it can
/// stream multiple event lines).
pub fn handle_line(coord: &Coordinator, line: &str) -> Value {
    match parse_command(line) {
        Ok(cmd) => dispatch(coord, cmd),
        Err(e) => error_json(&format!("bad request: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_line_rejects_garbage() {
        // A coordinator is only needed for valid commands; bad JSON fails
        // in parse_command before any routing, so a throwaway zoo-less call
        // is safe via parse error path.
        let v = parse_command("not json");
        assert!(v.is_err());
        let e = error_json("boom");
        assert_eq!(e.get("ok").unwrap().as_bool().unwrap(), false);
    }
}
