//! One function per paper table/figure (DESIGN.md §4 experiment index).
//!
//! Shared conventions:
//! * NFE grid {8, 10, 16, 20} maps to RK2-step counts n ∈ {4, 5, 8, 10}
//!   (two model evaluations per midpoint step) — exactly the paper's grid.
//! * "FD" = Fréchet distance vs GT-solver samples; "FD(data)" vs the target
//!   dataset (the FID-analog used in the tables).
//! * Every experiment writes a markdown report + a CSV of its series.

use anyhow::Result;

use super::context::ExpContext;
use super::report::{report_csv_rows, write_csv, Report, CSV_HEADER};
use crate::solvers::theta::Base;
use crate::models::VelocityModel;
use crate::tensor::Tensor;

const NFES: [usize; 4] = [8, 10, 16, 20];

/// Baseline solver specs at a given NFE for a model (dedicated-solver
/// analogs; see DESIGN.md §2 substitution table).
fn baselines(nfe: usize, model_sched: &str) -> Vec<String> {
    let mut out = vec![format!("rk1:n={nfe}")];
    if nfe % 2 == 0 {
        let n = nfe / 2;
        out.push(format!("rk2:n={n}"));
        out.push(format!("rk2:n={n}:grid=edm")); // EDM time grid
        out.push(format!("rk2:n={n}:grid=logsnr")); // DDIM/DEIS spacing
        // DPM-Solver-2 analog: midpoint along a transferred Gaussian path.
        // (The raw variance-exploding EDM path is too stiff for a fixed RK
        // transfer — that is exactly why EDM warps time, which the
        // grid=edm baseline above captures — so transfer targets stay in
        // the VP/CS family.)
        let target = if model_sched == "vp" { "cs" } else { "vp" };
        out.push(format!("rk2-target:n={n}:sched={target}"));
    }
    if nfe % 4 == 0 {
        out.push(format!("rk4:n={}", nfe / 4));
    }
    out
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 1: CIFAR10-analog — Bespoke vs dedicated solvers at NFE 10/20.
pub fn tab1(ctx: &mut ExpContext) -> Result<()> {
    let mut rep = Report::new("Table 1 — checker2 (CIFAR10 analog): FD(data) at NFE 10 and 20");
    rep.para(
        "Paper: Bespoke-RK2 beats every dedicated solver at low NFE across \
         eps-VP / FM-CS / FM-OT parameterizations. FD(data) is the FID analog.",
    );
    let mut csv = Vec::new();
    for model in ["checker2-vp", "checker2-cs", "checker2-ot"] {
        let sched = ctx.zoo.manifest().model(model)?.sched.clone();
        rep.section(model);
        let mut rows = Vec::new();
        for nfe in [10usize, 20] {
            for spec in baselines(nfe, &sched) {
                rows.push(ctx.eval_spec(model, &spec)?);
            }
            let bes = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
            rows.push(ctx.eval_sampler(model, &bes)?);
        }
        let gt = ctx.eval_gt(model)?;
        rows.push(gt);
        rep.sampler_table(&rows);
        csv.extend(report_csv_rows(model, &rows));
    }
    write_csv(&ctx.report_path("tab1.csv"), CSV_HEADER, &csv)?;
    rep.save(&ctx.report_path("tab1.md"))
}

/// Tables 2/3 core: best-FD per NFE + GT-FD + %time for a model list.
fn best_fd_table(ctx: &mut ExpContext, id: &str, title: &str, models: &[&str]) -> Result<()> {
    let mut rep = Report::new(title);
    rep.para(
        "Columns mirror the paper: FD(data) per NFE for the RK2-Bespoke \
         solver, the GT solver's FD(data), the ratio in %, and the Bespoke \
         training cost as GT-equivalent NFE (the analog of %GPU-time: our \
         'model pre-training' is free-form, so we report absolute cost).",
    );
    let mut md_rows = Vec::new();
    let mut csv = Vec::new();
    for model in models {
        let gt_rep = ctx.eval_gt(model)?;
        for nfe in NFES {
            let n = nfe / 2;
            let bes = ctx.bespoke_sampler(model, Base::Rk2, n, "full")?;
            let r = ctx.eval_sampler(model, &bes)?;
            let pct = 100.0 * r.fd_data / gt_rep.fd_data.max(1e-12);
            md_rows.push(vec![
                model.to_string(),
                format!("{nfe}"),
                format!("{:.4}", r.fd_data),
                format!("{:.4}", gt_rep.fd_data),
                format!("{:.0}%", pct),
                format!("{:.5}", r.rmse),
            ]);
            csv.extend(report_csv_rows(model, &[r]));
        }
    }
    rep.table(
        &["model", "NFE", "FD(data)", "GT-FD(data)", "% of GT", "RMSE"],
        &md_rows,
    );
    write_csv(&ctx.report_path(&format!("{id}.csv")), CSV_HEADER, &csv)?;
    rep.save(&ctx.report_path(&format!("{id}.md")))
}

/// Table 2: ImageNet-64/128 analog (tex8 ×3 parameterizations, tex16).
pub fn tab2(ctx: &mut ExpContext) -> Result<()> {
    best_fd_table(
        ctx,
        "tab2",
        "Table 2 — tex8/tex16 (ImageNet-64/128 analogs): Bespoke best FD per NFE",
        &["tex8-vp", "tex8-cs", "tex8-ot", "tex16-ot"],
    )
}

/// Table 3: CIFAR10 analog per-NFE Bespoke FD.
pub fn tab3(ctx: &mut ExpContext) -> Result<()> {
    best_fd_table(
        ctx,
        "tab3",
        "Table 3 — checker2 (CIFAR10 analog): Bespoke best FD per NFE",
        &["checker2-vp", "checker2-cs", "checker2-ot", "mlp2-ot"],
    )
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Figure 1: sampling-path visualization (2D PCA of GT / RK2 / Bespoke
/// trajectories from the same noise).
pub fn fig1(ctx: &mut ExpContext) -> Result<()> {
    use crate::solvers::dopri5::Dopri5;
    let model = "checker2-ot";
    let hlo = ctx.zoo.hlo(model)?;
    let (x0s, _) = ctx.gt(model)?;
    let x0 = x0s[0].clone();
    // GT dense path sampled at 101 times; RK2 and Bespoke at their grids.
    let dense = Dopri5::default().solve_model_dense(hlo.as_ref(), &x0)?;
    let mut rows = Vec::new();
    for i in 0..=100 {
        let t = i as f32 / 100.0;
        let x = dense.eval(t);
        for b in 0..4.min(x.rows()) {
            let r = x.row(b);
            rows.push(vec![
                "gt".into(),
                b.to_string(),
                format!("{t:.3}"),
                format!("{:.5}", r[0]),
                format!("{:.5}", r[1]),
            ]);
        }
    }
    // discrete solvers: log each step state
    let th = ctx.theta(model, Base::Rk2, 5, "full")?;
    let bes = crate::solvers::BespokeSolver::new(&th);
    let mut x = x0.clone();
    for i in 0..5 {
        for b in 0..4 {
            let r = x.row(b);
            rows.push(vec![
                "bespoke-rk2".into(),
                b.to_string(),
                format!("{:.3}", i as f32 / 5.0),
                format!("{:.5}", r[0]),
                format!("{:.5}", r[1]),
            ]);
        }
        x = bes.step(hlo.as_ref(), &x, i)?;
    }
    let mut xr = x0.clone();
    let rk2 = crate::solvers::rk::FixedGridSolver::uniform(crate::solvers::rk::BaseRk::Rk2, 5);
    // log rk2 path by stepping manually over its uniform grid
    for i in 0..5 {
        for b in 0..4 {
            let r = xr.row(b);
            rows.push(vec![
                "rk2".into(),
                b.to_string(),
                format!("{:.3}", i as f32 / 5.0),
                format!("{:.5}", r[0]),
                format!("{:.5}", r[1]),
            ]);
        }
        let mut f = |xx: &Tensor, t: f32| hlo.eval(xx, t);
        xr = crate::solvers::rk::BaseRk::Rk2.step(&mut f, &xr, i as f32 / 5.0, 0.2)?;
    }
    let _ = rk2;
    write_csv(
        &ctx.report_path("fig1_paths.csv"),
        &["solver", "sample", "t", "x", "y"],
        &rows,
    )?;
    let mut rep = Report::new("Figure 1 — sampling paths (GT vs RK2 vs Bespoke-RK2, d=2)");
    rep.para("Raw trajectories in fig1_paths.csv (2-D data: PCA plane == data plane).");
    rep.save(&ctx.report_path("fig1.md"))
}

/// Figures 3/9/10: RK1 vs RK2 vs their Bespoke versions, RMSE+PSNR vs NFE.
pub fn fig3_9_10(ctx: &mut ExpContext, id: &str, model: &str) -> Result<()> {
    let mut rep = Report::new(format!(
        "Figure {id} — RK1/RK2 and Bespoke versions on {model}: RMSE & PSNR vs NFE"
    ));
    let mut rows = Vec::new();
    for nfe in NFES {
        rows.push(ctx.eval_spec(model, &format!("rk1:n={nfe}"))?);
        if ctx.zoo.manifest().lossgrad(model, "rk1", nfe).is_ok() {
            let bes1 = ctx.bespoke_sampler(model, Base::Rk1, nfe, "full")?;
            rows.push(ctx.eval_sampler(model, &bes1)?);
        }
        if nfe % 2 == 0 {
            rows.push(ctx.eval_spec(model, &format!("rk2:n={}", nfe / 2))?);
            let bes2 = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
            rows.push(ctx.eval_sampler(model, &bes2)?);
        }
    }
    rep.para(
        "Paper finding: at equal NFE budget, RK2-Bespoke < RK1-Bespoke in \
         RMSE (and both beat their plain versions).",
    );
    rep.sampler_table(&rows);
    write_csv(
        &ctx.report_path(&format!("{id}.csv")),
        CSV_HEADER,
        &report_csv_rows(model, &rows),
    )?;
    rep.save(&ctx.report_path(&format!("{id}.md")))
}

/// Figure 4: Bespoke vs the EDM heuristic on the eps-VP model.
pub fn fig4(ctx: &mut ExpContext) -> Result<()> {
    let model = "checker2-vp";
    let mut rep = Report::new("Figure 4 — EDM heuristic vs Bespoke on the VP model");
    let mut rows = Vec::new();
    for nfe in NFES {
        rows.push(ctx.eval_spec(model, &format!("rk1:n={nfe}"))?); // Euler
        if nfe % 2 == 0 {
            let n = nfe / 2;
            rows.push(ctx.eval_spec(model, &format!("rk2:n={n}:grid=edm"))?);
            let bes = ctx.bespoke_sampler(model, Base::Rk2, n, "full")?;
            rows.push(ctx.eval_sampler(model, &bes)?);
        }
    }
    rep.para(
        "Paper: RK2-Bespoke reaches the EDM curve's quality with ~40% fewer \
         NFE. Compare fd_data across equal NFE.",
    );
    rep.sampler_table(&rows);
    write_csv(&ctx.report_path("fig4.csv"), CSV_HEADER, &report_csv_rows(model, &rows))?;
    rep.save(&ctx.report_path("fig4.md"))
}

/// Figure 5: FD + RMSE vs NFE across datasets/models with all baselines.
pub fn fig5(ctx: &mut ExpContext) -> Result<()> {
    let mut rep = Report::new("Figure 5 — FD & RMSE vs NFE across models (all solvers)");
    let mut csv = Vec::new();
    for model in ["checker2-ot", "tex8-ot", "tex16-ot"] {
        let sched = ctx.zoo.manifest().model(model)?.sched.clone();
        rep.section(model);
        let mut rows = Vec::new();
        for nfe in NFES {
            for spec in baselines(nfe, &sched) {
                rows.push(ctx.eval_spec(model, &spec)?);
            }
            if nfe % 2 == 0 {
                let bes = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
                rows.push(ctx.eval_sampler(model, &bes)?);
            }
        }
        rep.sampler_table(&rows);
        csv.extend(report_csv_rows(model, &rows));
    }
    write_csv(&ctx.report_path("fig5.csv"), CSV_HEADER, &csv)?;
    rep.save(&ctx.report_path("fig5.md"))
}

/// Figure 11: CIFAR analog FID/RMSE/PSNR vs NFE for all three models.
pub fn fig11(ctx: &mut ExpContext) -> Result<()> {
    let mut rep = Report::new("Figure 11 — checker2 models: FD/RMSE/PSNR vs NFE");
    let mut csv = Vec::new();
    for model in ["checker2-vp", "checker2-cs", "checker2-ot"] {
        rep.section(model);
        let mut rows = Vec::new();
        for nfe in NFES {
            rows.push(ctx.eval_spec(model, &format!("rk1:n={nfe}"))?);
            if nfe % 2 == 0 {
                rows.push(ctx.eval_spec(model, &format!("rk2:n={}", nfe / 2))?);
                let bes = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
                rows.push(ctx.eval_sampler(model, &bes)?);
            }
            if nfe % 4 == 0 {
                rows.push(ctx.eval_spec(model, &format!("rk4:n={}", nfe / 4))?);
            }
        }
        rep.sampler_table(&rows);
        csv.extend(report_csv_rows(model, &rows));
    }
    write_csv(&ctx.report_path("fig11.csv"), CSV_HEADER, &csv)?;
    rep.save(&ctx.report_path("fig11.md"))
}

/// Figure 12: validation RMSE vs training iteration for each n.
pub fn fig12(ctx: &mut ExpContext) -> Result<()> {
    let model = "tex8-ot";
    let mut csv = Vec::new();
    for n in [4usize, 5, 8, 10] {
        // force a fresh training run so the history exists
        let key = format!("{model}_rk2_n{n}_full");
        if !ctx.histories.contains_key(&key) {
            let outcome = ctx.train_bespoke(model, Base::Rk2, n, "full")?;
            // keep the theta cache warm for other experiments
            let path = ctx.out_dir.join("thetas").join(format!("theta_{model}_rk2_n{n}.json"));
            if !path.exists() {
                outcome.best.save(&path)?;
            }
        }
        for p in &ctx.histories[&key] {
            if !p.val_rmse.is_nan() {
                csv.push(vec![
                    n.to_string(),
                    p.iter.to_string(),
                    format!("{:.6}", p.loss),
                    format!("{:.6}", p.val_rmse),
                ]);
            }
        }
    }
    write_csv(
        &ctx.report_path("fig12.csv"),
        &["n", "iter", "loss", "val_rmse"],
        &csv,
    )?;
    let mut rep = Report::new("Figure 12 — validation RMSE vs Bespoke training iteration (tex8-ot)");
    rep.para("Series in fig12.csv; paper shows monotone-ish decrease per n.");
    rep.save(&ctx.report_path("fig12.md"))
}

/// Figure 13: PSNR vs NFE for the ImageNet analogs.
pub fn fig13(ctx: &mut ExpContext) -> Result<()> {
    let mut rep = Report::new("Figure 13 — tex8/tex16: PSNR vs NFE");
    let mut csv = Vec::new();
    for model in ["tex8-ot", "tex16-ot"] {
        rep.section(model);
        let mut rows = Vec::new();
        for nfe in NFES {
            rows.push(ctx.eval_spec(model, &format!("rk1:n={nfe}"))?);
            if nfe % 2 == 0 {
                rows.push(ctx.eval_spec(model, &format!("rk2:n={}", nfe / 2))?);
                let bes = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
                rows.push(ctx.eval_sampler(model, &bes)?);
            }
            if nfe % 4 == 0 {
                rows.push(ctx.eval_spec(model, &format!("rk4:n={}", nfe / 4))?);
            }
        }
        rep.sampler_table(&rows);
        csv.extend(report_csv_rows(model, &rows));
    }
    write_csv(&ctx.report_path("fig13.csv"), CSV_HEADER, &csv)?;
    rep.save(&ctx.report_path("fig13.md"))
}

/// Figure 14: AFHQ analog (largest d): PSNR/RMSE vs NFE.
pub fn fig14(ctx: &mut ExpContext) -> Result<()> {
    let model = "tex16-ot";
    let mut rep = Report::new("Figure 14 — tex16 (AFHQ-256 analog): PSNR & RMSE vs NFE");
    let mut rows = Vec::new();
    for nfe in NFES {
        rows.push(ctx.eval_spec(model, &format!("rk1:n={nfe}"))?);
        if nfe % 2 == 0 {
            rows.push(ctx.eval_spec(model, &format!("rk2:n={}", nfe / 2))?);
            let bes = ctx.bespoke_sampler(model, Base::Rk2, nfe / 2, "full")?;
            rows.push(ctx.eval_sampler(model, &bes)?);
        }
        if nfe % 4 == 0 {
            rows.push(ctx.eval_spec(model, &format!("rk4:n={}", nfe / 4))?);
        }
    }
    rep.sampler_table(&rows);
    write_csv(&ctx.report_path("fig14.csv"), CSV_HEADER, &report_csv_rows(model, &rows))?;
    rep.save(&ctx.report_path("fig14.md"))
}

/// Figure 15: ablation — time-only vs scale-only vs full transform.
pub fn fig15(ctx: &mut ExpContext) -> Result<()> {
    let model = "tex8-ot";
    let mut rep = Report::new("Figure 15 — ablation: time-only / scale-only / full (tex8-ot)");
    let mut rows = Vec::new();
    for n in [4usize, 8] {
        rows.push(ctx.eval_spec(model, &format!("rk2:n={n}"))?);
        for mode in ["time-only", "scale-only", "full"] {
            let bes = ctx.bespoke_sampler(model, Base::Rk2, n, mode)?;
            rows.push(ctx.eval_sampler(model, &bes)?);
        }
    }
    rep.para(
        "Paper: time optimization provides most of the gain; adding scale \
         improves RMSE at low NFE and FID throughout.",
    );
    rep.sampler_table(&rows);
    write_csv(&ctx.report_path("fig15.csv"), CSV_HEADER, &report_csv_rows(model, &rows))?;
    rep.save(&ctx.report_path("fig15.md"))
}

/// Figure 16: transfer a Bespoke solver across resolutions (tex8 -> tex16).
pub fn fig16(ctx: &mut ExpContext) -> Result<()> {
    let mut rep = Report::new("Figure 16 — transferred Bespoke solver (tex8-ot θ on tex16-ot)");
    let mut rows = Vec::new();
    for n in [4usize, 5, 8, 10] {
        rows.push(ctx.eval_spec("tex16-ot", &format!("rk2:n={n}"))?);
        // native theta
        let native = ctx.bespoke_sampler("tex16-ot", Base::Rk2, n, "full")?;
        rows.push(ctx.eval_sampler("tex16-ot", &native)?);
        // transferred theta (theta is resolution-independent: pure solver params)
        let th8 = ctx.theta("tex8-ot", Base::Rk2, n, "full")?;
        let transferred = crate::solvers::BespokeSolver::with_label(
            &th8,
            format!("bespoke-rk2:n={n}:transfer(tex8)"),
        );
        rows.push(ctx.eval_sampler("tex16-ot", &transferred)?);
    }
    rep.para(
        "Paper: the transferred solver is worse than the native Bespoke \
         solver but still clearly better than the RK2 baseline.",
    );
    rep.sampler_table(&rows);
    write_csv(
        &ctx.report_path("fig16.csv"),
        CSV_HEADER,
        &report_csv_rows("tex16-ot", &rows),
    )?;
    rep.save(&ctx.report_path("fig16.md"))
}

/// Figures 17-19: dump the learned theta parameters for inspection.
pub fn fig17_19(ctx: &mut ExpContext) -> Result<()> {
    let mut csv = Vec::new();
    for model in ["checker2-ot", "checker2-cs", "checker2-vp"] {
        for n in [4usize, 5, 8, 10] {
            let th = ctx.theta(model, Base::Rk2, n, "full")?;
            let dec = th.decode();
            for j in 0..dec.t.len() {
                csv.push(vec![
                    model.to_string(),
                    n.to_string(),
                    format!("{:.2}", j as f32 / 2.0), // grid index i (halves)
                    format!("{:.6}", dec.t[j]),
                    if j < dec.tdot.len() { format!("{:.6}", dec.tdot[j]) } else { String::new() },
                    format!("{:.6}", dec.s[j]),
                    if j < dec.sdot.len() { format!("{:.6}", dec.sdot[j]) } else { String::new() },
                ]);
            }
        }
    }
    write_csv(
        &ctx.report_path("fig17_19_theta.csv"),
        &["model", "n", "grid_i", "t", "tdot", "s", "sdot"],
        &csv,
    )?;
    let mut rep = Report::new("Figures 17-19 — learned Bespoke parameters θ");
    rep.para("Decoded (t_i, ṫ_i, s_i, ṡ_i) sequences in fig17_19_theta.csv.");
    rep.save(&ctx.report_path("fig17.md"))
}
