//! Experiment harness: regenerates **every table and figure** of the
//! paper's evaluation on this repo's substrates (DESIGN.md §4 maps each
//! experiment id to the paper artifact it reproduces).
//!
//! `repro exp <id>` runs one experiment; `repro exp all` runs the suite.
//! Reports land in `out/reports/<id>.md` (+ `.csv` series files); trained
//! thetas are cached in `out/thetas/` and re-used across experiments.

pub mod context;
pub mod experiments;
pub mod report;

pub use context::ExpContext;

use anyhow::{bail, Result};

/// All experiment ids in suggested execution order (cheap → expensive).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig17", "tab3", "tab1", "fig10", "fig11", "fig4", "fig12", "fig3", "fig9",
    "fig13", "fig5", "fig14", "fig15", "fig16", "tab2",
];

pub fn run(ctx: &mut ExpContext, id: &str) -> Result<()> {
    match id {
        "tab1" => experiments::tab1(ctx),
        "tab2" => experiments::tab2(ctx),
        "tab3" => experiments::tab3(ctx),
        "fig1" => experiments::fig1(ctx),
        "fig3" => experiments::fig3_9_10(ctx, "fig3", "tex8-ot"),
        "fig9" => experiments::fig3_9_10(ctx, "fig9", "tex8-vp"),
        "fig10" => experiments::fig3_9_10(ctx, "fig10", "checker2-ot"),
        "fig4" => experiments::fig4(ctx),
        "fig5" => experiments::fig5(ctx),
        "fig11" => experiments::fig11(ctx),
        "fig12" => experiments::fig12(ctx),
        "fig13" => experiments::fig13(ctx),
        "fig14" => experiments::fig14(ctx),
        "fig15" => experiments::fig15(ctx),
        "fig16" => experiments::fig16(ctx),
        "fig17" => experiments::fig17_19(ctx),
        "all" => {
            for id in ALL_EXPERIMENTS {
                crate::log_info!("=== experiment {id} ===");
                run(ctx, id)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment {id:?}; available: {ALL_EXPERIMENTS:?} or 'all'"),
    }
}
