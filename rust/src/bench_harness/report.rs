//! Markdown + CSV report writers for the experiment harness.

use std::path::Path;

use anyhow::Result;

use crate::eval::SamplerReport;

pub struct Report {
    title: String,
    lines: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Report {
        let title = title.into();
        Report { lines: vec![format!("# {title}"), String::new()], title }
    }

    pub fn para(&mut self, text: impl AsRef<str>) {
        self.lines.push(text.as_ref().to_string());
        self.lines.push(String::new());
    }

    pub fn section(&mut self, name: impl AsRef<str>) {
        self.lines.push(format!("## {}", name.as_ref()));
        self.lines.push(String::new());
    }

    /// A markdown table of sampler reports.
    pub fn sampler_table(&mut self, rows: &[SamplerReport]) {
        self.lines.push(
            "| sampler | NFE | RMSE | PSNR | FD (vs GT) | FD (vs data) | SWD | ms/batch |".into(),
        );
        self.lines
            .push("|---|---:|---:|---:|---:|---:|---:|---:|".into());
        for r in rows {
            self.lines.push(format!(
                "| {} | {} | {:.5} | {:.2} | {:.4} | {:.4} | {:.4} | {:.1} |",
                r.sampler, r.nfe, r.rmse, r.psnr, r.fd, r.fd_data, r.swd, r.wall_ms_per_batch
            ));
        }
        self.lines.push(String::new());
    }

    /// Generic markdown table.
    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        self.lines.push(format!("| {} |", header.join(" | ")));
        self.lines
            .push(format!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
        for r in rows {
            self.lines.push(format!("| {} |", r.join(" | ")));
        }
        self.lines.push(String::new());
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.lines.join("\n"))?;
        crate::log_info!("wrote {} ({})", path.display(), self.title);
        Ok(())
    }
}

/// CSV writer for figure series.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Rows of sampler reports as CSV cells (shared by the figure series).
pub fn report_csv_rows(model: &str, rows: &[SamplerReport]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                model.to_string(),
                r.sampler.clone(),
                r.nfe.to_string(),
                format!("{:.6}", r.rmse),
                format!("{:.3}", r.psnr),
                format!("{:.5}", r.fd),
                format!("{:.5}", r.fd_data),
                format!("{:.5}", r.swd),
            ]
        })
        .collect()
}

pub const CSV_HEADER: &[&str] =
    &["model", "sampler", "nfe", "rmse", "psnr", "fd_gt", "fd_data", "swd"];
