//! Shared experiment context: GT-solution caching, on-demand Bespoke
//! training with theta checkpoints, and sampler evaluation plumbing.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context as _, Result};

use crate::bespoke::{self, TrainOutcome};
use crate::config::{Config, TrainConfig};
use crate::eval::{evaluate_sampler, SamplerReport};
use crate::models::{HloModel, VelocityModel, Zoo};
use crate::runtime::Executable;
use crate::solvers::theta::{Base, RawTheta};
use crate::solvers::{BespokeSolver, Dopri5, Sampler, SolverSpec};
use crate::tensor::Tensor;
use crate::util::Rng;
use crate::log_info;

pub struct ExpContext {
    pub zoo: Arc<Zoo>,
    pub cfg: Config,
    pub out_dir: PathBuf,
    /// (model, n_batches) -> (x0 batches, GT batches).
    gt_cache: BTreeMap<(String, usize), (Vec<Tensor>, Vec<Tensor>)>,
    /// dataset tensors by name.
    data_cache: BTreeMap<String, Tensor>,
    /// training histories recorded while building thetas (for fig12).
    pub histories: BTreeMap<String, Vec<bespoke::TrainPoint>>,
}

impl ExpContext {
    pub fn new(zoo: Arc<Zoo>, cfg: Config) -> Result<ExpContext> {
        let out_dir = if cfg.out_dir.is_empty() {
            PathBuf::from("out")
        } else {
            PathBuf::from(&cfg.out_dir)
        };
        std::fs::create_dir_all(out_dir.join("reports"))?;
        std::fs::create_dir_all(out_dir.join("thetas"))?;
        Ok(ExpContext {
            zoo,
            cfg,
            out_dir,
            gt_cache: BTreeMap::new(),
            data_cache: BTreeMap::new(),
            histories: BTreeMap::new(),
        })
    }

    pub fn report_path(&self, name: &str) -> PathBuf {
        self.out_dir.join("reports").join(name)
    }

    /// Number of eval batches for a model (targets `eval.metric_samples`).
    pub fn n_batches(&self, model: &str) -> usize {
        let b = self.zoo.manifest().model(model).map(|m| m.batch).unwrap_or(64);
        (self.cfg.eval.metric_samples / b).clamp(4, 16)
    }

    /// Noise + GT-solver solutions for a model (cached).
    pub fn gt(&mut self, model: &str) -> Result<(&[Tensor], &[Tensor])> {
        let nb = self.n_batches(model);
        let key = (model.to_string(), nb);
        if !self.gt_cache.contains_key(&key) {
            let hlo = self.zoo.hlo(model)?;
            let (b, d) = (hlo.batch(), hlo.dim());
            let mut rng = Rng::new(self.cfg.eval.seed);
            let gt_solver = Dopri5 {
                rtol: self.cfg.eval.gt_tol,
                atol: self.cfg.eval.gt_tol,
                max_steps: 100_000,
            };
            let mut x0s = Vec::with_capacity(nb);
            let mut gts = Vec::with_capacity(nb);
            log_info!("[gt] solving {nb} GT batches for {model}...");
            for _ in 0..nb {
                let x0 = Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
                let sol = gt_solver.solve_model_dense(hlo.as_ref(), &x0)?;
                gts.push(sol.final_state().clone());
                x0s.push(x0);
            }
            self.gt_cache.insert(key.clone(), (x0s, gts));
        }
        let (a, b) = self.gt_cache.get(&key).unwrap();
        Ok((a.as_slice(), b.as_slice()))
    }

    /// Target dataset tensor for a model (for the FID-analog fd_data).
    pub fn dataset(&mut self, model: &str) -> Result<Tensor> {
        let ds_name = self.zoo.manifest().model(model)?.dataset.clone();
        if !self.data_cache.contains_key(&ds_name) {
            let t = self.zoo.manifest().load_dataset(&ds_name)?;
            self.data_cache.insert(ds_name.clone(), t);
        }
        Ok(self.data_cache.get(&ds_name).unwrap().clone())
    }

    /// Evaluate a sampler spec (solver spec string) on a model.
    pub fn eval_spec(&mut self, model: &str, spec: &str) -> Result<SamplerReport> {
        self.eval_solver_spec(model, &SolverSpec::parse(spec)?)
    }

    /// Evaluate a typed solver spec on a model.
    pub fn eval_solver_spec(&mut self, model: &str, spec: &SolverSpec) -> Result<SamplerReport> {
        let sched = self.zoo.scheduler(model)?;
        let sampler = spec.build(sched)?;
        self.eval_sampler(model, sampler.as_ref())
    }

    /// Evaluate an instantiated sampler on a model.
    pub fn eval_sampler(&mut self, model: &str, sampler: &dyn Sampler) -> Result<SamplerReport> {
        let hlo = self.zoo.hlo(model)?;
        let data = self.dataset(model)?;
        let (x0, gt) = self.gt(model)?;
        // borrow juggling: clone slices (Tensor clones are cheap enough here)
        let x0v: Vec<Tensor> = x0.to_vec();
        let gtv: Vec<Tensor> = gt.to_vec();
        evaluate_sampler(hlo.as_ref(), sampler, &x0v, &gtv, Some(&data))
    }

    /// GT-solver report (for GT-FD reference rows).
    pub fn eval_gt(&mut self, model: &str) -> Result<SamplerReport> {
        let tol = self.cfg.eval.gt_tol;
        self.eval_spec(model, &format!("dopri5:tol={tol:e}"))
    }

    fn theta_path(&self, model: &str, base: Base, n: usize, ablation: &str) -> PathBuf {
        let suffix = if ablation == "full" { String::new() } else { format!("_{ablation}") };
        self.out_dir
            .join("thetas")
            .join(format!("theta_{model}_{}_n{n}{suffix}.json", base.name()))
    }

    /// Load a cached theta or train one (checkpointing to out/thetas).
    pub fn theta(&mut self, model: &str, base: Base, n: usize, ablation: &str) -> Result<RawTheta> {
        let path = self.theta_path(model, base, n, ablation);
        if path.exists() {
            return RawTheta::load(&path);
        }
        let outcome = self.train_bespoke(model, base, n, ablation)?;
        outcome.best.save(&path)?;
        Ok(outcome.best)
    }

    /// Train a Bespoke solver now (recording history for fig12).
    pub fn train_bespoke(
        &mut self,
        model: &str,
        base: Base,
        n: usize,
        ablation: &str,
    ) -> Result<TrainOutcome> {
        let hlo: Arc<HloModel> = self.zoo.hlo(model)?;
        let lg = self.zoo.manifest().lossgrad(model, base.name(), n)?;
        let exe = Executable::load(&self.zoo.manifest().path(&lg.file))
            .with_context(|| format!("loading lossgrad for {model} {} n={n}", base.name()))?;
        let tcfg = TrainConfig { ablation: ablation.into(), ..self.cfg.train.clone() };
        log_info!(
            "[train] bespoke-{} n={n} for {model} ({} iters, ablation={ablation})",
            base.name(),
            tcfg.iters
        );
        let outcome = bespoke::train(&hlo, &exe, base, n, &tcfg)?;
        let hist_key = format!("{model}_{}_n{n}_{ablation}", base.name());
        self.histories.insert(hist_key, outcome.history.clone());
        Ok(outcome)
    }

    /// Bespoke sampler for (model, base, n), training if necessary.
    pub fn bespoke_sampler(
        &mut self,
        model: &str,
        base: Base,
        n: usize,
        ablation: &str,
    ) -> Result<BespokeSolver> {
        let th = self.theta(model, base, n, ablation)?;
        let label = if ablation == "full" {
            format!("bespoke-{}:n={n}", base.name())
        } else {
            format!("bespoke-{}:n={n}:{ablation}", base.name())
        };
        Ok(BespokeSolver::with_label(&th, label))
    }
}
