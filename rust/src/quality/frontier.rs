//! Per-model Pareto frontiers over scorecards, and budget resolution
//! against them.
//!
//! The frontier answers the paper's central serving question — "what is the
//! best sample I can get for this budget?" — from measured data: every
//! scorecard row (base RK grids, dopri5, every bespoke artifact version) is
//! a candidate point in (NFE, RMSE) space, and the frontier is the
//! efficient subset.
//!
//! **Determinism is a contract.** The same scorecard set produces
//! byte-identical frontier JSON in any insertion order: candidates are
//! sorted by a total order (NFE, RMSE, wall-ms, artifact version, solver
//! string) before the dominance scan, and budget resolution breaks ties by
//! fixed rules (best quality → fewer NFE → older artifact version → solver
//! string). Pinned by `rust/tests/quality_frontier.rs`.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::scorecard::Scorecard;
use crate::json::Value;
use crate::registry::{ArtifactKey, ManifestStamp, Registry};

/// One efficient (solver, NFE, quality) point of a model's frontier.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    /// Concrete, buildable spec (`rk2:n=4`, `bespoke:path=...`).
    pub solver: String,
    /// The scorecard template the point came from (display only).
    pub source: String,
    /// Bespoke artifact binding, when the row measured a registry artifact.
    pub artifact: Option<(ArtifactKey, u64)>,
    pub nfe: u64,
    pub rmse: f32,
    pub psnr: f32,
    pub fd: f64,
    pub swd: f32,
    pub wall_ms: f64,
}

impl FrontierPoint {
    /// Artifact version for tie-breaking (0 = baseline, which sorts as
    /// "oldest").
    fn version(&self) -> u64 {
        self.artifact.as_ref().map(|(_, v)| *v).unwrap_or(0)
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("solver", Value::Str(self.solver.clone())),
            ("source", Value::Str(self.source.clone())),
            ("nfe", Value::Num(self.nfe as f64)),
            ("rmse", Value::num_or_null(self.rmse as f64)),
            ("psnr", Value::num_or_null(self.psnr as f64)),
            ("fd", Value::num_or_null(self.fd)),
            ("swd", Value::num_or_null(self.swd as f64)),
            ("wall_ms", Value::num_or_null(self.wall_ms)),
        ];
        if let Some((key, ver)) = &self.artifact {
            fields.push((
                "artifact",
                Value::obj(vec![
                    ("model", Value::Str(key.model.clone())),
                    ("base", Value::Str(key.base.name().into())),
                    ("n", Value::Num(key.n as f64)),
                    ("ablation", Value::Str(key.ablation.clone())),
                    ("version", Value::Num(*ver as f64)),
                ]),
            ));
        }
        Value::obj(fields)
    }
}

/// A sampling budget: the client states a constraint, the frontier resolves
/// it to a concrete solver spec. Exactly one dimension per budget.
#[derive(Clone, Debug, PartialEq)]
pub enum Budget {
    /// At most this many model evaluations per sample batch.
    NfeMax(u64),
    /// At most this many milliseconds of solve wall time per batch (as
    /// measured on the eval host — advisory, not an SLA).
    LatencyMs(f64),
    /// At least this quality: RMSE vs the GT solver at most `x`.
    RmseMax(f32),
}

impl Budget {
    /// Parse the wire form: an object with exactly one of
    /// `{"nfe_max": N}`, `{"latency_ms": X}`, `{"quality": "rmse<=X"}`.
    pub fn from_json(v: &Value) -> Result<Budget> {
        let obj = v.as_obj().context("budget must be an object")?;
        if obj.len() != 1 {
            bail!("budget takes exactly one of nfe_max | latency_ms | quality");
        }
        let out = if let Some(n) = v.get_opt("nfe_max") {
            Budget::NfeMax(n.as_usize()? as u64)
        } else if let Some(l) = v.get_opt("latency_ms") {
            Budget::LatencyMs(l.as_f64()?)
        } else if let Some(q) = v.get_opt("quality") {
            Budget::parse_quality(q.as_str()?)?
        } else {
            let key = obj.keys().next().map(String::as_str).unwrap_or("");
            bail!("unknown budget key {key:?} (nfe_max | latency_ms | quality)");
        };
        out.validate()?;
        Ok(out)
    }

    /// Parse the CLI form: `nfe_max=N` | `latency_ms=X` | `rmse<=X`.
    pub fn parse(s: &str) -> Result<Budget> {
        let out = if let Some(n) = s.strip_prefix("nfe_max=") {
            Budget::NfeMax(n.parse().with_context(|| format!("bad nfe_max in {s:?}"))?)
        } else if let Some(l) = s.strip_prefix("latency_ms=") {
            Budget::LatencyMs(l.parse().with_context(|| format!("bad latency_ms in {s:?}"))?)
        } else if s.starts_with("rmse<=") {
            Budget::parse_quality(s)?
        } else {
            bail!("bad budget {s:?} (nfe_max=N | latency_ms=X | rmse<=X)");
        };
        out.validate()?;
        Ok(out)
    }

    fn parse_quality(s: &str) -> Result<Budget> {
        let x = s
            .strip_prefix("rmse<=")
            .with_context(|| format!("bad quality budget {s:?} (expected rmse<=X)"))?;
        Ok(Budget::RmseMax(
            x.parse().with_context(|| format!("bad rmse bound in {s:?}"))?,
        ))
    }

    fn validate(&self) -> Result<()> {
        match self {
            Budget::NfeMax(n) if *n == 0 => bail!("nfe_max must be >= 1"),
            Budget::LatencyMs(l) if !(l.is_finite() && *l > 0.0) => {
                bail!("latency_ms must be a positive finite number, got {l}")
            }
            Budget::RmseMax(x) if !(x.is_finite() && *x > 0.0) => {
                bail!("rmse bound must be a positive finite number, got {x}")
            }
            _ => Ok(()),
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            Budget::NfeMax(n) => Value::obj(vec![("nfe_max", Value::Num(*n as f64))]),
            Budget::LatencyMs(l) => Value::obj(vec![("latency_ms", Value::Num(*l))]),
            Budget::RmseMax(x) => {
                Value::obj(vec![("quality", Value::Str(format!("rmse<={x}")))])
            }
        }
    }
}

impl fmt::Display for Budget {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Budget::NfeMax(n) => write!(f, "nfe_max={n}"),
            Budget::LatencyMs(l) => write!(f, "latency_ms={l}"),
            Budget::RmseMax(x) => write!(f, "rmse<={x}"),
        }
    }
}

/// A model's Pareto frontier: points with strictly increasing NFE and
/// strictly decreasing RMSE.
#[derive(Clone, Debug)]
pub struct Frontier {
    pub model: String,
    /// Candidate rows considered (before dominance filtering).
    pub candidates: usize,
    pub points: Vec<FrontierPoint>,
}

impl Frontier {
    /// Build the frontier from scorecards. Rows with non-finite RMSE or
    /// zero NFE are excluded (nothing to trade off). Insertion order of
    /// `cards` (and of rows within them) does not affect the result.
    pub fn build(model: &str, cards: &[&Scorecard]) -> Frontier {
        let mut cand: Vec<FrontierPoint> = Vec::new();
        for card in cards {
            if card.model != model {
                continue;
            }
            for row in &card.rows {
                if !row.rmse.is_finite() || row.nfe == 0 {
                    continue;
                }
                cand.push(FrontierPoint {
                    solver: row.solver.clone(),
                    source: card.solver.clone(),
                    artifact: card.artifact.clone(),
                    nfe: row.nfe,
                    rmse: row.rmse,
                    psnr: row.psnr,
                    fd: row.fd,
                    swd: row.swd,
                    wall_ms: row.wall_ms,
                });
            }
        }
        let candidates = cand.len();
        // Total order => deterministic frontier for any input order. All
        // sort keys are finite (RMSE filtered above; wall_ms compared
        // NaN-last just in case).
        cand.sort_by(|a, b| {
            a.nfe
                .cmp(&b.nfe)
                .then(a.rmse.total_cmp(&b.rmse))
                .then(a.wall_ms.total_cmp(&b.wall_ms))
                .then(a.version().cmp(&b.version()))
                .then(a.solver.cmp(&b.solver))
        });
        // Dominance scan: keep a point iff it strictly improves RMSE over
        // everything cheaper (equal-NFE duplicates lose to the first, which
        // the sort placed best).
        let mut points: Vec<FrontierPoint> = Vec::new();
        for p in cand {
            match points.last() {
                None => points.push(p),
                Some(last) if p.nfe > last.nfe && p.rmse < last.rmse => points.push(p),
                Some(_) => {}
            }
        }
        Frontier { model: model.to_string(), candidates, points }
    }

    /// Resolve a budget to the best frontier point, or an error naming the
    /// tightest constraint when nothing qualifies. Tie-break contract (the
    /// frontier's strict ordering makes real ties impossible, but the rules
    /// are enforced generically so resolution stays deterministic even if
    /// the point set changes shape): best quality → fewer NFE → older
    /// artifact version → solver string; quality budgets minimize NFE
    /// first, then RMSE.
    pub fn resolve(&self, budget: &Budget) -> Result<&FrontierPoint> {
        let qualifies: Vec<&FrontierPoint> = self
            .points
            .iter()
            .filter(|p| match budget {
                Budget::NfeMax(k) => p.nfe <= *k,
                Budget::LatencyMs(l) => p.wall_ms.is_finite() && p.wall_ms <= *l,
                Budget::RmseMax(x) => p.rmse <= *x,
            })
            .collect();
        let best = match budget {
            Budget::RmseMax(_) => qualifies.into_iter().min_by(|a, b| {
                a.nfe
                    .cmp(&b.nfe)
                    .then(a.rmse.total_cmp(&b.rmse))
                    .then(a.version().cmp(&b.version()))
                    .then(a.solver.cmp(&b.solver))
            }),
            _ => qualifies.into_iter().min_by(|a, b| {
                a.rmse
                    .total_cmp(&b.rmse)
                    .then(a.nfe.cmp(&b.nfe))
                    .then(a.version().cmp(&b.version()))
                    .then(a.solver.cmp(&b.solver))
            }),
        };
        best.with_context(|| {
            format!(
                "budget {budget} is unsatisfiable for model {}: {} frontier \
                 point(s), none qualify (evaluate more solvers or relax the \
                 budget)",
                self.model,
                self.points.len()
            )
        })
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("model", Value::Str(self.model.clone())),
            ("candidates", Value::Num(self.candidates as f64)),
            (
                "points",
                Value::Arr(self.points.iter().map(|p| p.to_json()).collect()),
            ),
        ])
    }
}

/// Build a model's frontier from every scorecard currently registered for
/// it (hash-checked loads; a corrupt scorecard is an error, not a silent
/// hole in the frontier).
pub fn build_frontier(registry: &Registry, model: &str) -> Result<Frontier> {
    let mut cards = Vec::new();
    for rec in registry.eval_records() {
        if rec.model != model {
            continue;
        }
        // Numeric quarantine (DESIGN.md §14): scorecards bound to a
        // quarantined artifact version drop out of the frontier, so budget
        // routing cannot pick a checkpoint that produced non-finite state.
        // (`frontier_pins` deliberately does NOT apply this filter: the
        // quarantined theta must survive gc for the lifting re-eval.)
        if let Some((key, ver)) = &rec.artifact {
            if registry.find(key, *ver).is_some_and(|r| r.quarantined) {
                continue;
            }
        }
        let bytes = registry.load_eval_bytes(&rec)?;
        cards.push(
            Scorecard::from_json(&Value::parse(&bytes).context("parsing scorecard")?)
                .with_context(|| format!("decoding scorecard {}", rec.file))?,
        );
    }
    let refs: Vec<&Scorecard> = cards.iter().collect();
    Ok(Frontier::build(model, &refs))
}

/// Every artifact version referenced by any model's current frontier —
/// the versions `registry gc` must pin so budget routing never loses a
/// checkpoint it would serve.
///
/// Unlike [`build_frontier`], scorecards that fail to load (corruption,
/// truncation) are *skipped with a log line* instead of erroring: gc is
/// exactly the tool an operator reaches for when a store is damaged, so it
/// must not be wedged by the damage itself. A skipped card can only
/// under-pin, and gc still keeps last-k + best regardless.
pub fn frontier_pins(registry: &Registry) -> Result<Vec<(ArtifactKey, u64)>> {
    let records = registry.eval_records();
    let mut models: Vec<String> = records.iter().map(|r| r.model.clone()).collect();
    models.sort();
    models.dedup();
    let mut pins: Vec<(ArtifactKey, u64)> = Vec::new();
    for model in models {
        let mut cards = Vec::new();
        for rec in records.iter().filter(|r| r.model == model) {
            let loaded = registry
                .load_eval_bytes(rec)
                .and_then(|b| Scorecard::from_json(&Value::parse(&b)?));
            match loaded {
                Ok(c) => cards.push(c),
                Err(e) => {
                    crate::log_info!(
                        "frontier_pins: skipping unreadable scorecard {}: {e:#}",
                        rec.file
                    );
                }
            }
        }
        let refs: Vec<&Scorecard> = cards.iter().collect();
        for p in Frontier::build(&model, &refs).points {
            if let Some(binding) = p.artifact {
                if !pins.contains(&binding) {
                    pins.push(binding);
                }
            }
        }
    }
    Ok(pins)
}

/// Cached per-model frontiers, invalidated by the registry's manifest
/// stamp — the same (mtime, length) refresh discipline the store itself
/// uses, so any registration (theta or scorecard, this process or another)
/// rebuilds on the next lookup.
pub struct FrontierCache {
    registry: Arc<Registry>,
    cache: Mutex<BTreeMap<String, (ManifestStamp, Arc<Frontier>)>>,
}

impl FrontierCache {
    pub fn new(registry: Arc<Registry>) -> FrontierCache {
        FrontierCache { registry, cache: Mutex::new(BTreeMap::new()) }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The model's current frontier (rebuilt iff the manifest changed since
    /// the cached build).
    pub fn frontier(&self, model: &str) -> Result<Arc<Frontier>> {
        let stamp = self.registry.current_stamp();
        if let Some((cached_stamp, f)) = self.cache.lock().unwrap().get(model) {
            if *cached_stamp == stamp {
                return Ok(f.clone());
            }
        }
        // Build outside the cache lock (scorecard loads touch disk).
        let f = Arc::new(build_frontier(&self.registry, model)?);
        self.cache
            .lock()
            .unwrap()
            .insert(model.to_string(), (stamp, f.clone()));
        Ok(f)
    }

    /// Resolve a budget for a model against its current frontier.
    pub fn resolve(&self, model: &str, budget: &Budget) -> Result<FrontierPoint> {
        let f = self.frontier(model)?;
        Ok(f.resolve(budget)?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(solver: &str, nfe: u64, rmse: f32) -> FrontierPoint {
        FrontierPoint {
            solver: solver.into(),
            source: "rk2:n=4".into(),
            artifact: None,
            nfe,
            rmse,
            psnr: 10.0,
            fd: 0.1,
            swd: 0.1,
            wall_ms: nfe as f64 * 0.5,
        }
    }

    fn frontier(points: Vec<FrontierPoint>) -> Frontier {
        Frontier { model: "m".into(), candidates: points.len(), points }
    }

    #[test]
    fn budget_grammar() {
        assert_eq!(Budget::parse("nfe_max=8").unwrap(), Budget::NfeMax(8));
        assert_eq!(Budget::parse("latency_ms=2.5").unwrap(), Budget::LatencyMs(2.5));
        assert_eq!(Budget::parse("rmse<=0.05").unwrap(), Budget::RmseMax(0.05));
        for bad in ["nfe_max=0", "latency_ms=-1", "rmse<=0", "steps=4", "rmse<0.1", ""] {
            assert!(Budget::parse(bad).is_err(), "should reject {bad:?}");
        }
        // JSON round-trip through the wire form
        for b in [Budget::NfeMax(8), Budget::LatencyMs(2.5), Budget::RmseMax(0.05)] {
            let back = Budget::from_json(&b.to_json()).unwrap();
            assert_eq!(back, b);
        }
        for bad in [r#"{}"#, r#"{"nfe_max":1,"latency_ms":2}"#, r#"{"steps":4}"#] {
            let v = Value::parse(bad).unwrap();
            assert!(Budget::from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn resolve_picks_within_budget() {
        let f = frontier(vec![
            point("rk2:n=1", 2, 0.5),
            point("rk2:n=4", 8, 0.1),
            point("rk2:n=16", 32, 0.01),
        ]);
        // nfe budget: best quality among affordable points
        assert_eq!(f.resolve(&Budget::NfeMax(8)).unwrap().solver, "rk2:n=4");
        assert_eq!(f.resolve(&Budget::NfeMax(100)).unwrap().solver, "rk2:n=16");
        assert!(f.resolve(&Budget::NfeMax(1)).is_err());
        // quality budget: fewest NFE meeting the bound
        assert_eq!(f.resolve(&Budget::RmseMax(0.2)).unwrap().solver, "rk2:n=4");
        assert!(f.resolve(&Budget::RmseMax(0.001)).is_err());
        // latency budget: wall_ms = nfe * 0.5 here
        assert_eq!(f.resolve(&Budget::LatencyMs(4.0)).unwrap().solver, "rk2:n=4");
    }
}
