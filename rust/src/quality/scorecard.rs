//! Scorecards: the persisted output of one eval sweep — quality-vs-NFE
//! metric rows for a (model, solver template) cell, measured by
//! `eval::evaluate_sampler` against cached GT batches.
//!
//! A scorecard file (`v<k>.eval.json`) lives in the registry store beside
//! the thetas (artifact-bound cards) or under `evals/` (baseline sweeps)
//! and is hash-checked through `registry::Registry::load_eval_bytes`; this
//! module owns only the content codec. All metric numbers are NaN-safe:
//! non-finite values serialize as explicit JSON `null` and decode back to
//! NaN, like every other registry record.

use anyhow::{bail, Result};

use crate::eval::SamplerReport;
use crate::json::Value;
use crate::registry::{ArtifactKey, META_SCHEMA_VERSION};
use crate::solvers::theta::Base;

/// One measured (concrete spec, NFE) point of a sweep.
#[derive(Clone, Debug)]
pub struct ScoreRow {
    /// The concrete, buildable spec this row measured (`rk2:n=4`,
    /// `bespoke:path=...`, ...).
    pub solver: String,
    /// Measured model evaluations per batch.
    pub nfe: u64,
    /// Model evaluations actually performed per batch, including rejected
    /// adaptive attempts (equals `nfe` for fixed-grid solvers; cards
    /// written before the field existed decode it as `nfe`).
    pub nfe_actual: u64,
    pub rmse: f32,
    pub psnr: f32,
    pub fd: f64,
    pub swd: f32,
    /// Fréchet distance vs the target dataset; NaN when no reference.
    pub fd_data: f64,
    pub wall_ms: f64,
    /// The compute backend the row was measured on (`"hlo"`/`"analytic"`,
    /// DESIGN.md §15). Cards written before the field existed decode it as
    /// `""` (unrecorded).
    pub backend: String,
}

impl ScoreRow {
    pub fn from_report(solver: &str, backend: &str, rep: &SamplerReport) -> ScoreRow {
        ScoreRow {
            solver: solver.to_string(),
            nfe: rep.nfe,
            nfe_actual: rep.nfe_actual,
            rmse: rep.rmse,
            psnr: rep.psnr,
            fd: rep.fd,
            swd: rep.swd,
            fd_data: rep.fd_data,
            wall_ms: rep.wall_ms_per_batch,
            backend: backend.to_string(),
        }
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("solver", Value::Str(self.solver.clone())),
            ("nfe", Value::Num(self.nfe as f64)),
            ("nfe_actual", Value::Num(self.nfe_actual as f64)),
            ("rmse", Value::num_or_null(self.rmse as f64)),
            ("psnr", Value::num_or_null(self.psnr as f64)),
            ("fd", Value::num_or_null(self.fd)),
            ("swd", Value::num_or_null(self.swd as f64)),
            ("fd_data", Value::num_or_null(self.fd_data)),
            ("wall_ms", Value::num_or_null(self.wall_ms)),
            ("backend", Value::Str(self.backend.clone())),
        ])
    }

    fn from_json(v: &Value) -> Result<ScoreRow> {
        let num = |key: &str| -> Result<f64> {
            match v.get(key)? {
                Value::Null => Ok(f64::NAN),
                x => x.as_f64(),
            }
        };
        let nfe = v.get("nfe")?.as_usize()? as u64;
        Ok(ScoreRow {
            solver: v.get("solver")?.as_str()?.to_string(),
            nfe,
            nfe_actual: match v.get_opt("nfe_actual") {
                Some(x) => x.as_usize()? as u64,
                None => nfe,
            },
            rmse: num("rmse")? as f32,
            psnr: num("psnr")? as f32,
            fd: num("fd")?,
            swd: num("swd")? as f32,
            fd_data: num("fd_data")?,
            wall_ms: num("wall_ms")?,
            backend: match v.get_opt("backend") {
                Some(x) => x.as_str()?.to_string(),
                None => String::new(),
            },
        })
    }
}

/// A full scorecard: the sweep's identity (model, solver template, optional
/// artifact binding, eval settings) plus one [`ScoreRow`] per measured cell.
#[derive(Clone, Debug)]
pub struct Scorecard {
    pub schema_version: u64,
    pub model: String,
    /// The solver template that was swept (canonical spec string; for
    /// registry-form bespoke templates this keeps the `bespoke:model=...`
    /// spelling — the rows carry the resolved concrete specs).
    pub solver: String,
    /// The bespoke artifact this card measured, when the template resolved
    /// through the registry.
    pub artifact: Option<(ArtifactKey, u64)>,
    /// DOPRI5 tolerance of the GT batches the metrics compare against.
    pub gt_tol: f64,
    pub seed: u64,
    /// Number of eval batches behind each row.
    pub batches: usize,
    pub created_at: u64,
    pub rows: Vec<ScoreRow>,
}

impl Scorecard {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("schema_version", Value::Num(self.schema_version as f64)),
            ("model", Value::Str(self.model.clone())),
            ("solver", Value::Str(self.solver.clone())),
        ];
        if let Some((key, ver)) = &self.artifact {
            fields.push((
                "artifact",
                Value::obj(vec![
                    ("model", Value::Str(key.model.clone())),
                    ("base", Value::Str(key.base.name().into())),
                    ("n", Value::Num(key.n as f64)),
                    ("ablation", Value::Str(key.ablation.clone())),
                    ("version", Value::Num(*ver as f64)),
                ]),
            ));
        }
        fields.extend([
            ("gt_tol", Value::Num(self.gt_tol)),
            ("seed", Value::Num(self.seed as f64)),
            ("batches", Value::Num(self.batches as f64)),
            ("created_at", Value::Num(self.created_at as f64)),
            (
                "rows",
                Value::Arr(self.rows.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Scorecard> {
        let schema_version = v.get("schema_version")?.as_usize()? as u64;
        if schema_version > META_SCHEMA_VERSION {
            bail!(
                "scorecard schema_version {schema_version} is newer than \
                 this binary understands ({META_SCHEMA_VERSION})"
            );
        }
        let artifact = match v.get_opt("artifact") {
            None => None,
            Some(av) => Some((
                ArtifactKey {
                    model: av.get("model")?.as_str()?.to_string(),
                    base: Base::parse(av.get("base")?.as_str()?)?,
                    n: av.get("n")?.as_usize()?,
                    ablation: av.get("ablation")?.as_str()?.to_string(),
                },
                av.get("version")?.as_usize()? as u64,
            )),
        };
        let mut rows = Vec::new();
        for rv in v.get("rows")?.as_arr()? {
            rows.push(ScoreRow::from_json(rv)?);
        }
        Ok(Scorecard {
            schema_version,
            model: v.get("model")?.as_str()?.to_string(),
            solver: v.get("solver")?.as_str()?.to_string(),
            artifact,
            gt_tol: v.get("gt_tol")?.as_f64()?,
            seed: v.get("seed")?.as_usize()? as u64,
            batches: v.get("batches")?.as_usize()?,
            created_at: v.get("created_at")?.as_usize()? as u64,
            rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_card() -> Scorecard {
        Scorecard {
            schema_version: META_SCHEMA_VERSION,
            model: "checker2-ot".into(),
            solver: "rk2:n=4".into(),
            artifact: None,
            gt_tol: 1e-5,
            seed: 1234,
            batches: 4,
            created_at: 1_753_000_000,
            rows: vec![
                ScoreRow {
                    solver: "rk2:n=2".into(),
                    nfe: 4,
                    nfe_actual: 4,
                    rmse: 0.5,
                    psnr: 12.0,
                    fd: 0.4,
                    swd: 0.3,
                    fd_data: f64::NAN,
                    wall_ms: 1.0,
                    backend: "analytic".into(),
                },
                ScoreRow {
                    solver: "rk2:n=4".into(),
                    nfe: 8,
                    nfe_actual: 11,
                    rmse: 0.1,
                    psnr: 20.0,
                    fd: 0.1,
                    swd: 0.05,
                    fd_data: 0.2,
                    wall_ms: 2.0,
                    backend: "hlo".into(),
                },
            ],
        }
    }

    #[test]
    fn round_trips_with_nan_metrics() {
        let card = sample_card();
        let text = card.to_json().to_string_pretty();
        assert!(text.contains("null"), "NaN fd_data must serialize as null");
        let back = Scorecard::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.model, card.model);
        assert_eq!(back.rows.len(), 2);
        assert!(back.rows[0].fd_data.is_nan());
        assert_eq!(back.rows[1].fd_data, 0.2);
        assert_eq!(back.rows[1].nfe, 8);
        assert_eq!(back.rows[1].nfe_actual, 11);
        assert_eq!(back.rows[1].rmse, 0.1);
        assert_eq!(back.rows[0].backend, "analytic");
        assert_eq!(back.rows[1].backend, "hlo");
        assert!(back.artifact.is_none());
        // Cards written before nfe_actual / backend decode them as nfe /
        // "" (unrecorded) respectively.
        let mut v = card.to_json();
        if let Value::Obj(m) = &mut v {
            if let Some(Value::Arr(rows)) = m.get_mut("rows") {
                for r in rows {
                    if let Value::Obj(rm) = r {
                        rm.remove("nfe_actual");
                        rm.remove("backend");
                    }
                }
            }
        }
        let legacy = Scorecard::from_json(&v).unwrap();
        assert_eq!(legacy.rows[1].nfe_actual, 8);
        assert_eq!(legacy.rows[1].backend, "");
    }

    #[test]
    fn round_trips_artifact_binding() {
        let mut card = sample_card();
        card.artifact = Some((ArtifactKey::new("checker2-ot", Base::Rk2, 4, "full"), 3));
        card.solver = "bespoke:model=checker2-ot:n=4".into();
        let text = card.to_json().to_string_compact();
        let back = Scorecard::from_json(&Value::parse(&text).unwrap()).unwrap();
        let (key, ver) = back.artifact.unwrap();
        assert_eq!(ver, 3);
        assert_eq!(key.n, 4);
        assert_eq!(key.base, Base::Rk2);
    }

    #[test]
    fn rejects_future_schema() {
        let mut v = sample_card().to_json();
        if let Value::Obj(m) = &mut v {
            m.insert("schema_version".into(), Value::Num(999.0));
        }
        assert!(Scorecard::from_json(&v).is_err());
    }
}
