//! Quality scorecards, Pareto frontiers, and budget-aware routing
//! (DESIGN.md §9) — the subsystem that turns the paper's headline
//! quality-vs-NFE tradeoff into a serving primitive.
//!
//! Three layers:
//!
//! * [`scorecard`] — the measured data: background eval jobs sweep a
//!   (solver template × n-grid) matrix per model through
//!   `eval::evaluate_sampler` (RMSE/PSNR/FD/SWD/wall-ms vs cached GT
//!   batches) and persist versioned `v<k>.eval.json` scorecards into the
//!   registry store beside the thetas, hash-checked and manifest-tracked
//!   like them.
//! * [`frontier`] — the efficient set: a deterministic per-model Pareto
//!   frontier over all scorecard rows (base RK grids, dopri5, every
//!   bespoke artifact version), cached and invalidated by the registry
//!   manifest stamp.
//! * [`eval_jobs`] + budget routing — `{"cmd":"evaluate"}` runs sweeps on
//!   the generic `registry::JobManager` machinery, and a `SampleRequest`
//!   `budget` (`nfe_max` | `latency_ms` | `quality: rmse<=X`) resolves
//!   against the frontier to a concrete `SolverSpec` before routing
//!   (`budget_routed` / `budget_unsatisfiable` metrics events).
//!
//! The registry stores scorecard *bytes* (integrity, versioning, GC — with
//! frontier-referenced artifact versions pinned); this module owns their
//! semantics.

pub mod eval_jobs;
pub mod frontier;
pub mod scorecard;

pub use eval_jobs::{
    load_scorecard, register_scorecard, EvalJobManager, EvalJobSnapshot, EvalJobSpec, EvalRunner,
    EvalRunnerDyn,
};
pub use frontier::{build_frontier, frontier_pins, Budget, Frontier, FrontierCache, FrontierPoint};
pub use scorecard::{ScoreRow, Scorecard};
