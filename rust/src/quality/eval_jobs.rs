//! Background eval jobs: sweep a (solver template × n-grid) matrix for a
//! model through `eval::evaluate_sampler` and publish the resulting
//! [`Scorecard`] into the registry — the data the Pareto frontier and
//! budget routing are built from.
//!
//! Eval jobs ride the same generic [`JobManager`] machinery as training
//! jobs (queue, coalescing, progress, panic containment); only the runner
//! differs. Progress is reported per scorecard cell (`iter` = cells done,
//! `val_rmse` = the last cell's RMSE, `loss` = NaN).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::scorecard::{Scorecard, ScoreRow};
use crate::config::{EvalConfig, QualityConfig};
use crate::eval::evaluate_sampler;
use crate::json::Value;
use crate::models::{Backend, ResolvedModel, VelocityModel, Zoo};
use crate::registry::meta::unix_now;
use crate::registry::{
    ArtifactKey, EvalRecord, JobCtx, JobManager, JobProgress, JobRunner, JobSnapshot, Registry,
    META_SCHEMA_VERSION,
};
use crate::solvers::{Dopri5, Family, Sampler, SolverSpec};
use crate::tensor::Tensor;
use crate::util::Rng;

/// What to evaluate. `grid` is a list of step counts `n` to sweep the
/// template over (fixed-grid RK and transfer templates only); empty means
/// "the template's own configuration". `seed` overrides the server's eval
/// seed. Like training jobs, overrides do not participate in coalescing.
#[derive(Clone, Debug)]
pub struct EvalJobSpec {
    pub model: String,
    /// Canonical solver template spec string.
    pub solver: String,
    pub grid: Vec<usize>,
    pub seed: Option<u64>,
}

/// The eval-job runner trait object: what [`EvalJobManager`] drives.
pub type EvalRunnerDyn =
    dyn JobRunner<Spec = EvalJobSpec, Output = Scorecard, Artifact = EvalRecord>;

/// Background eval-job manager (the `{"cmd":"evaluate"}` plane).
pub type EvalJobManager = JobManager<EvalRunnerDyn>;

/// Snapshot of one eval job.
pub type EvalJobSnapshot = JobSnapshot<EvalJobSpec, EvalRecord>;

/// Cached per-model eval inputs: pre-drawn noise batches, their GT-solver
/// solutions, and the dataset reference (for the FID-analog `fd_data`).
struct GtBundle {
    x0: Vec<Tensor>,
    gt: Vec<Tensor>,
    data: Option<Tensor>,
}

/// The real eval runner: resolves the model (HLO executable, falling back
/// to the pure-Rust analytic oracle for `ideal` models so eval works with
/// no compiled artifacts), caches GT batches per (model, seed), and sweeps
/// the template through [`evaluate_sampler`].
pub struct EvalRunner {
    zoo: Arc<Zoo>,
    registry: Arc<Registry>,
    eval_cfg: EvalConfig,
    /// Behind a mutex so `{"cmd":"reload"}` can swap `[quality]` knobs on a
    /// live server; jobs read it once per use, so a reload mid-sweep
    /// affects the next cell expansion, never a half-built one.
    quality_cfg: Mutex<QualityConfig>,
    gt_cache: Mutex<BTreeMap<(String, u64), Arc<GtBundle>>>,
}

impl EvalRunner {
    pub fn new(
        zoo: Arc<Zoo>,
        registry: Arc<Registry>,
        eval_cfg: EvalConfig,
        quality_cfg: QualityConfig,
    ) -> EvalRunner {
        EvalRunner {
            zoo,
            registry,
            eval_cfg,
            quality_cfg: Mutex::new(quality_cfg),
            gt_cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Hot-reload the `[quality]` knobs (grid default, eval batch count).
    pub fn set_quality(&self, cfg: QualityConfig) {
        *self.quality_cfg.lock().unwrap() = cfg;
    }

    /// The model to evaluate: the compiled HLO executable when present,
    /// else the analytic oracle (`ideal` models only) — the same `auto`
    /// resolution the serving plane uses (DESIGN.md §15). The resolved
    /// backend name is stamped into every [`ScoreRow`] the job produces,
    /// so cards measured on the oracle are distinguishable from cards
    /// measured on the compiled artifact.
    fn model(&self, name: &str) -> Result<ResolvedModel> {
        self.zoo.serving_model_for(name, Backend::Auto)
    }

    /// Noise + GT batches for a model at a seed (cached; GT solves are the
    /// expensive part of an eval job). The cache is bounded: seeds are
    /// client-supplied, so an unbounded (model, seed) map would let a seed
    /// sweep grow server memory without limit.
    fn gt(&self, model_name: &str, model: &dyn VelocityModel, seed: u64) -> Result<Arc<GtBundle>> {
        const MAX_GT_BUNDLES: usize = 8;
        let key = (model_name.to_string(), seed);
        if let Some(b) = self.gt_cache.lock().unwrap().get(&key) {
            return Ok(b.clone());
        }
        let (b, d) = (model.batch(), model.dim());
        let nb = self.quality_cfg.lock().unwrap().eval_batches.max(1);
        let gt_solver = Dopri5 {
            rtol: self.eval_cfg.gt_tol,
            atol: self.eval_cfg.gt_tol,
            max_steps: 100_000,
        };
        let mut rng = Rng::new(seed);
        let mut x0 = Vec::with_capacity(nb);
        let mut gt = Vec::with_capacity(nb);
        for _ in 0..nb {
            let noise = Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
            gt.push(gt_solver.sample(model, &noise)?);
            x0.push(noise);
        }
        let data = self
            .zoo
            .manifest()
            .model(model_name)
            .ok()
            .and_then(|m| self.zoo.manifest().load_dataset(&m.dataset).ok());
        let bundle = Arc::new(GtBundle { x0, gt, data });
        let mut cache = self.gt_cache.lock().unwrap();
        while cache.len() >= MAX_GT_BUNDLES {
            let evict = cache.keys().next().cloned().expect("non-empty cache has a first key");
            cache.remove(&evict);
        }
        cache.insert(key, bundle.clone());
        Ok(bundle)
    }

    /// Expand the template into the concrete specs to measure, plus the
    /// artifact binding for registry-resolved bespoke templates.
    fn cells(
        &self,
        spec: &EvalJobSpec,
    ) -> Result<(Vec<SolverSpec>, Option<(ArtifactKey, u64)>)> {
        let template = SolverSpec::parse(&spec.solver)?;
        for &n in &spec.grid {
            if n == 0 {
                bail!("grid entries must be >= 1");
            }
        }
        // Sweep grid precedence: request's explicit grid > the configured
        // `[quality] grid` default > the template's own n.
        let default_grid = self.quality_cfg.lock().unwrap().grid.clone();
        let sweep = |n: usize| -> Vec<usize> {
            if !spec.grid.is_empty() {
                spec.grid.clone()
            } else if !default_grid.is_empty() {
                default_grid.clone()
            } else {
                vec![n]
            }
        };
        match &template {
            SolverSpec::Rk { base, n, grid } => Ok((
                sweep(*n)
                    .into_iter()
                    .map(|k| SolverSpec::Rk { base: *base, n: k, grid: *grid })
                    .collect(),
                None,
            )),
            SolverSpec::Transfer { base, n, sched } => Ok((
                sweep(*n)
                    .into_iter()
                    .map(|k| SolverSpec::Transfer { base: *base, n: k, sched: *sched })
                    .collect(),
                None,
            )),
            SolverSpec::Ab { base, n, order } => Ok((
                sweep(*n)
                    .into_iter()
                    .map(|k| SolverSpec::Ab { base: *base, n: k, order: *order })
                    .collect(),
                None,
            )),
            SolverSpec::Dopri5 { .. }
            | SolverSpec::Bespoke { .. }
            | SolverSpec::Bns { .. }
            | SolverSpec::Multistep { .. } => {
                if !spec.grid.is_empty() {
                    bail!(
                        "solver {} has a fixed configuration; grid sweeps \
                         apply to rk/transfer/ab templates only",
                        spec.solver
                    );
                }
                Ok((vec![template.clone()], None))
            }
            SolverSpec::BespokeRegistry { .. }
            | SolverSpec::BnsRegistry { .. }
            | SolverSpec::MultistepRegistry { .. } => {
                if !spec.grid.is_empty() {
                    bail!(
                        "learned artifacts are trained for a fixed n; grid \
                         sweeps apply to rk/transfer/ab templates only"
                    );
                }
                // Family-filtered best(): bespoke accepts any family,
                // bns/multistep pin theirs — mirroring `resolve_spec`.
                let (model, n, base, ablation, family) = match &template {
                    SolverSpec::BespokeRegistry { model, n, base, ablation } => {
                        (model, *n, *base, ablation.as_deref(), None)
                    }
                    SolverSpec::BnsRegistry { model, n, base, ablation } => {
                        (model, *n, *base, ablation.as_deref(), Some(Family::Bns))
                    }
                    SolverSpec::MultistepRegistry { model, n, ablation } => {
                        (model, *n, None, ablation.as_deref(), Some(Family::Multistep))
                    }
                    _ => unreachable!("outer match arm guarantees a registry form"),
                };
                let rec =
                    self.registry.best(model, n, base, ablation, family).with_context(|| {
                        format!("no registered artifact to evaluate for {}", spec.solver)
                    })?;
                // Derive the concrete spec from this exact record (not a
                // second `resolve_spec` lookup): a training job registering
                // a better version between two lookups must not make the
                // card's artifact binding disagree with the theta it
                // actually measured.
                let path = self.registry.theta_path(&rec).to_string_lossy().into_owned();
                let concrete = match family {
                    None => SolverSpec::Bespoke { path },
                    Some(Family::Bns) => SolverSpec::Bns { path },
                    Some(Family::Multistep) => SolverSpec::Multistep { path },
                    Some(Family::Stationary) => {
                        unreachable!("registry forms never pin family=stationary")
                    }
                };
                Ok((vec![concrete], Some((rec.key, rec.version))))
            }
        }
    }
}

impl JobRunner for EvalRunner {
    type Spec = EvalJobSpec;
    type Output = Scorecard;
    type Artifact = EvalRecord;

    fn kind(&self) -> &'static str {
        "eval"
    }

    fn coalesce_key(&self, spec: &EvalJobSpec) -> String {
        format!("{}|{}|{:?}", spec.model, spec.solver, spec.grid)
    }

    fn label(&self, spec: &EvalJobSpec) -> String {
        if spec.grid.is_empty() {
            format!("eval {} {}", spec.model, spec.solver)
        } else {
            format!("eval {} {} grid={:?}", spec.model, spec.solver, spec.grid)
        }
    }

    fn validate(&self, spec: &EvalJobSpec) -> Result<()> {
        self.zoo.manifest().model(&spec.model)?;
        self.cells(spec)?;
        Ok(())
    }

    fn run(
        &self,
        spec: &EvalJobSpec,
        ctx: &JobCtx,
        progress: &mut dyn FnMut(&JobProgress),
    ) -> Result<Scorecard> {
        let resolved = self.model(&spec.model)?;
        let backend = resolved.backend.name();
        let model = resolved.model;
        let sched = self.zoo.scheduler(&spec.model)?;
        let (cells, artifact) = self.cells(spec)?;
        let seed = spec.seed.unwrap_or(self.eval_cfg.seed);
        let bundle = self.gt(&spec.model, model.as_ref(), seed)?;

        let mut rows = Vec::with_capacity(cells.len());
        for (i, cell) in cells.iter().enumerate() {
            // Cell boundaries are the cancel checkpoints: eval jobs have no
            // resumable state, so a cancelled sweep just stops here.
            ctx.cancel.bail_if_cancelled()?;
            let sampler = cell.build(sched)?;
            let rep = evaluate_sampler(
                model.as_ref(),
                sampler.as_ref(),
                &bundle.x0,
                &bundle.gt,
                bundle.data.as_ref(),
            )?;
            progress(&JobProgress {
                iter: i + 1,
                iters_total: cells.len(),
                loss: f32::NAN,
                val_rmse: rep.rmse,
            });
            rows.push(ScoreRow::from_report(&cell.to_string(), backend, &rep));
        }
        Ok(Scorecard {
            schema_version: META_SCHEMA_VERSION,
            model: spec.model.clone(),
            solver: spec.solver.clone(),
            artifact,
            gt_tol: self.eval_cfg.gt_tol,
            seed,
            batches: bundle.x0.len(),
            created_at: unix_now(),
            rows,
        })
    }

    fn spec_to_json(&self, spec: &EvalJobSpec) -> Value {
        let mut pairs = vec![
            ("model", Value::Str(spec.model.clone())),
            ("solver", Value::Str(spec.solver.clone())),
            (
                "grid",
                Value::Arr(spec.grid.iter().map(|&n| Value::Num(n as f64)).collect()),
            ),
        ];
        if let Some(seed) = spec.seed {
            pairs.push(("seed", Value::Num(seed as f64)));
        }
        Value::obj(pairs)
    }

    fn spec_from_json(&self, v: &Value) -> Result<EvalJobSpec> {
        Ok(EvalJobSpec {
            model: v.get("model")?.as_str()?.to_string(),
            solver: v.get("solver")?.as_str()?.to_string(),
            grid: v
                .get("grid")?
                .as_arr()?
                .iter()
                .map(|n| n.as_usize())
                .collect::<Result<Vec<_>>>()?,
            seed: v.get_opt("seed").map(|s| s.as_usize()).transpose()?.map(|s| s as u64),
        })
    }

    fn publish(&self, registry: &Registry, card: Scorecard) -> Result<EvalRecord> {
        register_scorecard(registry, &card)
    }
}

/// Serialize + register a scorecard into the registry (shared by the
/// background runner and the synchronous `repro eval run` CLI path).
pub fn register_scorecard(registry: &Registry, card: &Scorecard) -> Result<EvalRecord> {
    let bytes = card.to_json().to_string_pretty();
    registry.register_eval(
        &card.model,
        &card.solver,
        card.artifact.as_ref().map(|(k, v)| (k, *v)),
        &bytes,
    )
}

/// Round-trip guard used by tests: a registered scorecard must load back
/// hash-clean and decode to the same row set.
pub fn load_scorecard(registry: &Registry, rec: &EvalRecord) -> Result<Scorecard> {
    let bytes = registry.load_eval_bytes(rec)?;
    Scorecard::from_json(&Value::parse(&bytes).context("parsing scorecard")?)
}
