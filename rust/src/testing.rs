//! In-tree property-testing harness (no external proptest dependency —
//! builds are fully offline). `forall` drives a deterministic RNG through N
//! cases and reports the first failing seed so failures reproduce exactly.
//! The [`loadgen`] submodule is the deterministic multi-client load
//! harness behind `repro loadgen`, the stress tests and `BENCH_5.json`.

pub mod loadgen;

use crate::util::Rng;

/// Run `check(rng, case_index)` for `cases` seeds; panic with the failing
/// seed on first failure. `check` should panic/assert on violation.
pub fn forall(name: &str, cases: usize, check: impl Fn(&mut Rng, usize)) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector helpers for property bodies.
pub fn vec_normal(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0usize;
        // interior mutability via a cell to count calls
        let cell = std::cell::Cell::new(0usize);
        forall("counts", 10, |_rng, _i| {
            cell.set(cell.get() + 1);
        });
        count += cell.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall("fails", 5, |rng, _| {
            assert!(rng.uniform() < 0.0, "always fails");
        });
    }
}
