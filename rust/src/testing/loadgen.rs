//! Deterministic in-process load harness: seeded multi-client `sample`
//! schedules, replayable bit-for-bit in tests and from the `repro loadgen`
//! CLI subcommand (DESIGN.md §10).
//!
//! A [`LoadSpec`] expands — via one forked RNG stream per client — into a
//! fixed per-client list of [`SampleRequest`]s. The *schedule* (which
//! client sends which request with which seed) is fully determined by
//! `spec.seed`; only the thread interleaving varies between runs, and the
//! coordinator's bitwise fusion invariant makes the results independent of
//! that interleaving. Each response's sample rows are folded into an
//! fnv1a64 digest, so two runs (e.g. fused vs `fuse_max_rows = 1`) can be
//! compared byte-for-byte without retaining every sample.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, Metrics, SampleRequest};
use crate::json::Value;
use crate::registry::fnv1a64;
use crate::util::obs::{Histogram, Stage};
use crate::util::Rng;

/// What workload to generate. Every field is part of the schedule seed:
/// the same spec always expands to the same requests.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub model: String,
    /// Solver specs drawn round-robin-free: each request picks one from
    /// this list with the schedule RNG.
    pub solvers: Vec<String>,
    /// Per-request batch-size choices, picked with the schedule RNG.
    pub n_choices: Vec<usize>,
    pub clients: usize,
    pub requests_per_client: usize,
    /// Root seed: forks one stream per client, which yields each request's
    /// sample seed.
    pub seed: u64,
}

impl LoadSpec {
    pub fn new(model: &str, solver: &str) -> LoadSpec {
        LoadSpec {
            model: model.to_string(),
            solvers: vec![solver.to_string()],
            n_choices: vec![8],
            clients: 8,
            requests_per_client: 16,
            seed: 0x10ad_9e4e,
        }
    }
}

/// One planned request: `client`/`index` name its slot in the schedule,
/// stable across replays.
#[derive(Clone, Debug)]
pub struct PlannedRequest {
    pub client: usize,
    pub index: usize,
    pub req: SampleRequest,
}

/// Expand a spec into per-client request schedules. Deterministic in
/// `spec` alone.
pub fn schedule(spec: &LoadSpec) -> Vec<Vec<PlannedRequest>> {
    let mut root = Rng::new(spec.seed);
    (0..spec.clients)
        .map(|client| {
            let mut rng = root.fork(client as u64 + 1);
            (0..spec.requests_per_client)
                .map(|index| {
                    let solver = spec.solvers[rng.below(spec.solvers.len().max(1))].clone();
                    let n_samples = spec.n_choices[rng.below(spec.n_choices.len().max(1))];
                    PlannedRequest {
                        client,
                        index,
                        req: SampleRequest {
                            model: spec.model.clone(),
                            solver,
                            n_samples,
                            seed: rng.next_u64(),
                            return_samples: true,
                            budget: None,
                        },
                    }
                })
                .collect()
        })
        .collect()
}

/// fnv1a64 over the little-endian bytes of every sample row, row order
/// preserved — byte-identical samples <=> equal digests.
pub fn sample_digest(rows: &[Vec<f32>]) -> u64 {
    let mut bytes = Vec::with_capacity(rows.iter().map(|r| r.len() * 4).sum());
    for r in rows {
        for v in r {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    fnv1a64(&bytes)
}

/// One completed request: its schedule slot, digest and latency.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub client: usize,
    pub index: usize,
    pub rows: usize,
    pub latency_ms: f64,
    pub digest: u64,
}

/// Aggregate numbers of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub rows: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub rows_per_sec: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self, name: &str) -> Value {
        Value::obj(vec![
            ("name", Value::Str(name.to_string())),
            ("requests", Value::Num(self.requests as f64)),
            ("rows", Value::Num(self.rows as f64)),
            ("wall_secs", Value::Num(self.wall_secs)),
            ("throughput_rps", Value::Num(self.throughput_rps)),
            ("rows_per_sec", Value::Num(self.rows_per_sec)),
            ("latency_p50_ms", Value::Num(self.latency_p50_ms)),
            ("latency_p90_ms", Value::Num(self.latency_p90_ms)),
            ("latency_p99_ms", Value::Num(self.latency_p99_ms)),
        ])
    }
}

/// A finished run: the report plus per-slot outcomes (sorted by
/// (client, index)) for digest comparison against another run.
pub struct LoadRun {
    pub report: LoadReport,
    pub outcomes: Vec<RequestOutcome>,
}

impl LoadRun {
    /// True iff both runs produced byte-identical samples slot-for-slot.
    pub fn bitwise_matches(&self, other: &LoadRun) -> bool {
        self.outcomes.len() == other.outcomes.len()
            && self
                .outcomes
                .iter()
                .zip(&other.outcomes)
                .all(|(a, b)| {
                    (a.client, a.index, a.digest) == (b.client, b.index, b.digest)
                })
    }
}

fn aggregate(outcomes: Vec<RequestOutcome>, wall_secs: f64) -> LoadRun {
    let mut outcomes = outcomes;
    outcomes.sort_by_key(|o| (o.client, o.index));
    let mut lat = Histogram::new();
    let mut rows = 0usize;
    for o in &outcomes {
        lat.record_ms(o.latency_ms);
        rows += o.rows;
    }
    let wall = wall_secs.max(1e-9);
    let report = LoadReport {
        requests: outcomes.len(),
        rows,
        wall_secs,
        throughput_rps: outcomes.len() as f64 / wall,
        rows_per_sec: rows as f64 / wall,
        latency_p50_ms: lat.quantile_ms(0.5),
        latency_p90_ms: lat.quantile_ms(0.9),
        latency_p99_ms: lat.quantile_ms(0.99),
    };
    LoadRun { report, outcomes }
}

/// Fire the schedule at a coordinator: one thread per client, each issuing
/// its requests back-to-back. Any request error fails the whole run (the
/// harness drives known-good routes; an error is a bug, not load).
pub fn run(coord: &Arc<Coordinator>, spec: &LoadSpec) -> Result<LoadRun> {
    run_inner(coord, spec, false)
}

/// [`run`] but driving the same tracing work the server's dispatch does
/// per request (id assignment, accept/respond spans, traced submission).
/// With the coordinator's tracer disabled this collapses to exactly
/// [`run`]'s code path, so on-vs-off pairs measure tracing overhead.
pub fn run_traced(coord: &Arc<Coordinator>, spec: &LoadSpec) -> Result<LoadRun> {
    run_inner(coord, spec, true)
}

fn run_inner(coord: &Arc<Coordinator>, spec: &LoadSpec, traced: bool) -> Result<LoadRun> {
    let plan = schedule(spec);
    let started = Instant::now();
    let results: Vec<Result<Vec<RequestOutcome>>> = std::thread::scope(|s| {
        let handles: Vec<_> = plan
            .into_iter()
            .map(|client_plan| {
                let coord = coord.clone();
                s.spawn(move || {
                    client_plan
                        .into_iter()
                        .map(|p| if traced { run_one_traced(&coord, p) } else { run_one(&coord, p) })
                        .collect::<Result<Vec<_>>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("loadgen client panicked")),
            })
            .collect()
    });
    let wall_secs = started.elapsed().as_secs_f64();
    let mut outcomes = Vec::new();
    for r in results {
        outcomes.extend(r?);
    }
    Ok(aggregate(outcomes, wall_secs))
}

/// The golden twin of [`run`]: the same schedule issued sequentially on
/// the caller's thread, so every request solves without concurrent
/// batch-mates. Fused runs must match its digests bit-for-bit.
pub fn run_sequential(coord: &Arc<Coordinator>, spec: &LoadSpec) -> Result<LoadRun> {
    run_plan_sequential(coord, &schedule(spec))
}

/// [`run_sequential`] over an explicit plan — the golden-digest source for
/// chaos runs, which must share the caller's (possibly seed-masked) plan.
pub fn run_plan_sequential(
    coord: &Arc<Coordinator>,
    plan: &[Vec<PlannedRequest>],
) -> Result<LoadRun> {
    let started = Instant::now();
    let mut outcomes = Vec::new();
    for client_plan in plan {
        for p in client_plan {
            outcomes.push(run_one(coord, p.clone())?);
        }
    }
    Ok(aggregate(outcomes, started.elapsed().as_secs_f64()))
}

fn run_one(coord: &Arc<Coordinator>, p: PlannedRequest) -> Result<RequestOutcome> {
    let resp = coord
        .submit(&p.req)
        .with_context(|| format!("loadgen client {} request {}", p.client, p.index))?;
    let samples = resp
        .samples
        .as_ref()
        .context("loadgen requests always ask for samples")?;
    Ok(RequestOutcome {
        client: p.client,
        index: p.index,
        rows: samples.len(),
        latency_ms: resp.latency_ms,
        digest: sample_digest(samples),
    })
}

/// [`run_one`] through the traced dispatch path: same span sequence the
/// TCP server records around each `sample` command.
fn run_one_traced(coord: &Arc<Coordinator>, p: PlannedRequest) -> Result<RequestOutcome> {
    let tracer = coord.metrics.tracer();
    let tid = tracer.begin_request();
    if let Some(id) = tid {
        tracer.record(id, Stage::Accept, 0, p.req.n_samples as u64);
    }
    let started = Instant::now();
    let resp = coord
        .submit_traced(&p.req, tid)
        .with_context(|| format!("loadgen client {} request {}", p.client, p.index))?;
    if let Some(id) = tid {
        tracer.record(id, Stage::Respond, 0, started.elapsed().as_micros() as u64);
    }
    let samples = resp
        .samples
        .as_ref()
        .context("loadgen requests always ask for samples")?;
    Ok(RequestOutcome {
        client: p.client,
        index: p.index,
        rows: samples.len(),
        latency_ms: resp.latency_ms,
        digest: sample_digest(samples),
    })
}

// ---------------------------------------------------------------------------
// Chaos mode (DESIGN.md §12): the same deterministic schedules, fired over
// TCP at a live server while lifecycle events (drain, reload) land
// mid-storm. Every request must end in a byte-correct response
// (digest-checked against a golden in-process run) or a structured coded
// rejection — silent drops and garbled rows are counted so callers can
// assert they are zero.

/// Masked-seed variant of [`schedule`]: seeds are clamped to 32 bits so
/// they survive the wire protocol's JSON number (f64) round-trip
/// bit-exactly. Golden digests must come from the *same* plan
/// (via [`run_plan_sequential`]), never from the unmasked schedule.
pub fn tcp_schedule(spec: &LoadSpec) -> Vec<Vec<PlannedRequest>> {
    let mut plan = schedule(spec);
    for client_plan in &mut plan {
        for p in client_plan {
            p.req.seed &= 0xFFFF_FFFF;
        }
    }
    plan
}

/// Tally of one chaos storm. `no_response` (connection died without an
/// answer) and `digest_mismatches` (answer had wrong bytes) are the two
/// failure classes a graceful drain must keep at zero; coded rejections
/// are the *expected* back-pressure outcome.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    pub sent: usize,
    pub ok: usize,
    /// Total sample rows received across `ok` responses (for server-side
    /// reconciliation).
    pub ok_rows: usize,
    pub rejected_draining: usize,
    pub rejected_other: usize,
    pub digest_mismatches: usize,
    pub no_response: usize,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
}

impl ChaosReport {
    /// True iff every request was accounted for: a byte-correct response
    /// or a structured rejection, nothing silently dropped or corrupted.
    pub fn lossless(&self) -> bool {
        self.no_response == 0
            && self.digest_mismatches == 0
            && self.ok + self.rejected_draining + self.rejected_other == self.sent
    }

    pub fn to_json(&self, name: &str) -> Value {
        Value::obj(vec![
            ("name", Value::Str(name.to_string())),
            ("sent", Value::Num(self.sent as f64)),
            ("ok", Value::Num(self.ok as f64)),
            ("ok_rows", Value::Num(self.ok_rows as f64)),
            ("rejected_draining", Value::Num(self.rejected_draining as f64)),
            ("rejected_other", Value::Num(self.rejected_other as f64)),
            ("digest_mismatches", Value::Num(self.digest_mismatches as f64)),
            ("no_response", Value::Num(self.no_response as f64)),
            ("lossless", Value::Bool(self.lossless())),
            ("latency_p50_ms", Value::Num(self.latency_p50_ms)),
            ("latency_p90_ms", Value::Num(self.latency_p90_ms)),
            ("latency_p99_ms", Value::Num(self.latency_p99_ms)),
        ])
    }
}

fn sample_req_json(req: &SampleRequest) -> Value {
    Value::obj(vec![
        ("cmd", Value::Str("sample".into())),
        ("model", Value::Str(req.model.clone())),
        ("solver", Value::Str(req.solver.clone())),
        ("n_samples", Value::Num(req.n_samples as f64)),
        ("seed", Value::Num(req.seed as f64)),
        ("return_samples", Value::Bool(true)),
    ])
}

#[derive(Default)]
struct ClientTally {
    sent: usize,
    ok: usize,
    ok_rows: usize,
    rejected_draining: usize,
    rejected_other: usize,
    digest_mismatches: usize,
    no_response: usize,
    ok_latencies_ms: Vec<f64>,
}

/// Connect with retries — the server thread may still be binding.
fn connect_retrying(addr: &str) -> Result<std::net::TcpStream> {
    let deadline = Instant::now() + std::time::Duration::from_secs(5);
    loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                s.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
                return Ok(s);
            }
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn run_tcp_client(
    addr: &str,
    client_plan: &[PlannedRequest],
    golden: &std::collections::BTreeMap<(usize, usize), u64>,
) -> ClientTally {
    use std::io::{BufRead, BufReader, Write};
    let mut tally = ClientTally { sent: client_plan.len(), ..ClientTally::default() };
    let stream = match connect_retrying(addr) {
        Ok(s) => s,
        Err(_) => {
            tally.no_response = client_plan.len();
            return tally;
        }
    };
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => {
            tally.no_response = client_plan.len();
            return tally;
        }
    };
    let mut reader = BufReader::new(stream);
    for (done, p) in client_plan.iter().enumerate() {
        let line = sample_req_json(&p.req).to_string_compact();
        let started = Instant::now();
        let mut resp = String::new();
        let io_ok = writer
            .write_all(line.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .and_then(|_| reader.read_line(&mut resp))
            .map(|n| n > 0)
            .unwrap_or(false);
        if !io_ok {
            // Connection died mid-request: this and every remaining
            // request got no answer — the silent-drop failure class.
            tally.no_response = client_plan.len() - done;
            break;
        }
        let latency_ms = started.elapsed().as_secs_f64() * 1e3;
        let v = match Value::parse(&resp) {
            Ok(v) => v,
            Err(_) => {
                tally.no_response += 1;
                continue;
            }
        };
        let ok = v.get("ok").and_then(|b| b.as_bool()).unwrap_or(false);
        if !ok {
            let code = v
                .get_opt("code")
                .and_then(|c| c.as_str().ok())
                .unwrap_or("");
            if code == "draining" {
                tally.rejected_draining += 1;
            } else {
                tally.rejected_other += 1;
            }
            continue;
        }
        let rows = v
            .get("samples")
            .and_then(|s| s.as_arr())
            .and_then(|rows| {
                rows.iter()
                    .map(|r| r.as_f32_vec())
                    .collect::<Result<Vec<Vec<f32>>>>()
            });
        match rows {
            Ok(rows) if golden.get(&(p.client, p.index)) == Some(&sample_digest(&rows)) => {
                tally.ok += 1;
                tally.ok_rows += rows.len();
                tally.ok_latencies_ms.push(latency_ms);
            }
            _ => tally.digest_mismatches += 1,
        }
    }
    tally
}

/// Fire a plan at a live JSONL server over TCP, one connection per client,
/// verifying each successful response byte-for-byte against `golden`
/// (produced by [`run_plan_sequential`] from the same plan). Lifecycle
/// events mid-storm (drain, reload) are the caller's business — spawn a
/// trigger thread alongside this call.
pub fn run_tcp(addr: &str, plan: &[Vec<PlannedRequest>], golden: &LoadRun) -> Result<ChaosReport> {
    let expected: std::collections::BTreeMap<(usize, usize), u64> = golden
        .outcomes
        .iter()
        .map(|o| ((o.client, o.index), o.digest))
        .collect();
    let tallies: Vec<ClientTally> = std::thread::scope(|s| {
        let handles: Vec<_> = plan
            .iter()
            .map(|client_plan| {
                let expected = &expected;
                s.spawn(move || run_tcp_client(addr, client_plan, expected))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_default())
            .collect()
    });
    let mut report = ChaosReport::default();
    let mut lat = Histogram::new();
    for t in tallies {
        report.sent += t.sent;
        report.ok += t.ok;
        report.ok_rows += t.ok_rows;
        report.rejected_draining += t.rejected_draining;
        report.rejected_other += t.rejected_other;
        report.digest_mismatches += t.digest_mismatches;
        report.no_response += t.no_response;
        for l in t.ok_latencies_ms {
            lat.record_ms(l);
        }
    }
    report.latency_p50_ms = lat.quantile_ms(0.5);
    report.latency_p90_ms = lat.quantile_ms(0.9);
    report.latency_p99_ms = lat.quantile_ms(0.99);
    Ok(report)
}

/// Run the concurrent schedule while `reloads` hot config re-installs
/// fire in the background (full route retirement mid-storm). The result
/// must stay byte-identical to a quiet run — callers assert via
/// [`LoadRun::bitwise_matches`].
pub fn run_with_reloads(
    coord: &Arc<Coordinator>,
    spec: &LoadSpec,
    reloads: usize,
) -> Result<LoadRun> {
    use std::sync::atomic::{AtomicBool, Ordering};
    let stop = Arc::new(AtomicBool::new(false));
    let reloader = {
        let coord = coord.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            for _ in 0..reloads {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                coord.reload_serve(coord.serve_cfg());
                std::thread::sleep(std::time::Duration::from_millis(3));
            }
        })
    };
    let result = run(coord, spec);
    stop.store(true, Ordering::SeqCst);
    let _ = reloader.join();
    result
}

// ---------------------------------------------------------------------------
// Reconciliation (DESIGN.md §13): after a load run, the server's own
// counters must *exactly* match the client-side tally — any gap means a
// request was double-counted, silently dropped, or rows went missing in
// the fusion plane.

/// Point-in-time server-side accounting, captured before and after a run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerAccounting {
    pub requests: u64,
    pub samples: u64,
    /// Rows the fusion plane actually solved; every accepted row is solved
    /// exactly once, so the delta must equal the samples delta.
    pub rows_used: u64,
    pub rejected_draining: u64,
}

impl ServerAccounting {
    pub fn capture(metrics: &Metrics) -> ServerAccounting {
        let t = metrics.totals();
        ServerAccounting {
            requests: t.requests,
            samples: t.samples,
            rows_used: t.rows_used,
            rejected_draining: metrics.event_count("rejected_draining"),
        }
    }

    /// Delta of two captures taken around a run.
    pub fn delta(&self, before: &ServerAccounting) -> ServerAccounting {
        ServerAccounting {
            requests: self.requests - before.requests,
            samples: self.samples - before.samples,
            rows_used: self.rows_used - before.rows_used,
            rejected_draining: self.rejected_draining - before.rejected_draining,
        }
    }
}

/// Exact reconciliation of a server-side delta against client accounting.
/// `ok_requests`/`ok_rows` are the client's successful-request count and
/// summed sample rows; `rejected_draining` is how many structured draining
/// rejections the client saw (0 outside chaos runs). Returns a description
/// of the first mismatch, or `None` when the books balance.
pub fn reconcile(
    delta: &ServerAccounting,
    ok_requests: u64,
    ok_rows: u64,
    rejected_draining: u64,
) -> Option<String> {
    if delta.requests != ok_requests {
        return Some(format!(
            "server counted {} requests, clients completed {ok_requests}",
            delta.requests
        ));
    }
    if delta.samples != ok_rows {
        return Some(format!(
            "server counted {} sample rows, clients received {ok_rows}",
            delta.samples
        ));
    }
    if delta.rows_used != ok_rows {
        return Some(format!(
            "fusion plane solved {} rows, clients received {ok_rows}",
            delta.rows_used
        ));
    }
    if delta.rejected_draining != rejected_draining {
        return Some(format!(
            "server rejected {} requests while draining, clients saw {rejected_draining}",
            delta.rejected_draining
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_and_distinct() {
        let spec = LoadSpec {
            solvers: vec!["rk2:n=4".into(), "rk1:n=2".into()],
            n_choices: vec![1, 3],
            clients: 3,
            requests_per_client: 5,
            ..LoadSpec::new("m", "rk2:n=4")
        };
        let a = schedule(&spec);
        let b = schedule(&spec);
        assert_eq!(a.len(), 3);
        for (ca, cb) in a.iter().zip(&b) {
            assert_eq!(ca.len(), 5);
            for (pa, pb) in ca.iter().zip(cb) {
                assert_eq!(pa.req.seed, pb.req.seed, "replay must be identical");
                assert_eq!(pa.req.solver, pb.req.solver);
                assert_eq!(pa.req.n_samples, pb.req.n_samples);
                assert!(spec.n_choices.contains(&pa.req.n_samples));
            }
        }
        // different clients draw different seeds
        assert_ne!(a[0][0].req.seed, a[1][0].req.seed);
        // a different root seed reshuffles the schedule
        let other = schedule(&LoadSpec { seed: 99, ..spec });
        assert_ne!(a[0][0].req.seed, other[0][0].req.seed);
    }

    #[test]
    fn reconciliation_balances_and_detects_gaps() {
        let before = ServerAccounting::default();
        let after =
            ServerAccounting { requests: 4, samples: 32, rows_used: 32, rejected_draining: 1 };
        let delta = after.delta(&before);
        assert!(reconcile(&delta, 4, 32, 1).is_none());
        assert!(reconcile(&delta, 3, 32, 1).unwrap().contains("requests"));
        assert!(reconcile(&delta, 4, 31, 1).is_some());
        assert!(reconcile(&delta, 4, 32, 0).unwrap().contains("draining"));
    }

    #[test]
    fn digest_distinguishes_bytes() {
        let rows_a = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let mut rows_b = rows_a.clone();
        assert_eq!(sample_digest(&rows_a), sample_digest(&rows_b));
        rows_b[1][1] = 4.0000005;
        assert_ne!(sample_digest(&rows_a), sample_digest(&rows_b));
    }
}
