//! Small shared utilities: deterministic RNG, wall-clock timers, logging,
//! observability primitives (histograms, trace ring, event sink), the
//! numerical-plane observability block (flight recorder, NaN quarantine
//! guard, phase timers, alerts), and the daemon lifecycle primitives
//! (cancel tokens, retry backoff, signal flags).

pub mod lifecycle;
pub mod numerics;
pub mod obs;
pub mod rng;
pub mod threads;
pub mod timer;

pub use lifecycle::{CancelToken, DrainGate, RetryPolicy};
pub use numerics::{Numerics, NumericError};
pub use obs::{EventLog, Histogram, Span, Stage, Tracer, WindowCounter};
pub use rng::Rng;
pub use timer::Timer;

/// Simple leveled stderr logger. Set `BESPOKE_LOG=debug` for verbose output.
pub fn log_enabled(level: &str) -> bool {
    match std::env::var("BESPOKE_LOG").as_deref() {
        Ok("debug") => true,
        Ok("info") | Err(_) => level != "debug",
        Ok(_) => level == "error",
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled("info") {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled("debug") {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}
