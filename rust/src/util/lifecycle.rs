//! Lifecycle primitives for daemon-grade serving (DESIGN.md §12):
//! cooperative cancellation tokens, deterministic retry backoff, and the
//! process signal flags that drive graceful drain and config hot-reload.
//!
//! These are deliberately tiny and dependency-free:
//!
//! * [`CancelToken`] — a cloneable atomic flag checked at loop
//!   *checkpoints* (trainer iterations, eval grid cells). Cancellation is
//!   cooperative: the holder observes the flag at the next checkpoint and
//!   returns the [`CANCELLED`] marker error; nothing is ever killed
//!   mid-step.
//! * [`RetryPolicy`] — a *pure function* from attempt number to backoff
//!   delay (capped exponential). Keeping it side-effect-free is what makes
//!   the backoff sequence testable under a fake clock: tests call
//!   [`RetryPolicy::delay`] directly, the job queue applies the same
//!   function to real deadlines.
//! * [`signals`] — `#[cfg(unix)]` SIGTERM/SIGINT → drain flag,
//!   SIGHUP → reload flag. Handlers only flip static atomics
//!   (async-signal-safe); a watcher thread polls and acts.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Marker string carried by cancellation errors; [`is_cancelled_err`]
/// matches on it so the job layer can tell "cancelled" from "failed"
/// through an `anyhow::Error` chain.
pub const CANCELLED: &str = "cancelled";

/// A cloneable cooperative-cancellation flag. All clones share one atomic;
/// once cancelled it stays cancelled.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; observers see it at their next
    /// checkpoint.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Checkpoint helper: error out with the [`CANCELLED`] marker if
    /// cancellation was requested.
    pub fn bail_if_cancelled(&self) -> anyhow::Result<()> {
        if self.is_cancelled() {
            Err(anyhow::anyhow!(CANCELLED))
        } else {
            Ok(())
        }
    }
}

impl fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CancelToken")
            .field("cancelled", &self.is_cancelled())
            .finish()
    }
}

/// True iff `err` is (or wraps) a cooperative-cancellation bail-out.
pub fn is_cancelled_err(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.to_string() == CANCELLED)
}

/// Capped exponential backoff for re-enqueued failed jobs. The delay for
/// attempt `k` (1-based: the first *retry* is attempt 1) is
/// `min(base_ms << (k - 1), cap_ms)`; `max_attempts` bounds the total
/// number of retries per job key.
///
/// `delay` is a pure function of the policy and the attempt number — the
/// deterministic sequence the lifecycle tests pin without sleeping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries per job after the initial run (0 disables retry).
    pub max_attempts: u32,
    /// First-retry delay in milliseconds.
    pub base_ms: u64,
    /// Upper bound on any single delay in milliseconds.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    /// Retry is opt-in: the default policy performs no retries, so
    /// existing job behavior is unchanged unless configured.
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 0, base_ms: 250, cap_ms: 30_000 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based). Attempt 0 (the
    /// initial run) has no delay.
    pub fn delay(&self, attempt: u32) -> Duration {
        if attempt == 0 {
            return Duration::ZERO;
        }
        let shift = (attempt - 1).min(20);
        let ms = self.base_ms.saturating_mul(1u64 << shift).min(self.cap_ms);
        Duration::from_millis(ms)
    }

    /// True iff a job that has already consumed `attempts` retries may be
    /// re-enqueued once more.
    pub fn allows(&self, attempts: u32) -> bool {
        attempts < self.max_attempts
    }
}

/// Process-level signal flags (Unix). SIGTERM/SIGINT request a graceful
/// drain; SIGHUP requests a config reload. Handlers only set atomics —
/// the serve loop's watcher thread polls [`drain_requested`] /
/// [`take_reload_request`] and runs the actual (non-signal-safe) work.
#[cfg(unix)]
pub mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    static DRAIN: AtomicBool = AtomicBool::new(false);
    static RELOAD: AtomicBool = AtomicBool::new(false);

    const SIGHUP: i32 = 1;
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        /// libc `signal(2)`. A typed handler pointer keeps the
        /// declaration cast-free; glibc semantics are SA_RESTART, so a
        /// blocked `accept` resumes — drains must wake it explicitly
        /// (the server self-connects to its own listener).
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_drain_signal(_signum: i32) {
        DRAIN.store(true, Ordering::SeqCst);
    }

    extern "C" fn on_reload_signal(_signum: i32) {
        RELOAD.store(true, Ordering::SeqCst);
    }

    /// Install the handlers. Call once from `repro serve` before
    /// accepting connections.
    pub fn install() {
        unsafe {
            signal(SIGTERM, on_drain_signal);
            signal(SIGINT, on_drain_signal);
            signal(SIGHUP, on_reload_signal);
        }
    }

    /// True once SIGTERM or SIGINT has been received (level-triggered:
    /// drain is terminal, so this never resets).
    pub fn drain_requested() -> bool {
        DRAIN.load(Ordering::SeqCst)
    }

    /// Test/CLI hook: behave as if SIGTERM arrived.
    pub fn request_drain() {
        DRAIN.store(true, Ordering::SeqCst);
    }

    /// Consume a pending SIGHUP (edge-triggered: each reload request is
    /// handled once).
    pub fn take_reload_request() -> bool {
        RELOAD.swap(false, Ordering::SeqCst)
    }

    /// Test/CLI hook: behave as if SIGHUP arrived.
    pub fn request_reload() {
        RELOAD.store(true, Ordering::SeqCst);
    }
}

/// Non-Unix stub: no signals, flags never fire. The in-band protocol
/// cmds (`drain` / `reload`) still work everywhere.
#[cfg(not(unix))]
pub mod signals {
    pub fn install() {}
    pub fn drain_requested() -> bool {
        false
    }
    pub fn request_drain() {}
    pub fn take_reload_request() -> bool {
        false
    }
    pub fn request_reload() {}
}

/// A draining latch shared between the accept loop, connection handlers
/// and the job planes: once flipped, new work is rejected with structured
/// `draining` errors while in-flight work finishes.
#[derive(Clone, Default)]
pub struct DrainGate {
    draining: Arc<AtomicBool>,
}

impl DrainGate {
    pub fn new() -> DrainGate {
        DrainGate::default()
    }

    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_is_shared_and_sticky() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!t.is_cancelled());
        assert!(clone.bail_if_cancelled().is_ok());
        clone.cancel();
        assert!(t.is_cancelled());
        let err = t.bail_if_cancelled().unwrap_err();
        assert!(is_cancelled_err(&err));
        t.cancel(); // idempotent
        assert!(clone.is_cancelled());
    }

    #[test]
    fn cancelled_err_detection_survives_context() {
        use anyhow::Context;
        let err: anyhow::Error = anyhow::anyhow!(CANCELLED);
        let wrapped = Err::<(), _>(err).context("train job 3").unwrap_err();
        assert!(is_cancelled_err(&wrapped));
        assert!(!is_cancelled_err(&anyhow::anyhow!("solver diverged")));
    }

    #[test]
    fn retry_backoff_is_deterministic_and_capped() {
        let p = RetryPolicy { max_attempts: 5, base_ms: 100, cap_ms: 1_000 };
        let delays: Vec<u64> =
            (0..7).map(|k| p.delay(k).as_millis() as u64).collect();
        // 0 (initial run), then 100, 200, 400, 800, capped at 1000.
        assert_eq!(delays, vec![0, 100, 200, 400, 800, 1_000, 1_000]);
        assert!(p.allows(0));
        assert!(p.allows(4));
        assert!(!p.allows(5));
        // huge attempt numbers neither overflow nor exceed the cap
        assert_eq!(p.delay(64), Duration::from_millis(1_000));
        // retry off by default
        assert!(!RetryPolicy::default().allows(0));
    }

    #[test]
    fn drain_gate_latches() {
        let g = DrainGate::new();
        let peer = g.clone();
        assert!(!g.is_draining());
        peer.begin_drain();
        assert!(g.is_draining());
    }
}
