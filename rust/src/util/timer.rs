//! Wall-clock timing helpers used by the metrics layer and bench harness.

use std::time::Instant;

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Streaming percentile estimator backed by a sorted-on-demand buffer —
/// exact percentiles, suitable for the request volumes we serve.
#[derive(Default, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
}

impl Percentiles {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// q in [0, 1]; returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_on_small_sets() {
        let mut p = Percentiles::default();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            p.record(v);
        }
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(0.5), 3.0);
        assert_eq!(p.quantile(1.0), 5.0);
        assert!((p.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let p = Percentiles::default();
        assert_eq!(p.quantile(0.5), 0.0);
        assert!(p.is_empty());
    }
}
