//! Wall-clock timing helpers used by the metrics layer and bench harness.

use std::time::Instant;

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Exact-percentile estimator backed by a sorted-on-demand buffer, for
/// *bounded offline* uses (bench repeats, test fixtures). The buffer is
/// hard-capped at [`Percentiles::CAP`] samples — later records still update
/// the count/mean but are not retained, so this type can never grow without
/// bound. Serving-path metrics use [`crate::util::obs::Histogram`], which
/// is O(1) per record and fixed-size by construction.
#[derive(Default, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    count: usize,
    sum: f64,
}

impl Percentiles {
    /// Retention cap: quantiles are exact up to this many samples.
    pub const CAP: usize = 65_536;

    pub fn record(&mut self, v: f64) {
        if self.samples.len() < Self::CAP {
            self.samples.push(v);
        }
        self.count += 1;
        self.sum += v;
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// q in [0, 1]; returns 0.0 when empty. Exact over the retained
    /// (first [`Percentiles::CAP`]) samples.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_on_small_sets() {
        let mut p = Percentiles::default();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            p.record(v);
        }
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(0.5), 3.0);
        assert_eq!(p.quantile(1.0), 5.0);
        assert!((p.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let p = Percentiles::default();
        assert_eq!(p.quantile(0.5), 0.0);
        assert!(p.is_empty());
    }

    #[test]
    fn percentiles_retention_is_capped() {
        let mut p = Percentiles::default();
        for i in 0..Percentiles::CAP + 100 {
            p.record(i as f64);
        }
        // Count and mean see every record; the quantile buffer stays capped.
        assert_eq!(p.len(), Percentiles::CAP + 100);
        assert_eq!(p.quantile(1.0), (Percentiles::CAP - 1) as f64);
        let n = (Percentiles::CAP + 100) as f64;
        assert!((p.mean() - (n - 1.0) / 2.0).abs() < 1e-6);
    }
}
