//! Wall-clock timing helpers used by the metrics layer and bench harness.

use std::time::Instant;

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

