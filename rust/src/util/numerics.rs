//! Numerical-plane observability (DESIGN.md §14): the solver flight
//! recorder, the NaN/Inf quarantine guard, kernel-phase timers, and the
//! structured alert ring the quality-drift sentinel feeds.
//!
//! Everything here observes the numerics of a solve without perturbing
//! them:
//!
//! * [`Numerics`] — the shared state block (hung off the coordinator's
//!   `Metrics`): per-(route, step-index) flight-recorder [`Histogram`]s,
//!   per-(route, phase) kernel timers, the quarantine counter, and a
//!   bounded alert ring. All toggles are atomic so a config reload flips
//!   them without pausing workers.
//! * [`scan_non_finite`] — the guard's scan: a single branch-free
//!   exponent-mask pass over the state buffer (vectorization-friendly),
//!   with an exact `(row, col)` locate only on a hit. Read-only: enabled
//!   guards can never change sample bytes.
//! * [`NumericError`] — the typed error a guard trip raises, carrying
//!   (step, row, solver spec, artifact version) through the reply channel
//!   so the protocol layer can emit a coded `numeric` rejection and the
//!   coordinator can quarantine the offending registry artifact.
//!
//! The hard invariant mirrors the tracer's (DESIGN.md §13): with probe,
//! guard and phase timers off, the solve hot path is untouched (one
//! relaxed atomic load per launch); with them on, sample bytes are still
//! bitwise identical because every hook is scan/record-only.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Value;
use crate::util::obs::Histogram;

// ---------------------------------------------------------------------------
// NaN/Inf scan
// ---------------------------------------------------------------------------

/// IEEE-754 single-precision exponent mask: a value is non-finite (NaN or
/// ±Inf) iff every exponent bit is set.
const EXP_MASK: u32 = 0x7f80_0000;

/// Scan a `[rows, dim]` row-major buffer for non-finite values. Returns the
/// first offending `(row, col)` or `None` when every value is finite.
///
/// The common (healthy) case is a single pass folding a branch-free
/// predicate with `|=` — no early exit, no lane-dependent control flow, so
/// the compiler can autovectorize it. Only when the fold reports a hit does
/// a second, scalar pass locate the exact index.
pub fn scan_non_finite(data: &[f32], dim: usize) -> Option<(usize, usize)> {
    let mut acc = 0u32;
    for &v in data {
        acc |= u32::from(v.to_bits() & EXP_MASK == EXP_MASK);
    }
    if acc == 0 {
        return None;
    }
    let i = data.iter().position(|v| !v.is_finite()).unwrap_or(0);
    let d = dim.max(1);
    Some((i / d, i % d))
}

/// Root-mean-square of a slice (0.0 when empty). Used by the flight
/// recorder for state/velocity magnitude stats; never fed back into the
/// solve.
pub fn slice_rms(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let ss: f64 = xs.iter().map(|&v| v as f64 * v as f64).sum();
    (ss / xs.len() as f64).sqrt()
}

/// RMS of the elementwise difference of two equal-length slices — the
/// flight recorder's per-step velocity-magnitude proxy (state delta per
/// step), and the sentinel's drift distance.
pub fn diff_rms(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    if n == 0 {
        return 0.0;
    }
    let ss: f64 = a[..n].iter().zip(&b[..n]).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum();
    (ss / n as f64).sqrt()
}

// ---------------------------------------------------------------------------
// NumericError
// ---------------------------------------------------------------------------

/// Typed non-finite-state error raised by the quarantine guard.
///
/// Carried intact (via `anyhow` downcast) from the worker's solve loop
/// through the fused-launch reply channel to the protocol layer, which
/// renders it as a coded `numeric` rejection; the coordinator additionally
/// uses the artifact attribution to quarantine the offending registry
/// version.
#[derive(Clone, Debug)]
pub struct NumericError {
    /// 0-based solver step at whose boundary the scan tripped.
    pub step: usize,
    /// Row (within the fused launch batch) holding the first non-finite
    /// value.
    pub row: usize,
    /// Canonical solver spec string of the session that produced it.
    pub solver: String,
    /// Registry attribution `(artifact key label, version)` when the route
    /// serves a registry artifact; `None` for path/builtin specs.
    pub artifact: Option<(String, u64)>,
}

impl std::fmt::Display for NumericError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite state at step {} row {} (solver {})",
            self.step, self.row, self.solver
        )?;
        if let Some((key, ver)) = &self.artifact {
            write!(f, " [artifact {key} v{ver}]")?;
        }
        Ok(())
    }
}

impl std::error::Error for NumericError {}

// ---------------------------------------------------------------------------
// Flight recorder + phase timers + alerts
// ---------------------------------------------------------------------------

/// Per-step flight-recorder slots per route. Steps beyond the cap fold
/// into the last slot so adaptive solvers with long step counts stay
/// bounded.
pub const MAX_FLIGHT_STEPS: usize = 64;

/// Alert ring capacity: old alerts are dropped (the lifetime total keeps
/// counting) so a flapping route cannot grow memory.
pub const MAX_ALERTS: usize = 256;

/// Kernel phases timed inside the fused solve path (DESIGN.md §14 phase
/// taxonomy). `stack_rng` covers noise generation + batch stacking,
/// `model_eval` the velocity-model evaluations, `tensor_ops` the solver's
/// own tensor arithmetic (solve wall minus model eval), `scatter` the
/// per-job result copy-out.
pub const PHASES: [&str; 4] = ["stack_rng", "model_eval", "tensor_ops", "scatter"];

/// One step-index slot of the flight recorder. Magnitudes are recorded
/// through the µs-domain [`Histogram`] at 1e-3 resolution (value `v` is
/// stored as `round(v·1000)` µs), which is plenty for O(0.001..1e3)
/// state/velocity RMS and error norms.
#[derive(Default, Clone)]
struct StepStats {
    x_rms: Histogram,
    v_rms: Histogram,
    err_norm: Histogram,
    accepted: u64,
    rejected: u64,
}

/// One structured alert (sentinel drift, frontier regression, quarantine).
#[derive(Clone, Debug)]
pub struct Alert {
    pub kind: String,
    pub route: String,
    pub message: String,
    pub at: f64,
}

fn unix_now() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

fn hist_stats_json(h: &Histogram) -> Value {
    Value::obj(vec![
        ("count", Value::Num(h.count() as f64)),
        ("mean", Value::Num(h.mean_ms())),
        ("p50", Value::Num(h.quantile_ms(0.5))),
        ("p95", Value::Num(h.quantile_ms(0.95))),
        ("max", Value::Num(h.max_ms())),
    ])
}

/// The numerical-plane observability state block. One instance lives on
/// the coordinator's `Metrics` and is shared by every worker thread.
///
/// Toggle reads are relaxed atomics; recorded state sits behind coarse
/// mutexes that are only taken when the corresponding toggle is on (plus
/// one uncontended lock per exposition query).
pub struct Numerics {
    probe: AtomicBool,
    guard: AtomicBool,
    phases: AtomicBool,
    quarantines: AtomicU64,
    alerts_total: AtomicU64,
    flight: Mutex<BTreeMap<String, Vec<StepStats>>>,
    phase_hists: Mutex<BTreeMap<String, BTreeMap<&'static str, Histogram>>>,
    alerts: Mutex<std::collections::VecDeque<Alert>>,
}

impl Default for Numerics {
    fn default() -> Self {
        Numerics {
            probe: AtomicBool::new(false),
            guard: AtomicBool::new(false),
            phases: AtomicBool::new(false),
            quarantines: AtomicU64::new(0),
            alerts_total: AtomicU64::new(0),
            flight: Mutex::new(BTreeMap::new()),
            phase_hists: Mutex::new(BTreeMap::new()),
            alerts: Mutex::new(std::collections::VecDeque::new()),
        }
    }
}

impl Numerics {
    /// Reconfigure toggles in place (config reload). Like
    /// `Tracer::configure`, this resets the recorded flight/phase state so
    /// an A/B toggle starts from a clean slate; the quarantine counter and
    /// alert ring persist (they record incidents, not samples).
    pub fn configure(&self, probe: bool, guard: bool, phases: bool) {
        self.probe.store(probe, Ordering::Relaxed);
        self.guard.store(guard, Ordering::Relaxed);
        self.phases.store(phases, Ordering::Relaxed);
        self.flight.lock().unwrap().clear();
        self.phase_hists.lock().unwrap().clear();
    }

    pub fn probe_on(&self) -> bool {
        self.probe.load(Ordering::Relaxed)
    }

    pub fn guard_on(&self) -> bool {
        self.guard.load(Ordering::Relaxed)
    }

    pub fn phases_on(&self) -> bool {
        self.phases.load(Ordering::Relaxed)
    }

    /// True when any per-step hook is live — the solve loop's single
    /// relaxed-load fast-path check.
    pub fn step_hooks_on(&self) -> bool {
        self.probe_on() || self.guard_on()
    }

    /// Record one flight-recorder sample for `(route, step)`. `v_rms` is
    /// absent on the first step (no previous state); `err_norm` only for
    /// adaptive solvers. `accepted`/`rejected` are per-call deltas.
    #[allow(clippy::too_many_arguments)]
    pub fn record_step(
        &self,
        route: &str,
        step: usize,
        x_rms: f64,
        v_rms: Option<f64>,
        err_norm: Option<f64>,
        accepted: u64,
        rejected: u64,
    ) {
        let mut flight = self.flight.lock().unwrap();
        let steps = flight.entry(route.to_string()).or_default();
        let idx = step.min(MAX_FLIGHT_STEPS - 1);
        if steps.len() <= idx {
            steps.resize_with(idx + 1, StepStats::default);
        }
        let s = &mut steps[idx];
        s.x_rms.record_ms(x_rms);
        if let Some(v) = v_rms {
            s.v_rms.record_ms(v);
        }
        if let Some(e) = err_norm {
            s.err_norm.record_ms(e);
        }
        s.accepted += accepted;
        s.rejected += rejected;
    }

    /// Record one kernel-phase wall time (milliseconds) for `route`.
    pub fn record_phase(&self, route: &str, phase: &'static str, ms: f64) {
        let mut hists = self.phase_hists.lock().unwrap();
        hists.entry(route.to_string()).or_default().entry(phase).or_default().record_ms(ms);
    }

    /// Bump the quarantine counter; returns the new lifetime total.
    pub fn record_quarantine(&self) -> u64 {
        self.quarantines.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Push a structured alert into the bounded ring.
    pub fn push_alert(&self, kind: &str, route: &str, message: &str) {
        self.alerts_total.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.alerts.lock().unwrap();
        if ring.len() >= MAX_ALERTS {
            ring.pop_front();
        }
        ring.push_back(Alert {
            kind: kind.to_string(),
            route: route.to_string(),
            message: message.to_string(),
            at: unix_now(),
        });
    }

    /// Alerts currently retained in the ring.
    pub fn alerts_active(&self) -> usize {
        self.alerts.lock().unwrap().len()
    }

    /// Lifetime alert count (survives ring eviction and `clear`).
    pub fn alerts_total(&self) -> u64 {
        self.alerts_total.load(Ordering::Relaxed)
    }

    /// `{"active":…,"total":…,"alerts":[…]}`, oldest first. `clear` empties
    /// the ring after snapshotting (the lifetime total is unaffected).
    pub fn alerts_json(&self, clear: bool) -> Value {
        let mut ring = self.alerts.lock().unwrap();
        let alerts: Vec<Value> = ring
            .iter()
            .map(|a| {
                Value::obj(vec![
                    ("kind", Value::Str(a.kind.clone())),
                    ("route", Value::Str(a.route.clone())),
                    ("message", Value::Str(a.message.clone())),
                    ("at", Value::Num(a.at)),
                ])
            })
            .collect();
        let active = ring.len();
        if clear {
            ring.clear();
        }
        Value::obj(vec![
            ("active", Value::Num(active as f64)),
            ("total", Value::Num(self.alerts_total() as f64)),
            ("alerts", Value::Arr(alerts)),
        ])
    }

    /// Flight-recorder exposition: per route, an array of per-step stat
    /// rows (skipping untouched slots).
    pub fn flight_json(&self) -> Value {
        let flight = self.flight.lock().unwrap();
        let mut routes = Vec::new();
        for (route, steps) in flight.iter() {
            let rows: Vec<Value> = steps
                .iter()
                .enumerate()
                .filter(|(_, s)| s.x_rms.count() > 0 || s.accepted > 0 || s.rejected > 0)
                .map(|(i, s)| {
                    let mut pairs = vec![
                        ("step", Value::Num(i as f64)),
                        ("x_rms", hist_stats_json(&s.x_rms)),
                        ("accepted", Value::Num(s.accepted as f64)),
                        ("rejected", Value::Num(s.rejected as f64)),
                    ];
                    if s.v_rms.count() > 0 {
                        pairs.push(("v_rms", hist_stats_json(&s.v_rms)));
                    }
                    if s.err_norm.count() > 0 {
                        pairs.push(("err_norm", hist_stats_json(&s.err_norm)));
                    }
                    Value::obj(pairs)
                })
                .collect();
            routes.push((route.as_str(), Value::Arr(rows)));
        }
        Value::obj(routes)
    }

    /// Kernel-phase exposition: per route, per phase, count/mean/quantile
    /// stats plus each phase's share of the route's total timed wall.
    pub fn phases_json(&self) -> Value {
        let hists = self.phase_hists.lock().unwrap();
        let mut routes = Vec::new();
        for (route, phases) in hists.iter() {
            let total: f64 = phases.values().map(|h| h.sum_ms()).sum();
            let mut cols = Vec::new();
            for name in PHASES {
                if let Some(h) = phases.get(name) {
                    let mut stats = match hist_stats_json(h) {
                        Value::Obj(m) => m,
                        _ => unreachable!(),
                    };
                    stats.insert("sum_ms".into(), Value::Num(h.sum_ms()));
                    let share = if total > 0.0 { h.sum_ms() / total } else { 0.0 };
                    stats.insert("share".into(), Value::Num(share));
                    cols.push((name, Value::Obj(stats)));
                }
            }
            routes.push((route.as_str(), Value::obj(cols)));
        }
        Value::obj(routes)
    }

    /// Current toggle state, for the `profile` response and `metrics`
    /// snapshot.
    pub fn flags_json(&self) -> Value {
        Value::obj(vec![
            ("probe", Value::Bool(self.probe_on())),
            ("guard", Value::Bool(self.guard_on())),
            ("phases", Value::Bool(self.phases_on())),
        ])
    }

    /// Clone of every per-(route, phase) histogram, for the Prometheus
    /// exposition (which lives in the metrics layer).
    pub fn phase_hist_snapshot(&self) -> Vec<(String, &'static str, Histogram)> {
        let hists = self.phase_hists.lock().unwrap();
        let mut out = Vec::new();
        for (route, phases) in hists.iter() {
            for name in PHASES {
                if let Some(h) = phases.get(name) {
                    out.push((route.clone(), name, h.clone()));
                }
            }
        }
        out
    }

    /// Per-route rejected-step totals (summed over step slots), for the
    /// Prometheus exposition.
    pub fn rejected_by_route(&self) -> Vec<(String, u64)> {
        let flight = self.flight.lock().unwrap();
        flight
            .iter()
            .map(|(route, steps)| (route.clone(), steps.iter().map(|s| s.rejected).sum()))
            .filter(|(_, n)| *n > 0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_finds_first_non_finite() {
        let mut data = vec![0.5f32; 12]; // 3 rows x 4 cols
        assert_eq!(scan_non_finite(&data, 4), None);
        data[9] = f32::NAN;
        assert_eq!(scan_non_finite(&data, 4), Some((2, 1)));
        data[9] = f32::INFINITY;
        assert_eq!(scan_non_finite(&data, 4), Some((2, 1)));
        data[2] = f32::NEG_INFINITY;
        assert_eq!(scan_non_finite(&data, 4), Some((0, 2)));
        // Extreme-but-finite values do not trip the guard.
        let ok = vec![f32::MAX, f32::MIN_POSITIVE, -0.0, 1e-38];
        assert_eq!(scan_non_finite(&ok, 2), None);
    }

    #[test]
    fn rms_helpers() {
        assert_eq!(slice_rms(&[]), 0.0);
        assert!((slice_rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
        assert!((diff_rms(&[1.0, 2.0], &[1.0, 4.0]) - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn numeric_error_display_carries_attribution() {
        let e = NumericError {
            step: 3,
            row: 7,
            solver: "bespoke:path=x".into(),
            artifact: Some(("m/rk2/n4/full".into(), 2)),
        };
        let s = e.to_string();
        assert!(s.contains("step 3") && s.contains("row 7"), "{s}");
        assert!(s.contains("m/rk2/n4/full") && s.contains("v2"), "{s}");
    }

    #[test]
    fn flight_recorder_bounds_and_exposition() {
        let n = Numerics::default();
        n.configure(true, false, false);
        n.record_step("m/rk2:n=4", 0, 1.0, None, None, 1, 0);
        n.record_step("m/rk2:n=4", 1, 1.5, Some(0.5), Some(0.8), 1, 2);
        // Step indices beyond the cap fold into the last slot.
        n.record_step("m/rk2:n=4", MAX_FLIGHT_STEPS + 100, 2.0, None, None, 1, 0);
        let v = n.flight_json();
        let rows = v.get("m/rk2:n=4").unwrap();
        match rows {
            Value::Arr(rows) => {
                assert_eq!(rows.len(), 3);
                let last = rows.last().unwrap();
                assert_eq!(last.get("step").unwrap().as_usize().unwrap(), MAX_FLIGHT_STEPS - 1);
            }
            _ => panic!("expected array"),
        }
        assert_eq!(n.rejected_by_route(), vec![("m/rk2:n=4".to_string(), 2)]);
        // Reconfigure resets recorded state.
        n.configure(true, true, true);
        assert_eq!(n.rejected_by_route(), vec![]);
    }

    #[test]
    fn phase_share_sums_to_one() {
        let n = Numerics::default();
        n.record_phase("r", "model_eval", 3.0);
        n.record_phase("r", "tensor_ops", 1.0);
        let v = n.phases_json();
        let r = v.get("r").unwrap();
        let me = r.get("model_eval").unwrap().get("share").unwrap().as_f64().unwrap();
        let to = r.get("tensor_ops").unwrap().get("share").unwrap().as_f64().unwrap();
        assert!((me + to - 1.0).abs() < 1e-9, "{me} + {to}");
        assert!(me > to);
        assert_eq!(n.phase_hist_snapshot().len(), 2);
    }

    #[test]
    fn alert_ring_is_bounded_and_clearable() {
        let n = Numerics::default();
        for i in 0..MAX_ALERTS + 10 {
            n.push_alert("digest_drift", "r", &format!("drift {i}"));
        }
        assert_eq!(n.alerts_active(), MAX_ALERTS);
        assert_eq!(n.alerts_total(), (MAX_ALERTS + 10) as u64);
        let v = n.alerts_json(true);
        assert_eq!(v.get("active").unwrap().as_usize().unwrap(), MAX_ALERTS);
        assert_eq!(n.alerts_active(), 0);
        assert_eq!(n.alerts_total(), (MAX_ALERTS + 10) as u64);
    }
}
