//! Deterministic xoshiro256++ RNG with Box–Muller normal sampling.
//!
//! The crate's dependency footprint is intentionally minimal (no `rand`);
//! this generator is used for noise priors, workload generation, and the
//! in-tree property-testing harness. Seeding goes through splitmix64 so any
//! u64 seed produces a well-mixed state.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-request / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> exactly representable f32 in [0, 1).
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with cached spare).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= 1e-12 {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Vec of n standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs = r.normal_vec(n);
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
