//! Observability primitives (DESIGN.md §13): bounded log-bucketed latency
//! histograms, windowed rate counters, the request-trace span ring, and the
//! JSONL lifecycle event sink.
//!
//! Everything here is built for the serving hot path:
//!
//! * [`Histogram`] — HDR-style fixed log-bucketed counts over a `u64`
//!   microsecond domain. `record` is O(1), allocation-free after
//!   construction, and the whole histogram is 1024 buckets (~8 KiB) no
//!   matter how many samples land in it. Quantiles come back within a
//!   documented ≤ 1/64 (~1.6 %) relative error of an exact sort.
//! * [`WindowCounter`] — a ring of 300 one-second slots so throughput
//!   numbers reflect the last 1 m / 5 m of load, not lifetime uptime.
//! * [`Tracer`] / [`Span`] — per-request span records (accept → queue →
//!   fused launch → solve → scatter → respond, plus job-plane lifecycle
//!   events) in a preallocated ring with an explicit `dropped` counter:
//!   overflow is visible, never silent. Recording never allocates.
//! * [`EventLog`] — append-only JSONL sink with size-based rotation for
//!   lifecycle events (drain / reload / retry / cancel / hot-swap).
//!
//! Tracing is observation only: it assigns ids and copies timestamps into
//! the ring but never touches RNG streams, chunking, or solver state, so
//! sample bytes are bitwise identical with tracing on or off (pinned by
//! `tests/obs.rs`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::json::Value;

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// log2(sub-buckets per octave). 32 sub-buckets give ≤ 1/64 relative error.
const LOG_SUBS: u32 = 5;
const SUBS: u64 = 1 << LOG_SUBS;

/// Total bucket count: 32 exact buckets for values < 32 µs, then 31 octave
/// groups of 32 sub-buckets each, covering values up to 2^36 µs (~19 h).
/// Larger values clamp into the last bucket.
pub const N_BUCKETS: usize = 1024;

/// Bucket index for a microsecond value. O(1), branch + leading_zeros.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUBS {
        return v as usize;
    }
    let m = 63 - v.leading_zeros(); // highest set bit, >= LOG_SUBS
    let g = (m - LOG_SUBS + 1) as u64; // octave group, >= 1
    let idx = (g << LOG_SUBS) + ((v >> (m - LOG_SUBS)) & (SUBS - 1));
    (idx as usize).min(N_BUCKETS - 1)
}

/// Inclusive lower bound (µs) of bucket `idx`.
fn bucket_lower(idx: usize) -> u64 {
    let g = (idx as u64) >> LOG_SUBS;
    let sub = (idx as u64) & (SUBS - 1);
    if g == 0 {
        sub
    } else {
        (SUBS + sub) << (g - 1)
    }
}

/// Width (µs) of bucket `idx`; the bucket covers `[lower, lower + width)`.
fn bucket_width(idx: usize) -> u64 {
    let g = (idx as u64) >> LOG_SUBS;
    if g == 0 {
        1
    } else {
        1 << (g - 1)
    }
}

/// Bounded log-bucketed latency histogram over microseconds.
///
/// Values below 32 µs are exact; above that each octave is split into 32
/// sub-buckets, so the bucket-midpoint representative a quantile query
/// returns is within `width/2 ≤ lower/64` of the true sample — a ≤ 1/64
/// (~1.6 %) relative error, plus the ±0.5 µs from rounding `record_ms`
/// input to integer microseconds. Memory is a fixed 1024 `u64` counts.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: vec![0; N_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one microsecond value. O(1), no allocation.
    #[inline]
    pub fn record_us(&mut self, us: u64) {
        self.counts[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Record a millisecond value (rounded to integer µs; NaN/negative → 0).
    #[inline]
    pub fn record_ms(&mut self, ms: f64) {
        // Float→int casts saturate, and NaN casts to 0.
        self.record_us((ms * 1000.0).round().max(0.0) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64 / 1000.0
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_us as f64 / 1000.0
    }

    /// Nearest-rank quantile (same rank rule as an exact sort:
    /// `rank = round((n-1)·q)`), answered with the midpoint of the bucket
    /// holding that rank. `q = 1` returns the exact maximum.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((self.count - 1) as f64 * q).round() as u64;
        if rank >= self.count - 1 {
            return self.max_ms();
        }
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                let lower = bucket_lower(idx);
                let width = bucket_width(idx);
                return (lower as f64 + (width as f64 - 1.0) / 2.0) / 1000.0;
            }
        }
        self.max_ms()
    }

    /// Add every count from `other` into `self`. Bucket layout is fixed, so
    /// merge is exact and associative.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Non-empty buckets as `(le_ms, count)` pairs, where `le_ms` is the
    /// inclusive upper bound of the bucket in milliseconds and `count` is
    /// the per-bucket (non-cumulative) count.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        for (idx, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                let le = (bucket_lower(idx) + bucket_width(idx) - 1) as f64 / 1000.0;
                out.push((le, c));
            }
        }
        out
    }

    /// JSON exposition: array of `[le_ms, count]` pairs (non-cumulative).
    pub fn buckets_json(&self) -> Value {
        Value::Arr(
            self.nonzero_buckets()
                .into_iter()
                .map(|(le, c)| Value::Arr(vec![Value::Num(le), Value::Num(c as f64)]))
                .collect(),
        )
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_us as f64 / 1000.0
    }
}

// ---------------------------------------------------------------------------
// WindowCounter
// ---------------------------------------------------------------------------

/// Ring length in seconds: enough for a 5-minute window.
pub const RATE_SLOTS: u64 = 300;

/// Windowed event counter: a ring of 300 one-second slots. `rate_at(now, w)`
/// averages the last `w` slots (clamped to elapsed lifetime, so a counter
/// that is 3 s old reports a rate over 3 s, not `w`). The deterministic
/// `_at(sec)` API takes seconds-since-start so tests need no clock.
#[derive(Clone)]
pub struct WindowCounter {
    slots: Vec<u64>,
    last_sec: u64,
    lifetime: u64,
}

impl Default for WindowCounter {
    fn default() -> Self {
        WindowCounter { slots: vec![0; RATE_SLOTS as usize], last_sec: 0, lifetime: 0 }
    }
}

impl WindowCounter {
    pub fn new() -> WindowCounter {
        WindowCounter::default()
    }

    /// Zero every slot between the last-seen second and `now_sec`.
    fn advance(&mut self, now_sec: u64) {
        if now_sec <= self.last_sec {
            return;
        }
        if now_sec - self.last_sec >= RATE_SLOTS {
            for s in self.slots.iter_mut() {
                *s = 0;
            }
        } else {
            for s in self.last_sec + 1..=now_sec {
                self.slots[(s % RATE_SLOTS) as usize] = 0;
            }
        }
        self.last_sec = now_sec;
    }

    pub fn record_at(&mut self, now_sec: u64, n: u64) {
        self.advance(now_sec);
        self.slots[(now_sec % RATE_SLOTS) as usize] += n;
        self.lifetime += n;
    }

    /// Events per second over the trailing `window_secs` (≤ 300) seconds,
    /// including the current partial second.
    pub fn rate_at(&mut self, now_sec: u64, window_secs: u64) -> f64 {
        self.advance(now_sec);
        let w = window_secs.clamp(1, RATE_SLOTS);
        let span = w.min(now_sec + 1);
        let mut sum = 0u64;
        for k in 0..span {
            sum += self.slots[((now_sec - k) % RATE_SLOTS) as usize];
        }
        sum as f64 / span as f64
    }

    pub fn lifetime(&self) -> u64 {
        self.lifetime
    }
}

// ---------------------------------------------------------------------------
// Tracer
// ---------------------------------------------------------------------------

/// Span stages along a request's path (and job-plane lifecycle marks).
///
/// Payload conventions (`group` / `detail` per stage):
///
/// | stage         | group            | detail                      |
/// |---------------|------------------|-----------------------------|
/// | `accept`      | 0                | requested samples           |
/// | `enqueue`     | chunk index      | chunk rows                  |
/// | `fuse_launch` | fused launch id  | total rows in the launch    |
/// | `solve`       | fused launch id  | solve wall µs               |
/// | `solve_step`  | fused launch id  | 0-based solver step index   |
/// | `scatter`     | fused launch id  | rows scattered back         |
/// | `respond`     | 0                | request latency µs          |
/// | `job_queued`  | 0                | 0                           |
/// | `job_start`   | attempt          | 0                           |
/// | `job_retry`   | attempt          | backoff wait ms             |
/// | `job_end`     | attempt          | 0 done / 1 failed / 2 cancelled |
///
/// Fused peers share a `fuse_launch` group id — that is how a trace query
/// reconstructs which member requests rode the same launch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Accept,
    Enqueue,
    FuseLaunch,
    Solve,
    SolveStep,
    Scatter,
    Respond,
    JobQueued,
    JobStart,
    JobRetry,
    JobEnd,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Enqueue => "enqueue",
            Stage::FuseLaunch => "fuse_launch",
            Stage::Solve => "solve",
            Stage::SolveStep => "solve_step",
            Stage::Scatter => "scatter",
            Stage::Respond => "respond",
            Stage::JobQueued => "job_queued",
            Stage::JobStart => "job_start",
            Stage::JobRetry => "job_retry",
            Stage::JobEnd => "job_end",
        }
    }
}

/// One fixed-size span record. `t_us` is microseconds since the tracer's
/// epoch (process start); `seq` is a global monotone sequence number so
/// ordering survives the ring.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub id: u64,
    pub seq: u64,
    pub stage: Stage,
    pub t_us: u64,
    pub group: u64,
    pub detail: u64,
}

struct Ring {
    spans: Vec<Span>,
    cap: usize,
    /// Index of the oldest span once the ring is full.
    head: usize,
    seq: u64,
}

/// Request-trace collector: assigns request ids, allocates fused-launch
/// group ids, and records spans into a preallocated ring. Overflow
/// overwrites the oldest span and bumps `dropped` — loss is counted, never
/// silent. With tracing disabled every call is a cheap early-out and no
/// ids are assigned.
pub struct Tracer {
    enabled: AtomicBool,
    sample_n: AtomicU64,
    next_id: AtomicU64,
    next_group: AtomicU64,
    dropped: AtomicU64,
    epoch: Instant,
    ring: Mutex<Ring>,
}

pub const DEFAULT_TRACE_RING: usize = 4096;

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(true, DEFAULT_TRACE_RING, 1)
    }
}

impl Tracer {
    pub fn new(enabled: bool, ring_cap: usize, sample_n: u64) -> Tracer {
        let cap = ring_cap.max(1);
        Tracer {
            enabled: AtomicBool::new(enabled),
            sample_n: AtomicU64::new(sample_n.max(1)),
            next_id: AtomicU64::new(0),
            next_group: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
            ring: Mutex::new(Ring { spans: Vec::with_capacity(cap), cap, head: 0, seq: 0 }),
        }
    }

    /// Reconfigure in place (config reload): resets the ring and dropped
    /// counter; request/group id counters keep running.
    pub fn configure(&self, enabled: bool, ring_cap: usize, sample_n: u64) {
        self.enabled.store(enabled, Ordering::Relaxed);
        self.sample_n.store(sample_n.max(1), Ordering::Relaxed);
        let cap = ring_cap.max(1);
        let mut ring = self.ring.lock().unwrap();
        *ring = Ring { spans: Vec::with_capacity(cap), cap, head: 0, seq: 0 };
        self.dropped.store(0, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn sample_n(&self) -> u64 {
        self.sample_n.load(Ordering::Relaxed)
    }

    pub fn ring_cap(&self) -> usize {
        self.ring.lock().unwrap().cap
    }

    pub fn span_count(&self) -> usize {
        self.ring.lock().unwrap().spans.len()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Assign the next request id, honoring sampling: returns `Some(id)`
    /// for requests that should be traced, `None` when tracing is off or
    /// the id is not selected by `trace_sample_n`.
    pub fn begin_request(&self) -> Option<u64> {
        if !self.enabled() {
            return None;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let n = self.sample_n();
        if n <= 1 || id % n == 0 {
            Some(id)
        } else {
            None
        }
    }

    /// Allocate a fused-launch group id shared by the launch's members.
    pub fn next_group_id(&self) -> u64 {
        self.next_group.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one span. O(1); never allocates (the ring vector keeps its
    /// reserved capacity). A full ring overwrites the oldest span and
    /// increments `dropped`.
    pub fn record(&self, id: u64, stage: Stage, group: u64, detail: u64) {
        if !self.enabled() {
            return;
        }
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let mut ring = self.ring.lock().unwrap();
        ring.seq += 1;
        let span = Span { id, seq: ring.seq, stage, t_us, group, detail };
        if ring.spans.len() < ring.cap {
            ring.spans.push(span);
        } else {
            let head = ring.head;
            ring.spans[head] = span;
            ring.head = (head + 1) % ring.cap;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Spans in chronological order, optionally filtered by id, keeping at
    /// most the `limit` most recent.
    pub fn snapshot(&self, filter_id: Option<u64>, limit: usize) -> Vec<Span> {
        let ring = self.ring.lock().unwrap();
        let n = ring.spans.len();
        let mut out: Vec<Span> = (0..n)
            .map(|k| ring.spans[(ring.head + k) % n.max(1)])
            .filter(|s| filter_id.map(|id| s.id == id).unwrap_or(true))
            .collect();
        if out.len() > limit {
            out.drain(..out.len() - limit);
        }
        out
    }

    /// Other request ids that shared a fused launch with `id`: every id
    /// holding a `fuse_launch` span whose group matches one of `id`'s.
    pub fn fuse_peers(&self, id: u64) -> Vec<u64> {
        let ring = self.ring.lock().unwrap();
        let groups: Vec<u64> = ring
            .spans
            .iter()
            .filter(|s| s.id == id && s.stage == Stage::FuseLaunch)
            .map(|s| s.group)
            .collect();
        let mut peers: Vec<u64> = ring
            .spans
            .iter()
            .filter(|s| s.stage == Stage::FuseLaunch && s.id != id && groups.contains(&s.group))
            .map(|s| s.id)
            .collect();
        peers.sort_unstable();
        peers.dedup();
        peers
    }
}

/// JSON shape of one span (used by the `trace` command).
pub fn span_json(s: &Span) -> Value {
    Value::obj(vec![
        ("request_id", Value::Num(s.id as f64)),
        ("seq", Value::Num(s.seq as f64)),
        ("stage", Value::Str(s.stage.name().into())),
        ("t_us", Value::Num(s.t_us as f64)),
        ("group", Value::Num(s.group as f64)),
        ("detail", Value::Num(s.detail as f64)),
    ])
}

// ---------------------------------------------------------------------------
// EventLog
// ---------------------------------------------------------------------------

/// Append-only JSONL sink for lifecycle events with size-based rotation:
/// when the file exceeds `max_bytes` it is renamed to `<name>.1` (replacing
/// any previous rotation) and a fresh file is started. Writes are
/// best-effort — an I/O error drops the line rather than failing serving.
pub struct EventLog {
    path: PathBuf,
    max_bytes: u64,
    file: Mutex<Option<(std::fs::File, u64)>>,
}

impl EventLog {
    pub fn open(path: &Path, max_bytes: u64) -> Result<EventLog> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create event log dir {}", dir.display()))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open event log {}", path.display()))?;
        let len = file.metadata().map(|m| m.len()).unwrap_or(0);
        Ok(EventLog {
            path: path.to_path_buf(),
            max_bytes: max_bytes.max(4096),
            file: Mutex::new(Some((file, len))),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append `{"ts":…,"event":…,…}`. Rotates first if the file is over
    /// the size limit.
    pub fn log(&self, event: &str, fields: &[(&str, Value)]) {
        use std::io::Write;
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let mut pairs = vec![("ts", Value::Num(ts)), ("event", Value::Str(event.into()))];
        for (k, v) in fields {
            pairs.push((k, v.clone()));
        }
        let line = Value::obj(pairs).to_string_compact();
        let mut guard = self.file.lock().unwrap();
        if let Some((_, len)) = guard.as_ref() {
            if *len >= self.max_bytes {
                *guard = None;
                let name = self.path.file_name().map(|n| n.to_string_lossy().into_owned());
                if let Some(name) = name {
                    let rotated = self.path.with_file_name(format!("{name}.1"));
                    let _ = std::fs::rename(&self.path, rotated);
                }
                if let Ok(f) =
                    std::fs::OpenOptions::new().create(true).append(true).open(&self.path)
                {
                    *guard = Some((f, 0));
                }
            }
        }
        if let Some((f, len)) = guard.as_mut() {
            if writeln!(f, "{line}").is_ok() {
                *len += line.len() as u64 + 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_contiguous_and_monotone() {
        // Every value maps into a bucket whose [lower, lower+width) range
        // contains it, and bucket lowers are strictly increasing.
        let mut prev_lower = None;
        for idx in 0..N_BUCKETS {
            let lo = bucket_lower(idx);
            let w = bucket_width(idx);
            if let Some(p) = prev_lower {
                assert!(lo > p, "bucket {idx} lower {lo} not > {p}");
            }
            prev_lower = Some(lo);
            assert_eq!(bucket_index(lo), idx);
            assert_eq!(bucket_index(lo + w - 1), idx);
        }
        // Adjacent buckets tile the line: upper(idx)+1 == lower(idx+1).
        for idx in 0..N_BUCKETS - 1 {
            assert_eq!(bucket_lower(idx) + bucket_width(idx), bucket_lower(idx + 1));
        }
    }

    #[test]
    fn histogram_exact_below_32us_and_max_exact() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 5, 31] {
            h.record_us(v);
        }
        h.record_us(999_999);
        assert_eq!(h.count(), 5);
        assert_eq!(h.quantile_ms(0.0), 0.0);
        assert!((h.quantile_ms(1.0) - 999.999).abs() < 1e-9);
    }

    #[test]
    fn window_counter_rates() {
        let mut w = WindowCounter::new();
        w.record_at(0, 60);
        assert!((w.rate_at(0, 60) - 60.0).abs() < 1e-9); // 1 elapsed second
        w.record_at(1, 60);
        assert!((w.rate_at(1, 60) - 60.0).abs() < 1e-9);
        // 58 idle seconds: 120 events over a full 60 s window.
        assert!((w.rate_at(59, 60) - 2.0).abs() < 1e-9);
        // After the window has fully slid past, the rate is zero.
        assert_eq!(w.rate_at(1000, 60), 0.0);
        assert_eq!(w.lifetime(), 120);
    }

    #[test]
    fn tracer_sampling_every_nth() {
        let t = Tracer::new(true, 16, 3);
        let picks: Vec<bool> = (0..9).map(|_| t.begin_request().is_some()).collect();
        assert_eq!(picks.iter().filter(|&&b| b).count(), 3);
        let t_off = Tracer::new(false, 16, 1);
        assert!(t_off.begin_request().is_none());
        t_off.record(1, Stage::Accept, 0, 0);
        assert_eq!(t_off.span_count(), 0);
    }

    #[test]
    fn event_log_rotates_by_size() {
        let dir = std::env::temp_dir()
            .join(format!("bespoke_obs_evlog_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("events.jsonl");
        let log = EventLog::open(&path, 4096).unwrap();
        for _ in 0..200 {
            log.log("hot_swap", &[("n", Value::Num(1.0))]);
        }
        let rotated = dir.join("events.jsonl.1");
        assert!(rotated.exists(), "rotation never happened");
        // Every surviving line is valid JSON with ts + event.
        let body = std::fs::read_to_string(&path).unwrap();
        for line in body.lines() {
            let v = Value::parse(line).unwrap();
            assert!(v.get("ts").is_ok() && v.get("event").is_ok());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
