//! Compute-thread policy for the row-parallel host kernels (analytic model
//! eval, batch statistics, Fréchet distance).
//!
//! Resolution order for [`get`]:
//!
//! 1. an explicit [`set`] override (CLI `--threads` / `serve.compute_threads`
//!    config key, applied at startup),
//! 2. the `BESPOKE_THREADS` environment variable,
//! 3. `std::thread::available_parallelism()`.
//!
//! Every parallel kernel is written so its result is **independent of the
//! thread count** (row-parallel kernels are embarrassingly parallel;
//! reductions run over fixed-size chunks combined in chunk order — see
//! DESIGN.md §7), so this knob trades wall-clock for nothing else.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Explicit override; 0 means "unset".
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Cached env/hardware default (resolved once).
static DEFAULT: OnceLock<usize> = OnceLock::new();

/// Pin the compute-thread count for this process (config/CLI path).
/// `n = 0` clears the override back to env/hardware resolution.
pub fn set(n: usize) {
    OVERRIDE.store(n, Ordering::SeqCst);
}

/// The compute-thread count kernels should use right now (always >= 1).
pub fn get() -> usize {
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    *DEFAULT.get_or_init(|| {
        if let Ok(s) = std::env::var("BESPOKE_THREADS") {
            if let Ok(n) = s.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_wins_and_clears() {
        set(3);
        assert_eq!(get(), 3);
        set(0);
        assert!(get() >= 1);
    }
}
