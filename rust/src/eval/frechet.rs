//! Fréchet distance between Gaussian fits of two sample sets — FID with
//! identity features (our FID analog, DESIGN.md §2):
//!
//! ```text
//! FD^2 = ||mu_a - mu_b||^2 + tr(Ca + Cb - 2 (Ca^{1/2} Cb Ca^{1/2})^{1/2})
//! ```

use super::linalg::{matmul, sqrtm_psd, trace};
use crate::tensor::Tensor;

/// FD between sample sets a [Na, d] and b [Nb, d] (sizes may differ).
/// Uses the process compute-thread policy; the result is identical for
/// every thread count (the Gaussian fits reduce over fixed-size chunks).
pub fn frechet_distance(a: &Tensor, b: &Tensor) -> f64 {
    frechet_distance_with_threads(a, b, crate::util::threads::get())
}

/// [`frechet_distance`] with an explicit thread count: the two Gaussian
/// fits (mean + covariance, the O(N d^2) part) run on separate threads
/// when `nt >= 2`, each with a chunk-parallel covariance.
pub fn frechet_distance_with_threads(a: &Tensor, b: &Tensor, nt: usize) -> f64 {
    assert_eq!(a.cols(), b.cols(), "dimension mismatch");
    let d = a.cols();
    let fit = |t: &Tensor, nt_side: usize| -> (Vec<f64>, Vec<f64>) {
        let mu = t.mean_axis0_with_threads(nt_side).iter().map(|&x| x as f64).collect();
        let cov = t.covariance_with_threads(nt_side);
        (mu, cov)
    };
    // Thread fork only when at least one fit has multi-chunk work; tiny
    // sample sets (both single-chunk, i.e. serial reductions anyway) skip
    // the two spawn/joins. Either branch computes identical values.
    let chunk = crate::tensor::PAR_CHUNK_ROWS;
    let multi_chunk = a.rows() > chunk || b.rows() > chunk;
    let ((mu_a, ca), (mu_b, cb)) = if nt >= 2 && multi_chunk {
        let per_side = (nt / 2).max(1);
        std::thread::scope(|s| {
            let fit = &fit;
            let ha = s.spawn(move || fit(a, per_side));
            let hb = s.spawn(move || fit(b, per_side));
            (ha.join().expect("frechet fit worker"), hb.join().expect("frechet fit worker"))
        })
    } else {
        (fit(a, 1), fit(b, 1))
    };

    let mean_term: f64 = mu_a
        .iter()
        .zip(&mu_b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();

    let sa = sqrtm_psd(&ca, d);
    let inner = matmul(&matmul(&sa, &cb, d), &sa, d);
    let cross = sqrtm_psd(&inner, d);
    let fd2 = mean_term + trace(&ca, d) + trace(&cb, d) - 2.0 * trace(&cross, d);
    fd2.max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_samples(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| mean + std * rng.normal()).collect();
        Tensor::new(data, vec![n, d]).unwrap()
    }

    #[test]
    fn zero_for_same_samples() {
        let a = gaussian_samples(2048, 4, 0.0, 1.0, 0);
        assert!(frechet_distance(&a, &a) < 1e-6);
    }

    #[test]
    fn detects_mean_shift_analytically() {
        // FD between N(0, I) and N(m, I) == |m|; estimate within sample noise
        let a = gaussian_samples(8192, 2, 0.0, 1.0, 1);
        let b = gaussian_samples(8192, 2, 1.0, 1.0, 2);
        let fd = frechet_distance(&a, &b);
        let want = (2.0f64).sqrt(); // mean shift (1,1)
        assert!((fd - want).abs() < 0.1, "fd={fd} want~{want}");
    }

    #[test]
    fn detects_scale_change_analytically() {
        // FD(N(0, s^2 I), N(0, I))^2 = d (s - 1)^2
        let a = gaussian_samples(8192, 3, 0.0, 2.0, 3);
        let b = gaussian_samples(8192, 3, 0.0, 1.0, 4);
        let fd = frechet_distance(&a, &b);
        let want = (3.0f64).sqrt(); // sqrt(d (2-1)^2)
        assert!((fd - want).abs() < 0.15, "fd={fd} want~{want}");
    }

    #[test]
    fn thread_count_invariant() {
        // > PAR_CHUNK_ROWS rows with ragged final chunks; exact f64 equality
        let a = gaussian_samples(700, 3, 0.1, 1.1, 7);
        let b = gaussian_samples(651, 3, 0.0, 1.0, 8);
        let f1 = frechet_distance_with_threads(&a, &b, 1);
        for nt in [2usize, 7] {
            assert_eq!(frechet_distance_with_threads(&a, &b, nt), f1, "nt={nt}");
        }
    }

    #[test]
    fn symmetric() {
        let a = gaussian_samples(1024, 5, 0.0, 1.0, 5);
        let b = gaussian_samples(1024, 5, 0.3, 1.2, 6);
        let f1 = frechet_distance(&a, &b);
        let f2 = frechet_distance(&b, &a);
        assert!((f1 - f2).abs() < 1e-9);
    }
}
