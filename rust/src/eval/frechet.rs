//! Fréchet distance between Gaussian fits of two sample sets — FID with
//! identity features (our FID analog, DESIGN.md §2):
//!
//! ```text
//! FD^2 = ||mu_a - mu_b||^2 + tr(Ca + Cb - 2 (Ca^{1/2} Cb Ca^{1/2})^{1/2})
//! ```

use super::linalg::{matmul, sqrtm_psd, trace};
use crate::tensor::Tensor;

/// FD between sample sets a [Na, d] and b [Nb, d] (sizes may differ).
pub fn frechet_distance(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.cols(), b.cols(), "dimension mismatch");
    let d = a.cols();
    let mu_a: Vec<f64> = a.mean_axis0().iter().map(|&x| x as f64).collect();
    let mu_b: Vec<f64> = b.mean_axis0().iter().map(|&x| x as f64).collect();
    let ca = a.covariance();
    let cb = b.covariance();

    let mean_term: f64 = mu_a
        .iter()
        .zip(&mu_b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();

    let sa = sqrtm_psd(&ca, d);
    let inner = matmul(&matmul(&sa, &cb, d), &sa, d);
    let cross = sqrtm_psd(&inner, d);
    let fd2 = mean_term + trace(&ca, d) + trace(&cb, d) - 2.0 * trace(&cross, d);
    fd2.max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_samples(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| mean + std * rng.normal()).collect();
        Tensor::new(data, vec![n, d]).unwrap()
    }

    #[test]
    fn zero_for_same_samples() {
        let a = gaussian_samples(2048, 4, 0.0, 1.0, 0);
        assert!(frechet_distance(&a, &a) < 1e-6);
    }

    #[test]
    fn detects_mean_shift_analytically() {
        // FD between N(0, I) and N(m, I) == |m|; estimate within sample noise
        let a = gaussian_samples(8192, 2, 0.0, 1.0, 1);
        let b = gaussian_samples(8192, 2, 1.0, 1.0, 2);
        let fd = frechet_distance(&a, &b);
        let want = (2.0f64).sqrt(); // mean shift (1,1)
        assert!((fd - want).abs() < 0.1, "fd={fd} want~{want}");
    }

    #[test]
    fn detects_scale_change_analytically() {
        // FD(N(0, s^2 I), N(0, I))^2 = d (s - 1)^2
        let a = gaussian_samples(8192, 3, 0.0, 2.0, 3);
        let b = gaussian_samples(8192, 3, 0.0, 1.0, 4);
        let fd = frechet_distance(&a, &b);
        let want = (3.0f64).sqrt(); // sqrt(d (2-1)^2)
        assert!((fd - want).abs() < 0.15, "fd={fd} want~{want}");
    }

    #[test]
    fn symmetric() {
        let a = gaussian_samples(1024, 5, 0.0, 1.0, 5);
        let b = gaussian_samples(1024, 5, 0.3, 1.2, 6);
        let f1 = frechet_distance(&a, &b);
        let f2 = frechet_distance(&b, &a);
        assert!((f1 - f2).abs() < 1e-9);
    }
}
