//! Evaluation metrics — the quantities every paper table/figure reports:
//!
//! * **RMSE** (paper eq. 6): global truncation error vs the GT solver,
//! * **PSNR** w.r.t. GT samples (paper Figs. 9-14),
//! * **FD**: Fréchet distance between Gaussian fits in data space — the
//!   FID analog for our low-dimensional substrates (FID *is* a Fréchet
//!   distance in a feature space; see DESIGN.md §2),
//! * **sliced W2**: sliced 2-Wasserstein distance (cross-check metric).

pub mod frechet;
pub mod linalg;
pub mod pipeline;

pub use frechet::{frechet_distance, frechet_distance_with_threads};
pub use pipeline::{evaluate_sampler, SamplerReport};

use crate::tensor::Tensor;
use crate::util::Rng;

/// Paper eq. 6: E_{x0} || x(1) - x_n ||, per-sample RMS norm averaged
/// over the batch.
pub fn rmse(approx: &Tensor, gt: &Tensor) -> f32 {
    let diff = approx.sub(gt).expect("rmse: shape mismatch");
    let norms = diff.row_rms();
    norms.iter().sum::<f32>() / norms.len() as f32
}

/// PSNR in dB w.r.t. GT samples; MAX = 2.0 (data normalized to [-1, 1],
/// matching the paper's image convention).
pub fn psnr(approx: &Tensor, gt: &Tensor) -> f32 {
    let diff = approx.sub(gt).expect("psnr: shape mismatch");
    let mse = {
        let d = diff.data();
        (d.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / d.len() as f64) as f32
    };
    10.0 * ((2.0f32 * 2.0) / mse.max(1e-20)).log10()
}

/// Sliced 2-Wasserstein distance: average over `n_proj` random directions
/// of the 1-D W2 between the projected samples (equal sizes required).
pub fn sliced_w2(a: &Tensor, b: &Tensor, n_proj: usize, seed: u64) -> f32 {
    assert_eq!(a.shape(), b.shape(), "sliced_w2 expects equal sample sets");
    let (n, d) = (a.rows(), a.cols());
    let mut rng = Rng::new(seed);
    let mut total = 0.0f64;
    let mut pa = vec![0.0f32; n];
    let mut pb = vec![0.0f32; n];
    for _ in 0..n_proj {
        // random unit direction
        let mut dir = rng.normal_vec(d);
        let norm = dir.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        dir.iter_mut().for_each(|x| *x /= norm);
        for i in 0..n {
            pa[i] = a.row(i).iter().zip(&dir).map(|(x, w)| x * w).sum();
            pb[i] = b.row(i).iter().zip(&dir).map(|(x, w)| x * w).sum();
        }
        pa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let w2: f64 = pa
            .iter()
            .zip(&pb)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        total += w2;
    }
    ((total / n_proj as f64).sqrt()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_zero_for_identical() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap();
        assert_eq!(rmse(&a, &a), 0.0);
        assert!(psnr(&a, &a) > 100.0);
    }

    #[test]
    fn rmse_matches_hand_computation() {
        let a = Tensor::new(vec![1.0, 1.0], vec![1, 2]).unwrap();
        let b = Tensor::new(vec![0.0, 0.0], vec![1, 2]).unwrap();
        assert!((rmse(&a, &b) - 1.0).abs() < 1e-7); // sqrt((1+1)/2)
        // PSNR = 10 log10(4 / 1)
        assert!((psnr(&a, &b) - 10.0 * 4.0f32.log10()).abs() < 1e-4);
    }

    #[test]
    fn sliced_w2_detects_shift() {
        let mut rng = crate::util::Rng::new(0);
        let n = 512;
        let a = Tensor::new(rng.normal_vec(n * 2), vec![n, 2]).unwrap();
        let b = a.map(|x| x + 1.0);
        let same = sliced_w2(&a, &a, 16, 1);
        let shifted = sliced_w2(&a, &b, 16, 1);
        assert!(same < 1e-6);
        // shifting by (1,1) => W2 ~ |shift| projected; must be clearly > 0.5
        assert!(shifted > 0.5, "{shifted}");
    }
}
