//! Sampler evaluation pipeline: runs a sampler over pre-drawn noise batches
//! and reports every paper metric against the cached GT solutions.

use anyhow::Result;

use super::{frechet_distance, psnr, rmse, sliced_w2};
use crate::models::{CountingModel, VelocityModel};
use crate::solvers::Sampler;
use crate::tensor::Tensor;
use crate::util::Timer;

#[derive(Clone, Debug)]
pub struct SamplerReport {
    pub sampler: String,
    /// Measured model evaluations per batch (not the nominal count).
    pub nfe: u64,
    /// Model evaluations actually performed per batch, *including* rejected
    /// adaptive attempts. `CountingModel` sits under the solver, so every
    /// stage evaluation is counted whether or not the step was accepted;
    /// for fixed-grid solvers this equals `nfe`, and for adaptive solvers
    /// it is the true compute cost of the batch.
    pub nfe_actual: u64,
    pub rmse: f32,
    pub psnr: f32,
    /// Fréchet distance of generated samples vs GT-solver samples.
    pub fd: f64,
    /// Sliced W2 vs GT-solver samples.
    pub swd: f32,
    /// Fréchet distance vs the *target dataset* (the paper's FID analog:
    /// generated-vs-real); NaN when no dataset reference was supplied.
    pub fd_data: f64,
    pub wall_ms_per_batch: f64,
}

impl SamplerReport {
    /// NaN-safe JSON: every metric goes through [`Value::num_or_null`], so
    /// a report with no dataset reference (`fd_data = NaN`) — or any other
    /// non-finite metric — still serializes to *valid* JSON (`null`), never
    /// a bare `NaN` token. [`SamplerReport::from_json`] maps `null` back.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        Value::obj(vec![
            ("sampler", Value::Str(self.sampler.clone())),
            ("nfe", Value::Num(self.nfe as f64)),
            ("nfe_actual", Value::Num(self.nfe_actual as f64)),
            ("rmse", Value::num_or_null(self.rmse as f64)),
            ("psnr", Value::num_or_null(self.psnr as f64)),
            ("fd", Value::num_or_null(self.fd)),
            ("fd_data", Value::num_or_null(self.fd_data)),
            ("swd", Value::num_or_null(self.swd as f64)),
            ("wall_ms_per_batch", Value::num_or_null(self.wall_ms_per_batch)),
        ])
    }

    pub fn from_json(v: &crate::json::Value) -> Result<SamplerReport> {
        use crate::json::Value;
        let num = |key: &str| -> Result<f64> {
            match v.get(key)? {
                Value::Null => Ok(f64::NAN),
                x => x.as_f64(),
            }
        };
        let nfe = v.get("nfe")?.as_usize()? as u64;
        Ok(SamplerReport {
            sampler: v.get("sampler")?.as_str()?.to_string(),
            nfe,
            // Reports written before the field existed had no rejected-stage
            // accounting; the measured nfe is the best available value.
            nfe_actual: match v.get_opt("nfe_actual") {
                Some(x) => x.as_usize()? as u64,
                None => nfe,
            },
            rmse: num("rmse")? as f32,
            psnr: num("psnr")? as f32,
            fd: num("fd")?,
            fd_data: num("fd_data")?,
            swd: num("swd")? as f32,
            wall_ms_per_batch: num("wall_ms_per_batch")?,
        })
    }
}

/// Evaluate `sampler` on `x0_batches` against the matching `gt_batches`
/// (same noise, solved by the GT solver). Batch counts must match.
pub fn evaluate_sampler(
    model: &dyn VelocityModel,
    sampler: &dyn Sampler,
    x0_batches: &[Tensor],
    gt_batches: &[Tensor],
    data_ref: Option<&Tensor>,
) -> Result<SamplerReport> {
    assert_eq!(x0_batches.len(), gt_batches.len());
    let counting = CountingModel::new(model);
    let timer = Timer::start();
    let mut outs = Vec::with_capacity(x0_batches.len());
    for x0 in x0_batches {
        outs.push(sampler.sample(&counting, x0)?);
    }
    let wall_ms_per_batch = timer.elapsed_ms() / x0_batches.len() as f64;
    let nfe = counting.nfe() / x0_batches.len() as u64;

    // Per-noise metrics.
    let mut rmse_acc = 0.0f64;
    let mut psnr_acc = 0.0f64;
    for (o, g) in outs.iter().zip(gt_batches) {
        rmse_acc += rmse(o, g) as f64;
        psnr_acc += psnr(o, g) as f64;
    }
    let nb = outs.len() as f64;

    // Distribution metrics over the pooled sets.
    let gen_all = Tensor::concat_rows(&outs.iter().collect::<Vec<_>>())?;
    let gt_all = Tensor::concat_rows(&gt_batches.iter().collect::<Vec<_>>())?;
    let fd = frechet_distance(&gen_all, &gt_all);
    let swd = sliced_w2(&gen_all, &gt_all, 32, 0xe7a1);
    let fd_data = data_ref.map_or(f64::NAN, |ds| frechet_distance(&gen_all, ds));

    Ok(SamplerReport {
        sampler: sampler.name(),
        nfe,
        // The counting shim sees every stage evaluation, rejected adaptive
        // attempts included, so the measured per-batch count *is* the
        // actual compute cost.
        nfe_actual: nfe,
        rmse: (rmse_acc / nb) as f32,
        psnr: (psnr_acc / nb) as f32,
        fd,
        fd_data,
        swd,
        wall_ms_per_batch,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;
    use crate::solvers::rk::{BaseRk, FixedGridSolver};
    use crate::solvers::Dopri5;
    use crate::util::Rng;

    #[test]
    fn report_improves_with_steps() {
        let pts = Tensor::from_rows(&[vec![1.0, 0.0], vec![-1.0, 0.5], vec![0.0, -1.0]]).unwrap();
        let model = AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 16).unwrap();
        let mut rng = Rng::new(0);
        let x0: Vec<Tensor> = (0..2)
            .map(|_| Tensor::new(rng.normal_vec(32), vec![16, 2]).unwrap())
            .collect();
        let gt_solver = Dopri5::default();
        let gt: Vec<Tensor> =
            x0.iter().map(|x| gt_solver.sample(&model, x).unwrap()).collect();

        let coarse = evaluate_sampler(
            &model,
            &FixedGridSolver::uniform(BaseRk::Rk2, 2),
            &x0,
            &gt,
            None,
        )
        .unwrap();
        let fine = evaluate_sampler(
            &model,
            &FixedGridSolver::uniform(BaseRk::Rk2, 32),
            &x0,
            &gt,
            Some(&gt[0]),
        )
        .unwrap();
        assert!(fine.rmse < coarse.rmse);
        assert!(fine.psnr > coarse.psnr);
        assert_eq!(coarse.nfe, 4);
        assert_eq!(fine.nfe, 64);
        assert!(fine.fd_data.is_finite() && coarse.fd_data.is_nan());
        // JSON serialization round-trips structurally
        let j = fine.to_json().to_string_compact();
        assert!(j.contains("\"rmse\""));
    }

    #[test]
    fn report_json_is_nan_safe_and_round_trips() {
        let rep = SamplerReport {
            sampler: "rk2:n=4".into(),
            nfe: 8,
            nfe_actual: 10,
            rmse: 0.125,
            psnr: 30.5,
            fd: 0.01,
            fd_data: f64::NAN, // no dataset reference — must become null
            swd: 0.02,
            wall_ms_per_batch: 1.5,
        };
        // The Value tree must carry an explicit Null, not Value::Num(NaN)
        // (NaN poisons Value::PartialEq and as_f64 consumers; the writer
        // only papers over it lossily at serialization time).
        assert!(matches!(rep.to_json().get("fd_data").unwrap(), crate::json::Value::Null));
        let text = rep.to_json().to_string_compact();
        assert!(text.contains("\"fd_data\":null"), "NaN must serialize as null: {text}");
        let back = SamplerReport::from_json(&crate::json::Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.sampler, rep.sampler);
        assert_eq!(back.nfe, 8);
        assert_eq!(back.nfe_actual, 10);
        // Pre-nfe_actual reports decode with nfe as the fallback.
        let mut old = rep.to_json();
        if let crate::json::Value::Obj(m) = &mut old {
            m.remove("nfe_actual");
        }
        assert_eq!(SamplerReport::from_json(&old).unwrap().nfe_actual, 8);
        assert_eq!(back.rmse, rep.rmse);
        assert_eq!(back.psnr, rep.psnr);
        assert_eq!(back.fd, rep.fd);
        assert!(back.fd_data.is_nan());
        assert_eq!(back.swd, rep.swd);
        assert_eq!(back.wall_ms_per_batch, rep.wall_ms_per_batch);
    }
}
