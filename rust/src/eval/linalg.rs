//! Dense symmetric linear algebra for the Fréchet metric: cyclic Jacobi
//! eigendecomposition and PSD matrix square root. O(d^3) per sweep — ample
//! for our d <= 256 data spaces.

/// Eigendecomposition of a symmetric matrix (row-major d x d).
/// Returns (eigenvalues, eigenvectors as columns flattened row-major).
pub fn sym_eigen(a: &[f64], d: usize, sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), d * d);
    let mut m = a.to_vec();
    // v = identity
    let mut v = vec![0.0f64; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }
    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..d {
            for q in (p + 1)..d {
                off += m[p * d + q] * m[p * d + q];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                for k in 0..d {
                    let vkp = v[k * d + p];
                    let vkq = v[k * d + q];
                    v[k * d + p] = c * vkp - s * vkq;
                    v[k * d + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig = (0..d).map(|i| m[i * d + i]).collect();
    (eig, v)
}

/// Matrix multiply (row-major, d x d).
pub fn matmul(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; d * d];
    for i in 0..d {
        for k in 0..d {
            let aik = a[i * d + k];
            if aik == 0.0 {
                continue;
            }
            let row_b = &b[k * d..(k + 1) * d];
            let row_o = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                row_o[j] += aik * row_b[j];
            }
        }
    }
    out
}

/// PSD square root via eigendecomposition (negative eigenvalues from
/// numerical noise are clamped to zero).
pub fn sqrtm_psd(a: &[f64], d: usize) -> Vec<f64> {
    let (eig, v) = sym_eigen(a, d, 30);
    // sqrt = V diag(sqrt(eig)) V^T
    let mut out = vec![0.0f64; d * d];
    for k in 0..d {
        let lk = eig[k].max(0.0).sqrt();
        if lk == 0.0 {
            continue;
        }
        for i in 0..d {
            let vik = v[i * d + k] * lk;
            if vik == 0.0 {
                continue;
            }
            for j in 0..d {
                out[i * d + j] += vik * v[j * d + k];
            }
        }
    }
    out
}

pub fn trace(a: &[f64], d: usize) -> f64 {
    (0..d).map(|i| a[i * d + i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_psd(d: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let g: Vec<f64> = (0..d * d).map(|_| rng.normal() as f64).collect();
        // A = G G^T / d + I * 0.1
        let mut a = vec![0.0f64; d * d];
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += g[i * d + k] * g[j * d + k];
                }
                a[i * d + j] = s / d as f64;
            }
            a[i * d + i] += 0.1;
        }
        a
    }

    #[test]
    fn eigen_reconstructs_matrix() {
        let d = 12;
        let a = random_psd(d, 0);
        let (eig, v) = sym_eigen(&a, d, 30);
        // A == V diag(eig) V^T
        let mut recon = vec![0.0f64; d * d];
        for k in 0..d {
            for i in 0..d {
                for j in 0..d {
                    recon[i * d + j] += v[i * d + k] * eig[k] * v[j * d + k];
                }
            }
        }
        for (x, y) in a.iter().zip(&recon) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let d = 16;
        let a = random_psd(d, 1);
        let r = sqrtm_psd(&a, d);
        let r2 = matmul(&r, &r, d);
        for (x, y) in a.iter().zip(&r2) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn trace_and_matmul_basics() {
        let i2 = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(trace(&i2, 2), 2.0);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(matmul(&i2, &b, 2), b);
    }

    #[test]
    fn diagonal_matrix_sqrt_exact() {
        let a = vec![4.0, 0.0, 0.0, 9.0];
        let r = sqrtm_psd(&a, 2);
        assert!((r[0] - 2.0).abs() < 1e-10);
        assert!((r[3] - 3.0).abs() < 1e-10);
        assert!(r[1].abs() < 1e-10);
    }
}
