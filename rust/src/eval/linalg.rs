//! Dense symmetric linear algebra for the Fréchet metric: cyclic Jacobi
//! eigendecomposition and PSD matrix square root. O(d^3) per sweep — ample
//! for our d <= 256 data spaces.

/// Eigendecomposition of a symmetric matrix (row-major d x d).
/// Returns (eigenvalues, eigenvectors as columns flattened row-major).
pub fn sym_eigen(a: &[f64], d: usize, sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), d * d);
    let mut m = a.to_vec();
    // v = identity
    let mut v = vec![0.0f64; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }
    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..d {
            for q in (p + 1)..d {
                off += m[p * d + q] * m[p * d + q];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                for k in 0..d {
                    let vkp = v[k * d + p];
                    let vkq = v[k * d + q];
                    v[k * d + p] = c * vkp - s * vkq;
                    v[k * d + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig = (0..d).map(|i| m[i * d + i]).collect();
    (eig, v)
}

/// Column-tile width of the blocked [`matmul`]: a `MM_BK x MM_BJ` panel of
/// `b` (32 KiB at f64) stays L1/L2-resident while every row of `a` sweeps
/// it.
const MM_BJ: usize = 64;
/// Inner-dimension tile depth of the blocked [`matmul`].
const MM_BK: usize = 64;

/// Matrix multiply (row-major, d x d), cache-blocked.
///
/// Loop order is `j-tile, k-tile, i, k, j`: the inner j-loop is contiguous
/// over both the output row and `b`'s row (autovectorizes), and for each
/// (j-tile, k-tile) pair the touched panel of `b` stays cache-resident
/// across all `i`. For every output element the k-terms still accumulate
/// in ascending-k order, so the result is **bitwise identical** to the
/// textbook [`matmul_naive`] loop (pinned in `perf_equivalence.rs`). Rows
/// of `a` that are exactly zero are skipped — `sqrtm_psd` feeds
/// identity-like intermediates through here.
pub fn matmul(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; d * d];
    for j0 in (0..d).step_by(MM_BJ) {
        let j1 = (j0 + MM_BJ).min(d);
        for k0 in (0..d).step_by(MM_BK) {
            let k1 = (k0 + MM_BK).min(d);
            for i in 0..d {
                let row_o = &mut out[i * d + j0..i * d + j1];
                for k in k0..k1 {
                    let aik = a[i * d + k];
                    if aik == 0.0 {
                        continue;
                    }
                    let row_b = &b[k * d + j0..k * d + j1];
                    for (o, &bv) in row_o.iter_mut().zip(row_b) {
                        *o += aik * bv;
                    }
                }
            }
        }
    }
    out
}

/// Textbook i-j-k matrix multiply (dot-product form with strided column
/// access into `b`): the retained naive reference the blocked [`matmul`]
/// is pinned bitwise-identical against, and the `_naive` baseline of the
/// `kernels/matmul_*` benches. Never on a serving path.
pub fn matmul_naive(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..d {
            let mut acc = 0.0f64;
            for k in 0..d {
                let aik = a[i * d + k];
                if aik == 0.0 {
                    continue;
                }
                acc += aik * b[k * d + j];
            }
            out[i * d + j] = acc;
        }
    }
    out
}

/// PSD square root via eigendecomposition (negative eigenvalues from
/// numerical noise are clamped to zero).
pub fn sqrtm_psd(a: &[f64], d: usize) -> Vec<f64> {
    let (eig, v) = sym_eigen(a, d, 30);
    // sqrt = V diag(sqrt(eig)) V^T
    let mut out = vec![0.0f64; d * d];
    for k in 0..d {
        let lk = eig[k].max(0.0).sqrt();
        if lk == 0.0 {
            continue;
        }
        for i in 0..d {
            let vik = v[i * d + k] * lk;
            if vik == 0.0 {
                continue;
            }
            for j in 0..d {
                out[i * d + j] += vik * v[j * d + k];
            }
        }
    }
    out
}

pub fn trace(a: &[f64], d: usize) -> f64 {
    (0..d).map(|i| a[i * d + i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_psd(d: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let g: Vec<f64> = (0..d * d).map(|_| rng.normal() as f64).collect();
        // A = G G^T / d + I * 0.1
        let mut a = vec![0.0f64; d * d];
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += g[i * d + k] * g[j * d + k];
                }
                a[i * d + j] = s / d as f64;
            }
            a[i * d + i] += 0.1;
        }
        a
    }

    #[test]
    fn eigen_reconstructs_matrix() {
        let d = 12;
        let a = random_psd(d, 0);
        let (eig, v) = sym_eigen(&a, d, 30);
        // A == V diag(eig) V^T
        let mut recon = vec![0.0f64; d * d];
        for k in 0..d {
            for i in 0..d {
                for j in 0..d {
                    recon[i * d + j] += v[i * d + k] * eig[k] * v[j * d + k];
                }
            }
        }
        for (x, y) in a.iter().zip(&recon) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let d = 16;
        let a = random_psd(d, 1);
        let r = sqrtm_psd(&a, d);
        let r2 = matmul(&r, &r, d);
        for (x, y) in a.iter().zip(&r2) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn trace_and_matmul_basics() {
        let i2 = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(trace(&i2, 2), 2.0);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(matmul(&i2, &b, 2), b);
    }

    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        // d straddles several MM_BJ/MM_BK tiles with ragged edges; dense
        // random matrices so any change in per-element k-order would move
        // bits. Exact equality, not tolerance.
        for d in [1usize, 7, 64, 65, 130] {
            let mut rng = Rng::new(d as u64);
            let a: Vec<f64> = (0..d * d).map(|_| rng.normal() as f64).collect();
            let b: Vec<f64> = (0..d * d).map(|_| rng.normal() as f64).collect();
            assert_eq!(matmul(&a, &b, d), matmul_naive(&a, &b, d), "d={d}");
        }
    }

    #[test]
    fn diagonal_matrix_sqrt_exact() {
        let a = vec![4.0, 0.0, 0.0, 9.0];
        let r = sqrtm_psd(&a, 2);
        assert!((r[0] - 2.0).abs() < 1e-10);
        assert!((r[3] - 3.0).abs() < 1e-10);
        assert!(r[1].abs() < 1e-10);
    }
}
