//! `repro` — the launcher for the bespoke-flow serving stack.
//!
//! ```text
//! repro list                                     models + artifacts
//! repro sample --model M --solver S --n N        generate samples
//! repro train-bespoke --model M --n 8 [...]      train a Bespoke solver
//! repro eval --model M --solver S                metrics vs GT solver
//! repro serve [--addr 127.0.0.1:7777]            JSONL sampling + training server
//! repro registry list|show|gc                    trained-solver artifact store
//! repro exp <id>|all                             reproduce a paper table/figure
//! ```
//!
//! Global flags: `--config <file.json>` (see `config.rs` schema),
//! `--artifacts <dir>` (default `./artifacts`).

use std::collections::BTreeMap;
use std::sync::Arc;

use bespoke_flow::bench_harness::{self, ExpContext};
use bespoke_flow::config::Config;
use bespoke_flow::coordinator::{
    serve, serve_daemon, spawn_scheduler, Coordinator, SampleRequest, ServerState, TrajRequest,
};
use bespoke_flow::models::Zoo;
use bespoke_flow::quality::{
    build_frontier, frontier_pins, register_scorecard, Budget, EvalJobSpec, EvalRunner,
};
use bespoke_flow::registry::{
    sidecar_path, ArtifactMeta, JobManager, JobOptions, JobRunner, Registry, TrainJobManager,
    ZooRunner,
};
use bespoke_flow::util::RetryPolicy;
use bespoke_flow::runtime::{Executable, Manifest};
use bespoke_flow::solvers::theta::{Base, Family};
use bespoke_flow::solvers::{sampler_for_theta, Dopri5, Sampler, SolverSpec};
use bespoke_flow::testing::loadgen;
use bespoke_flow::{bail, Context, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Flags that take no value (presence == true).
const BOOL_FLAGS: &[&str] = &["traj", "register", "smoke", "chaos", "clear"];

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut positional = Vec::new();
    let mut flags = BTreeMap::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if BOOL_FLAGS.contains(&name) {
                flags.insert(name.to_string(), "true".to_string());
                continue;
            }
            let val = it.next().with_context(|| format!("flag --{name} needs a value"))?;
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Ok(Args { cmd, positional, flags })
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.flags.get("config") {
        Some(p) => Config::load(std::path::Path::new(p))?,
        None => Config::default(),
    };
    if let Some(addr) = args.flags.get("addr") {
        cfg.serve.addr = addr.clone();
    }
    if let Some(iters) = args.flags.get("iters") {
        cfg.train.iters = iters.parse().context("bad --iters")?;
    }
    if let Some(ab) = args.flags.get("ablation") {
        cfg.train.ablation = ab.clone();
    }
    if let Some(s) = args.flags.get("samples") {
        cfg.eval.metric_samples = s.parse().context("bad --samples")?;
    }
    if let Some(w) = args.flags.get("workers") {
        cfg.serve.workers_per_route = w.parse().context("bad --workers")?;
    }
    if let Some(t) = args.flags.get("threads") {
        cfg.serve.compute_threads = t.parse().context("bad --threads")?;
    }
    if let Some(b) = args.flags.get("backend") {
        cfg.serve.backend = bespoke_flow::models::Backend::parse(b).context("bad --backend")?;
    }
    if let Some(w) = args.flags.get("fuse-window-us") {
        cfg.serve.fuse_window_us = w.parse().context("bad --fuse-window-us")?;
    }
    if let Some(r) = args.flags.get("fuse-max-rows") {
        cfg.serve.fuse_max_rows = r.parse().context("bad --fuse-max-rows")?;
    }
    if let Some(r) = args.flags.get("registry") {
        cfg.registry.root = r.clone();
    }
    // Pin the process-wide compute-thread policy (0 keeps env/auto).
    bespoke_flow::util::threads::set(cfg.serve.compute_threads);
    Ok(cfg)
}

fn open_zoo(args: &Args) -> Result<Arc<Zoo>> {
    let man = match args.flags.get("artifacts") {
        Some(dir) => Manifest::load(std::path::Path::new(dir))?,
        None => Manifest::load_default()?,
    };
    Ok(Arc::new(Zoo::new(Arc::new(man))))
}

fn open_registry(cfg: &Config) -> Result<Arc<Registry>> {
    Ok(Arc::new(Registry::open(std::path::Path::new(&cfg.registry.root))?))
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => {
            let zoo = open_zoo(&args)?;
            println!("platform: {}", bespoke_flow::runtime::platform()?);
            println!(
                "{:<14} {:>5} {:>6} {:>6}  {:<8} {}",
                "model", "d", "batch", "kind", "sched", "lossgrads"
            );
            for name in zoo.model_names() {
                let m = zoo.manifest().model(&name)?;
                println!(
                    "{:<14} {:>5} {:>6} {:>6}  {:<8} {:?}",
                    name,
                    m.d,
                    m.batch,
                    m.kind,
                    m.sched,
                    m.lossgrads.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "sample" => {
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            // Registry attached so bespoke:model=... specs resolve offline too.
            let coord = Coordinator::with_registry(zoo, cfg.serve.clone(), open_registry(&cfg)?);
            let model = args.flags.get("model").context("--model required")?.clone();
            // Budget-aware routing: --budget resolves against the model's
            // Pareto frontier instead of naming a solver.
            let budget = args.flags.get("budget").map(|b| Budget::parse(b)).transpose()?;
            if budget.is_some() && args.flags.contains_key("solver") {
                bail!("--solver and --budget are mutually exclusive; give one");
            }
            // Validate + canonicalize the spec up front: typos fail here
            // with a parse error, not deep inside a worker thread.
            let spec = SolverSpec::parse(
                args.flags.get("solver").map(String::as_str).unwrap_or("rk2:n=8"),
            )?;
            let n_samples = args
                .flags
                .get("n")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(16);
            let seed = args.flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);

            if args.flags.contains_key("traj") {
                if budget.is_some() {
                    bail!("--traj does not take --budget (trajectory requests name a solver)");
                }
                // Step-streamed sampling: print one progress line per step.
                let req = TrajRequest {
                    model,
                    solver: spec.to_string(),
                    n_samples,
                    seed,
                    every: args
                        .flags
                        .get("every")
                        .map(|s| s.parse())
                        .transpose()
                        .context("bad --every")?
                        .unwrap_or(1),
                };
                let resp = coord.sample_traj(&req, &mut |step| {
                    let total = step
                        .steps_total
                        .map(|n| format!("/{n}"))
                        .unwrap_or_default();
                    println!(
                        "step {}{total}  t={:.4}  nfe={}  x[0]={:?}",
                        step.step,
                        step.t,
                        step.nfe_total,
                        step.samples.first().map(|r| r.as_slice()).unwrap_or(&[]),
                    );
                    Ok(())
                })?;
                if let Some(out) = args.flags.get("out") {
                    let rows: Vec<bespoke_flow::json::Value> = resp
                        .samples
                        .as_ref()
                        .context("trajectory response carried no samples")?
                        .iter()
                        .map(|r| bespoke_flow::json::Value::from_f32s(r))
                        .collect();
                    std::fs::write(out, bespoke_flow::json::Value::Arr(rows).to_string_pretty())?;
                    println!("wrote {} samples to {out}", resp.n_samples);
                }
                println!("nfe={} latency={:.1}ms", resp.nfe, resp.latency_ms);
                return Ok(());
            }

            let req = SampleRequest {
                model,
                solver: if budget.is_some() { String::new() } else { spec.to_string() },
                n_samples,
                seed,
                return_samples: true,
                budget,
            };
            let resp = coord.submit(&req)?;
            let samples = resp
                .samples
                .as_ref()
                .context("coordinator response carried no samples")?;
            if let Some(out) = args.flags.get("out") {
                let rows: Vec<bespoke_flow::json::Value> = samples
                    .iter()
                    .map(|r| bespoke_flow::json::Value::from_f32s(r))
                    .collect();
                std::fs::write(out, bespoke_flow::json::Value::Arr(rows).to_string_pretty())?;
                println!("wrote {} samples to {out}", resp.n_samples);
            } else {
                for row in samples.iter().take(4) {
                    println!("{row:?}");
                }
                if resp.n_samples > 4 {
                    println!("... ({} samples total)", resp.n_samples);
                }
            }
            println!(
                "nfe={} batches={} latency={:.1}ms",
                resp.nfe, resp.batches, resp.latency_ms
            );
            Ok(())
        }
        "train-bespoke" => {
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let model_name = args.flags.get("model").context("--model required")?;
            let base = Base::parse(args.flags.get("base").map(String::as_str).unwrap_or("rk2"))?;
            let n: usize = args.flags.get("n").context("--n required")?.parse()?;
            let family = match args.flags.get("family") {
                Some(f) => Family::parse(f)?,
                None => Family::Stationary,
            };
            let window = args
                .flags
                .get("window")
                .map(|w| w.parse::<usize>())
                .transpose()
                .context("bad --window")?;
            if window.is_some() && family != Family::Multistep {
                bail!("--window is only valid with --family multistep");
            }
            let out = match family {
                Family::Stationary => {
                    let model = zoo.hlo(model_name)?;
                    let lg = zoo.manifest().lossgrad(model_name, base.name(), n)?;
                    let exe = Executable::load(&zoo.manifest().path(&lg.file))?;
                    bespoke_flow::bespoke::train(&model, &exe, base, n, &cfg.train)?
                }
                _ => {
                    // Closed-form family trainer: needs a servable model
                    // only, no AOT'd loss-grad artifact.
                    let model = zoo.serving_model(model_name)?;
                    let w = window.unwrap_or(cfg.train.window);
                    bespoke_flow::bespoke::train_family(
                        model.as_ref(),
                        family,
                        base,
                        n,
                        w,
                        &cfg.train,
                    )?
                }
            };
            println!(
                "trained {model_name} {} {} n={n}: best val RMSE {:.5} in {:.1}s",
                family.name(),
                base.name(),
                out.best_val_rmse,
                out.wall_secs
            );
            let family_tag = if family == Family::Stationary {
                String::new()
            } else {
                format!("_{}", family.name())
            };
            let default_path = format!(
                "out/thetas/theta_{model_name}{family_tag}_{}_n{n}{}.json",
                base.name(),
                if cfg.train.ablation == "full" {
                    String::new()
                } else {
                    format!("_{}", cfg.train.ablation)
                },
            );
            let path = args.flags.get("out").cloned().unwrap_or(default_path);
            if let Some(parent) = std::path::Path::new(&path).parent() {
                std::fs::create_dir_all(parent)?;
            }
            out.best.save(std::path::Path::new(&path))?;
            // Always persist the full outcome (history, gt_nfe, wall time)
            // as a NaN-safe sidecar — the registry metadata record.
            let meta = ArtifactMeta::from_outcome(model_name, base, n, &cfg.train.ablation, &out);
            let meta_path = sidecar_path(std::path::Path::new(&path));
            meta.save(&meta_path)?;
            println!("saved {path} (+ {})", meta_path.display());
            if args.flags.contains_key("register") {
                let registry = open_registry(&cfg)?;
                let rec = registry.register(&out.best, &meta)?;
                println!(
                    "registered {} v{} in {} (val_rmse {:.5})",
                    rec.key.label(),
                    rec.version,
                    registry.root().display(),
                    rec.val_rmse
                );
            }
            Ok(())
        }
        "eval" => match args.positional.first().map(String::as_str) {
            // `repro eval run`: sweep a (solver × grid) matrix and register
            // the scorecard into the registry — the offline twin of the
            // server's `evaluate` command. Works without compiled HLO
            // artifacts for `ideal` models (analytic oracle fallback).
            Some("run") => {
                let cfg = load_config(&args)?;
                let zoo = open_zoo(&args)?;
                let registry = open_registry(&cfg)?;
                let model = args.flags.get("model").context("--model required")?.clone();
                let solver =
                    SolverSpec::parse(args.flags.get("solver").context("--solver required")?)?;
                let grid = match args.flags.get("grid") {
                    Some(g) => g
                        .split(',')
                        .map(|s| s.trim().parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .context("bad --grid (expected e.g. 2,4,8)")?,
                    None => Vec::new(),
                };
                let seed = args.flags.get("seed").map(|s| s.parse()).transpose()?;
                let runner =
                    EvalRunner::new(zoo, registry.clone(), cfg.eval.clone(), cfg.quality.clone());
                let spec =
                    EvalJobSpec { model, solver: solver.to_string(), grid, seed };
                runner.validate(&spec)?;
                let card = runner.run(&spec, &mut |p| {
                    println!(
                        "  cell {}/{}  rmse={:.6}",
                        p.iter, p.iters_total, p.val_rmse
                    );
                })?;
                let rec = register_scorecard(&registry, &card)?;
                println!(
                    "registered scorecard {} {} v{} ({} rows) in {}",
                    rec.model,
                    rec.solver,
                    rec.version,
                    card.rows.len(),
                    registry.root().display()
                );
                Ok(())
            }
            // `repro eval frontier`: print the model's current Pareto
            // frontier over all registered scorecards (artifact-free).
            Some("frontier") => {
                let cfg = load_config(&args)?;
                let registry = open_registry(&cfg)?;
                let model = args.flags.get("model").context("--model required")?;
                let f = build_frontier(&registry, model)?;
                println!("{}", f.to_json().to_string_pretty());
                Ok(())
            }
            Some(other) => bail!("unknown eval subcommand {other:?} (run|frontier)"),
            // Legacy one-shot evaluation: print a single report without
            // touching the registry.
            None => {
                let cfg = load_config(&args)?;
                let zoo = open_zoo(&args)?;
                let model = args.flags.get("model").context("--model required")?.clone();
                let mut spec = SolverSpec::parse(
                    args.flags.get("solver").map(String::as_str).unwrap_or("rk2:n=8"),
                )?;
                if spec.needs_registry() {
                    spec = open_registry(&cfg)?.resolve_spec(&spec)?;
                    println!("resolved to {spec}");
                }
                let mut ctx = ExpContext::new(zoo, cfg)?;
                let rep = ctx.eval_solver_spec(&model, &spec)?;
                println!("{}", rep.to_json().to_string_pretty());
                Ok(())
            }
        },
        "serve" => {
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let registry = open_registry(&cfg)?;
            let coord = Arc::new(Coordinator::with_registry(
                zoo.clone(),
                cfg.serve.clone(),
                registry.clone(),
            ));
            // `[obs]` knobs: tracer on/off, span ring, sampling, event log.
            coord.metrics.apply_obs(&cfg.obs)?;
            let retry = RetryPolicy {
                max_attempts: cfg.registry.retry_max_attempts as u32,
                base_ms: cfg.registry.retry_base_ms,
                cap_ms: cfg.registry.retry_cap_ms,
            };
            let runner = Arc::new(ZooRunner::new(zoo.clone(), cfg.train.clone()));
            let jobs = Arc::new(TrainJobManager::with_options(
                registry.clone(),
                runner,
                cfg.registry.max_jobs,
                Some(coord.metrics.clone()),
                JobOptions { max_pending: cfg.registry.max_pending, retry },
            )?);
            let eval_runner = Arc::new(EvalRunner::new(
                zoo,
                registry.clone(),
                cfg.eval.clone(),
                cfg.quality.clone(),
            ));
            let eval_jobs = Arc::new(JobManager::with_options(
                registry,
                eval_runner.clone() as Arc<bespoke_flow::quality::EvalRunnerDyn>,
                cfg.quality.max_eval_jobs,
                Some(coord.metrics.clone()),
                JobOptions { max_pending: cfg.quality.max_pending, retry },
            )?);
            // Pick up jobs a previous drain interrupted (pending_*.json).
            match jobs.resubmit_persisted() {
                Ok(0) => {}
                Ok(n) => println!("resubmitted {n} interrupted train job(s)"),
                Err(e) => eprintln!("warning: resubmitting train jobs failed: {e:#}"),
            }
            match eval_jobs.resubmit_persisted() {
                Ok(0) => {}
                Ok(n) => println!("resubmitted {n} interrupted eval job(s)"),
                Err(e) => eprintln!("warning: resubmitting eval jobs failed: {e:#}"),
            }
            let state = ServerState::with_jobs(coord, jobs)
                .with_eval_jobs(eval_jobs)
                .with_eval_runner(eval_runner);
            if let Some(p) = args.flags.get("config") {
                state.lifecycle.set_config_path(std::path::PathBuf::from(p));
            }
            state.lifecycle.set_registry_cfg(cfg.registry.clone());
            let scheduler = spawn_scheduler(&state, &cfg.schedule);
            println!(
                "serving on {} (JSONL protocol; try {{\"cmd\":\"ping\"}}; registry {})",
                cfg.serve.addr, cfg.registry.root
            );
            // SIGTERM/SIGINT drain gracefully; SIGHUP hot-reloads --config.
            serve_daemon(state, &cfg.serve.addr)?;
            if let Some(h) = scheduler {
                let _ = h.join();
            }
            println!("server drained; interrupted jobs persisted for restart");
            Ok(())
        }
        // Operational client commands: talk to a running server over TCP.
        "jobs" => {
            let cfg = load_config(&args)?;
            match args.positional.first().map(String::as_str) {
                Some("cancel") => {
                    let id: u64 = args
                        .positional
                        .get(1)
                        .context("usage: repro jobs cancel <id> [--kind train|eval]")?
                        .parse()
                        .context("bad job id")?;
                    let kind = args.flags.get("kind").map(String::as_str).unwrap_or("train");
                    if !matches!(kind, "train" | "eval") {
                        bail!("--kind must be train or eval");
                    }
                    send_server_cmd(
                        &cfg,
                        &format!(r#"{{"cmd":"cancel_job","job_id":{id},"kind":"{kind}"}}"#),
                    )
                }
                Some("list") | None => send_server_cmd(&cfg, r#"{"cmd":"jobs"}"#),
                Some(other) => bail!("unknown jobs subcommand {other:?} (cancel|list)"),
            }
        }
        "server" => match args.positional.first().map(String::as_str) {
            Some("reload") => send_server_cmd(&load_config(&args)?, r#"{"cmd":"reload"}"#),
            Some("drain") => send_server_cmd(&load_config(&args)?, r#"{"cmd":"drain"}"#),
            Some("ping") | None => send_server_cmd(&load_config(&args)?, r#"{"cmd":"ping"}"#),
            // Request tracing: dump the span ring, optionally filtered to
            // one request id (then fused-peer ids come back too).
            Some("trace") => {
                let cfg = load_config(&args)?;
                let mut parts = vec![r#""cmd":"trace""#.to_string()];
                if let Some(id) = args.flags.get("id") {
                    let id: u64 = id.parse().context("bad --id")?;
                    parts.push(format!(r#""id":{id}"#));
                }
                if let Some(limit) = args.flags.get("limit") {
                    let limit: usize = limit.parse().context("bad --limit")?;
                    parts.push(format!(r#""limit":{limit}"#));
                }
                send_server_cmd(&cfg, &format!("{{{}}}", parts.join(",")))
            }
            // Metrics exposition: JSON (default) or Prometheus text.
            Some("metrics") => {
                let cfg = load_config(&args)?;
                match args.flags.get("format").map(String::as_str) {
                    None | Some("json") => send_server_cmd(&cfg, r#"{"cmd":"metrics"}"#),
                    Some("prom") | Some("prometheus") => {
                        let v = query_server(&cfg, r#"{"cmd":"metrics_prom"}"#)?;
                        if !v.get("ok")?.as_bool()? {
                            bail!("server reported failure");
                        }
                        // The exposition text rides JSON-encoded in "body";
                        // print it raw so scrapers can consume stdout.
                        print!("{}", v.get("body")?.as_str()?);
                        Ok(())
                    }
                    Some(other) => bail!("unknown --format {other:?} (json|prom)"),
                }
            }
            // Numerical-plane summary: guard/probe flags, quarantine count,
            // per-phase timing shares, flight-recorder digest.
            Some("profile") => send_server_cmd(&load_config(&args)?, r#"{"cmd":"profile"}"#),
            // Structured alert ring (sentinel + quarantine); --clear drains
            // the active list after printing (totals survive).
            Some("alerts") => {
                let cfg = load_config(&args)?;
                let line = if args.flags.contains_key("clear") {
                    r#"{"cmd":"alerts","clear":true}"#
                } else {
                    r#"{"cmd":"alerts"}"#
                };
                send_server_cmd(&cfg, line)
            }
            Some(other) => {
                bail!(
                    "unknown server subcommand {other:?} \
                     (reload|drain|ping|trace|metrics|profile|alerts)"
                )
            }
        },
        "registry" => {
            let cfg = load_config(&args)?;
            let registry = open_registry(&cfg)?;
            registry_cmd(&args, &cfg, &registry)
        }
        "loadgen" => {
            // Deterministic load harness: replay a seeded multi-client
            // schedule twice — fusion on, then `fuse_max_rows = 1` — and
            // record throughput/latency percentiles plus the fused/solo
            // speedup into BENCH_5.json. Errors if the two runs are not
            // byte-identical (the fusion plane's core invariant).
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let model = args.flags.get("model").context("--model required")?.clone();
            let solvers: Vec<String> = args
                .flags
                .get("solver")
                .map(String::as_str)
                .unwrap_or("rk2:n=8")
                .split(',')
                .map(|s| SolverSpec::parse(s.trim()).map(|sp| sp.to_string()))
                .collect::<Result<_>>()?;
            let n_choices: Vec<usize> = args
                .flags
                .get("n")
                .map(String::as_str)
                .unwrap_or("8")
                .split(',')
                .map(|s| s.trim().parse::<usize>())
                .collect::<std::result::Result<_, _>>()
                .context("bad --n (expected e.g. 8 or 1,8)")?;
            if n_choices.iter().any(|&n| n == 0) {
                bail!("--n entries must be >= 1");
            }
            let smoke = args.flags.contains_key("smoke");
            let mut spec = loadgen::LoadSpec::new(&model, &solvers[0]);
            spec.solvers = solvers;
            spec.n_choices = n_choices;
            spec.clients = args
                .flags
                .get("clients")
                .map(|s| s.parse())
                .transpose()
                .context("bad --clients")?
                .unwrap_or(8);
            spec.requests_per_client = args
                .flags
                .get("requests")
                .map(|s| s.parse())
                .transpose()
                .context("bad --requests")?
                .unwrap_or(if smoke { 6 } else { 32 });
            if let Some(s) = args.flags.get("seed") {
                spec.seed = s.parse().context("bad --seed")?;
            }

            // Chaos mode: lifecycle events (drain over TCP, hot reloads)
            // land mid-storm; writes BENCH_7.json instead of BENCH_5.json.
            if args.flags.contains_key("chaos") {
                return loadgen_chaos(&args, &cfg, zoo, &model, &spec);
            }

            let mut solo_serve = cfg.serve.clone();
            solo_serve.fuse_max_rows = 1;
            let fused_coord = Arc::new(Coordinator::with_registry(
                zoo.clone(),
                cfg.serve.clone(),
                open_registry(&cfg)?,
            ));
            let solo_coord =
                Arc::new(Coordinator::with_registry(zoo, solo_serve, open_registry(&cfg)?));

            // Warm both coordinators' routes (spawns workers, compiles
            // models, opens sessions) so the timed runs measure serving.
            for s in &spec.solvers {
                let warm = SampleRequest {
                    model: model.clone(),
                    solver: s.clone(),
                    n_samples: 1,
                    seed: 0,
                    return_samples: false,
                    budget: None,
                };
                fused_coord.submit(&warm)?;
                solo_coord.submit(&warm)?;
            }

            // Server-side accounting captured post-warm-up, so the deltas
            // cover exactly the timed runs.
            let solo_before = loadgen::ServerAccounting::capture(&solo_coord.metrics);
            let fused_before = loadgen::ServerAccounting::capture(&fused_coord.metrics);
            let solo_run = loadgen::run(&solo_coord, &spec)?;
            let fused_run = loadgen::run(&fused_coord, &spec)?;
            let speedup =
                fused_run.report.rows_per_sec / solo_run.report.rows_per_sec.max(1e-9);
            let bitwise = fused_run.bitwise_matches(&solo_run);

            // Post-run reconciliation: the server's own counters must
            // exactly match what the clients accounted for.
            let mut reconcile_errors = Vec::new();
            for (name, coord, before, run) in [
                ("solo", &solo_coord, &solo_before, &solo_run),
                ("fused", &fused_coord, &fused_before, &fused_run),
            ] {
                let delta =
                    loadgen::ServerAccounting::capture(&coord.metrics).delta(before);
                match loadgen::reconcile(
                    &delta,
                    run.report.requests as u64,
                    run.report.rows as u64,
                    0,
                ) {
                    None => println!(
                        "{name:<6} reconciliation ok: {} requests, {} rows, all solved once",
                        delta.requests, delta.samples
                    ),
                    Some(msg) => reconcile_errors.push(format!("{name}: {msg}")),
                }
            }

            for (name, r) in [("fused", &fused_run.report), ("solo", &solo_run.report)] {
                println!(
                    "{name:<6} {} requests ({} rows) in {:.3}s  \
                     {:.1} req/s  {:.1} rows/s  p50 {:.2}ms  p90 {:.2}ms  p99 {:.2}ms",
                    r.requests,
                    r.rows,
                    r.wall_secs,
                    r.throughput_rps,
                    r.rows_per_sec,
                    r.latency_p50_ms,
                    r.latency_p90_ms,
                    r.latency_p99_ms
                );
            }
            println!(
                "speedup (rows/s, fused vs fuse_max_rows=1): {speedup:.2}x  \
                 bitwise_match: {bitwise}"
            );
            let fused_events = fused_coord.metrics.event_count("fused_rows");
            println!("fused_rows counter: {fused_events}");

            let out_path = args.flags.get("out").cloned().unwrap_or_else(|| {
                format!("{}/../BENCH_5.json", env!("CARGO_MANIFEST_DIR"))
            });
            let doc = bespoke_flow::json::Value::obj(vec![
                ("bench", bespoke_flow::json::Value::Str("loadgen".into())),
                (
                    "threads",
                    bespoke_flow::json::Value::Num(bespoke_flow::util::threads::get() as f64),
                ),
                ("model", bespoke_flow::json::Value::Str(model.clone())),
                (
                    "solvers",
                    bespoke_flow::json::Value::Arr(
                        spec.solvers
                            .iter()
                            .map(|s| bespoke_flow::json::Value::Str(s.clone()))
                            .collect(),
                    ),
                ),
                ("clients", bespoke_flow::json::Value::Num(spec.clients as f64)),
                (
                    "requests_per_client",
                    bespoke_flow::json::Value::Num(spec.requests_per_client as f64),
                ),
                (
                    "n_choices",
                    bespoke_flow::json::Value::Arr(
                        spec.n_choices
                            .iter()
                            .map(|&n| bespoke_flow::json::Value::Num(n as f64))
                            .collect(),
                    ),
                ),
                ("seed", bespoke_flow::json::Value::Num(spec.seed as f64)),
                (
                    "fuse_window_us",
                    bespoke_flow::json::Value::Num(cfg.serve.fuse_window_us as f64),
                ),
                ("fused_rows_events", bespoke_flow::json::Value::Num(fused_events as f64)),
                (
                    "results",
                    bespoke_flow::json::Value::Arr(vec![
                        fused_run.report.to_json("loadgen/fused"),
                        solo_run.report.to_json("loadgen/solo"),
                    ]),
                ),
                ("speedup_rows_per_sec", bespoke_flow::json::Value::Num(speedup)),
                ("bitwise_match", bespoke_flow::json::Value::Bool(bitwise)),
                (
                    "reconciled",
                    bespoke_flow::json::Value::Bool(reconcile_errors.is_empty()),
                ),
            ]);
            std::fs::write(&out_path, doc.to_string_pretty())
                .with_context(|| format!("writing {out_path}"))?;
            println!("wrote {out_path}");
            if !bitwise {
                bail!(
                    "fused and solo runs disagree byte-for-byte — the fusion \
                     row-equivalence invariant is broken"
                );
            }
            if !reconcile_errors.is_empty() {
                bail!(
                    "server-side metrics do not reconcile with client accounting: {}",
                    reconcile_errors.join("; ")
                );
            }
            Ok(())
        }
        "bench-obs" => {
            // Observability-overhead A/B, two planes measured back to back
            // with identical loadgen storms through one fused coordinator,
            // alternating per repeat so drift hits both modes equally:
            //   1. span tracer on vs off — writes BENCH_8.json;
            //   2. numerical plane (per-step probe + non-finite guard +
            //      phase timers) on vs off — writes BENCH_9.json.
            // Gates per plane: enabled wall time within 3% of disabled
            // (best-of-repeats), and sample bytes bitwise identical across
            // modes (the numerics runs are also checked against the
            // tracer-off baseline).
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let model = args.flags.get("model").context("--model required")?.clone();
            let solvers: Vec<String> = args
                .flags
                .get("solver")
                .map(String::as_str)
                .unwrap_or("rk2:n=8")
                .split(',')
                .map(|s| SolverSpec::parse(s.trim()).map(|sp| sp.to_string()))
                .collect::<Result<_>>()?;
            let n_choices: Vec<usize> = args
                .flags
                .get("n")
                .map(String::as_str)
                .unwrap_or("8")
                .split(',')
                .map(|s| s.trim().parse::<usize>())
                .collect::<std::result::Result<_, _>>()
                .context("bad --n (expected e.g. 8 or 1,8)")?;
            if n_choices.iter().any(|&n| n == 0) {
                bail!("--n entries must be >= 1");
            }
            let smoke = args.flags.contains_key("smoke");
            let mut spec = loadgen::LoadSpec::new(&model, &solvers[0]);
            spec.solvers = solvers;
            spec.n_choices = n_choices;
            spec.clients = args
                .flags
                .get("clients")
                .map(|s| s.parse())
                .transpose()
                .context("bad --clients")?
                .unwrap_or(8);
            spec.requests_per_client = args
                .flags
                .get("requests")
                .map(|s| s.parse())
                .transpose()
                .context("bad --requests")?
                .unwrap_or(if smoke { 6 } else { 32 });
            if let Some(s) = args.flags.get("seed") {
                spec.seed = s.parse().context("bad --seed")?;
            }
            let repeats: usize = args
                .flags
                .get("repeats")
                .map(|s| s.parse())
                .transpose()
                .context("bad --repeats")?
                .unwrap_or(if smoke { 1 } else { 3 });
            if repeats == 0 {
                bail!("--repeats must be >= 1");
            }

            let coord = Arc::new(Coordinator::with_registry(
                zoo,
                cfg.serve.clone(),
                open_registry(&cfg)?,
            ));
            for s in &spec.solvers {
                let warm = SampleRequest {
                    model: model.clone(),
                    solver: s.clone(),
                    n_samples: 1,
                    seed: 0,
                    return_samples: false,
                    budget: None,
                };
                coord.submit(&warm)?;
            }

            let ring = cfg.obs.trace_ring;
            let mut wall_on = f64::INFINITY;
            let mut wall_off = f64::INFINITY;
            let mut run_on = None;
            let mut run_off = None;
            for _ in 0..repeats {
                coord.metrics.tracer().configure(true, ring, 1);
                let r = loadgen::run_traced(&coord, &spec)?;
                wall_on = wall_on.min(r.report.wall_secs);
                run_on = Some(r);
                coord.metrics.tracer().configure(false, ring, 1);
                let r = loadgen::run_traced(&coord, &spec)?;
                wall_off = wall_off.min(r.report.wall_secs);
                run_off = Some(r);
            }
            let (run_on, run_off) = (run_on.unwrap(), run_off.unwrap());
            let bitwise = run_on.bitwise_matches(&run_off);
            let ratio = wall_on / wall_off.max(1e-9);
            let pass = ratio <= 1.03;
            println!(
                "tracing on  best wall: {wall_on:.3}s\n\
                 tracing off best wall: {wall_off:.3}s\n\
                 overhead ratio: {ratio:.4} (gate <= 1.03)  pass: {pass}  \
                 bitwise_match: {bitwise}"
            );

            let out_path = args.flags.get("out").cloned().unwrap_or_else(|| {
                format!("{}/../BENCH_8.json", env!("CARGO_MANIFEST_DIR"))
            });
            let doc = bespoke_flow::json::Value::obj(vec![
                ("bench", bespoke_flow::json::Value::Str("obs-overhead".into())),
                (
                    "threads",
                    bespoke_flow::json::Value::Num(bespoke_flow::util::threads::get() as f64),
                ),
                ("model", bespoke_flow::json::Value::Str(model.clone())),
                (
                    "solvers",
                    bespoke_flow::json::Value::Arr(
                        spec.solvers
                            .iter()
                            .map(|s| bespoke_flow::json::Value::Str(s.clone()))
                            .collect(),
                    ),
                ),
                ("clients", bespoke_flow::json::Value::Num(spec.clients as f64)),
                (
                    "requests_per_client",
                    bespoke_flow::json::Value::Num(spec.requests_per_client as f64),
                ),
                ("seed", bespoke_flow::json::Value::Num(spec.seed as f64)),
                ("repeats", bespoke_flow::json::Value::Num(repeats as f64)),
                ("trace_ring", bespoke_flow::json::Value::Num(ring as f64)),
                ("wall_on_secs", bespoke_flow::json::Value::Num(wall_on)),
                ("wall_off_secs", bespoke_flow::json::Value::Num(wall_off)),
                ("overhead_ratio", bespoke_flow::json::Value::Num(ratio)),
                ("bitwise_match", bespoke_flow::json::Value::Bool(bitwise)),
                ("pass", bespoke_flow::json::Value::Bool(pass)),
            ]);
            std::fs::write(&out_path, doc.to_string_pretty())
                .with_context(|| format!("writing {out_path}"))?;
            println!("wrote {out_path}");
            if !bitwise {
                bail!(
                    "sample bytes differ between tracing on and off — the \
                     observability plane is perturbing results"
                );
            }
            if !pass && !smoke {
                bail!(
                    "tracing overhead {ratio:.4} exceeds the 3% gate \
                     ({wall_on:.3}s on vs {wall_off:.3}s off)"
                );
            }

            // Plane 2 — numerics A/B (tracer stays off from the last
            // iteration above, so this isolates the numerical plane).
            let mut nwall_on = f64::INFINITY;
            let mut nwall_off = f64::INFINITY;
            let mut nrun_on = None;
            let mut nrun_off = None;
            for _ in 0..repeats {
                coord.metrics.numerics().configure(true, true, true);
                let r = loadgen::run_traced(&coord, &spec)?;
                nwall_on = nwall_on.min(r.report.wall_secs);
                nrun_on = Some(r);
                coord.metrics.numerics().configure(false, false, false);
                let r = loadgen::run_traced(&coord, &spec)?;
                nwall_off = nwall_off.min(r.report.wall_secs);
                nrun_off = Some(r);
            }
            let (nrun_on, nrun_off) = (nrun_on.unwrap(), nrun_off.unwrap());
            // Three-way byte identity: probe+guard on vs off, and both vs
            // the tracer A/B's disabled baseline — the guard must be
            // scan-only on healthy routes.
            let nbitwise = nrun_on.bitwise_matches(&nrun_off)
                && nrun_on.bitwise_matches(&run_off);
            let quarantines = coord.metrics.numerics().quarantines();
            let nratio = nwall_on / nwall_off.max(1e-9);
            let npass = nratio <= 1.03;
            println!(
                "numerics on  best wall: {nwall_on:.3}s\n\
                 numerics off best wall: {nwall_off:.3}s\n\
                 overhead ratio: {nratio:.4} (gate <= 1.03)  pass: {npass}  \
                 bitwise_match: {nbitwise}  quarantines: {quarantines}"
            );

            let out9 = args.flags.get("out9").cloned().unwrap_or_else(|| {
                format!("{}/../BENCH_9.json", env!("CARGO_MANIFEST_DIR"))
            });
            let doc9 = bespoke_flow::json::Value::obj(vec![
                (
                    "bench",
                    bespoke_flow::json::Value::Str("numerics-overhead".into()),
                ),
                (
                    "threads",
                    bespoke_flow::json::Value::Num(bespoke_flow::util::threads::get() as f64),
                ),
                ("model", bespoke_flow::json::Value::Str(model.clone())),
                ("clients", bespoke_flow::json::Value::Num(spec.clients as f64)),
                (
                    "requests_per_client",
                    bespoke_flow::json::Value::Num(spec.requests_per_client as f64),
                ),
                ("seed", bespoke_flow::json::Value::Num(spec.seed as f64)),
                ("repeats", bespoke_flow::json::Value::Num(repeats as f64)),
                ("wall_on_secs", bespoke_flow::json::Value::Num(nwall_on)),
                ("wall_off_secs", bespoke_flow::json::Value::Num(nwall_off)),
                (
                    "latency_p50_ms_on",
                    bespoke_flow::json::Value::Num(nrun_on.report.latency_p50_ms),
                ),
                (
                    "latency_p50_ms_off",
                    bespoke_flow::json::Value::Num(nrun_off.report.latency_p50_ms),
                ),
                ("overhead_ratio", bespoke_flow::json::Value::Num(nratio)),
                ("bitwise_match", bespoke_flow::json::Value::Bool(nbitwise)),
                (
                    "quarantines",
                    bespoke_flow::json::Value::Num(quarantines as f64),
                ),
                ("pass", bespoke_flow::json::Value::Bool(npass)),
            ]);
            std::fs::write(&out9, doc9.to_string_pretty())
                .with_context(|| format!("writing {out9}"))?;
            println!("wrote {out9}");
            if !nbitwise {
                bail!(
                    "sample bytes differ with the numeric guard/probe on — \
                     the numerical plane is perturbing healthy samples"
                );
            }
            if quarantines != 0 {
                bail!("guard quarantined {quarantines} healthy route(s) during the bench");
            }
            if !npass && !smoke {
                bail!(
                    "numerics overhead {nratio:.4} exceeds the 3% gate \
                     ({nwall_on:.3}s on vs {nwall_off:.3}s off)"
                );
            }
            Ok(())
        }
        "bench-families" => {
            // Solver-family bench: train tiny BNS + multistep artifacts
            // against the model's GT paths, then measure RMSE-at-NFE and
            // per-solve wall-time percentiles for the stationary base-RK
            // baselines, the trained families, and the training-free
            // Adams–Bashforth solver. Writes BENCH_6.json; works
            // artifact-free on the fixture zoo (`ideal` models fall back
            // to the analytic oracle).
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let model_name = args.flags.get("model").context("--model required")?.clone();
            let n: usize = args
                .flags
                .get("n")
                .map(|s| s.parse())
                .transpose()
                .context("bad --n")?
                .unwrap_or(4);
            if n == 0 {
                bail!("--n must be >= 1");
            }
            let repeats: usize = args
                .flags
                .get("repeats")
                .map(|s| s.parse())
                .transpose()
                .context("bad --repeats")?
                .unwrap_or(5);
            let model = zoo.serving_model(&model_name)?;
            let sched = zoo.scheduler(&model_name)?;

            // GT batches — the eval runner's recipe, inline.
            let gt_solver = Dopri5 {
                rtol: cfg.eval.gt_tol,
                atol: cfg.eval.gt_tol,
                max_steps: 100_000,
            };
            let nb = cfg.quality.eval_batches.max(1);
            let (b, d) = (model.batch(), model.dim());
            let mut rng = bespoke_flow::util::Rng::new(cfg.eval.seed);
            let mut x0 = Vec::with_capacity(nb);
            let mut gt = Vec::with_capacity(nb);
            for _ in 0..nb {
                let noise =
                    bespoke_flow::tensor::Tensor::new(rng.normal_vec(b * d), vec![b, d])?;
                gt.push(gt_solver.sample(model.as_ref(), &noise)?);
                x0.push(noise);
            }

            println!(
                "training bns (rk2, n={n}) and multistep (rk1, n={n}, window={})",
                cfg.train.window
            );
            let bns = bespoke_flow::bespoke::train_family(
                model.as_ref(),
                Family::Bns,
                Base::Rk2,
                n,
                cfg.train.window,
                &cfg.train,
            )?;
            let ms = bespoke_flow::bespoke::train_family(
                model.as_ref(),
                Family::Multistep,
                Base::Rk1,
                n,
                cfg.train.window,
                &cfg.train,
            )?;

            let entries: Vec<(&str, Box<dyn Sampler>)> = vec![
                // stationary-identity baselines at the families' NFE points
                ("stationary", SolverSpec::parse(&format!("rk1:n={n}"))?.build(sched)?),
                ("stationary", SolverSpec::parse(&format!("rk2:n={n}"))?.build(sched)?),
                ("bns", sampler_for_theta(&bns.best)?),
                ("multistep", sampler_for_theta(&ms.best)?),
                ("ab", SolverSpec::parse(&format!("ab:n={n}"))?.build(sched)?),
            ];
            let mut rows = Vec::new();
            for (tag, sampler) in &entries {
                let rep = bespoke_flow::eval::evaluate_sampler(
                    model.as_ref(),
                    sampler.as_ref(),
                    &x0,
                    &gt,
                    None,
                )?;
                let mut times_ms = Vec::with_capacity(nb * repeats);
                for _ in 0..repeats {
                    for x in &x0 {
                        let t0 = std::time::Instant::now();
                        sampler.sample(model.as_ref(), x)?;
                        times_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                let (p50, p90, p99) = (
                    percentile_ms(&mut times_ms, 50.0),
                    percentile_ms(&mut times_ms, 90.0),
                    percentile_ms(&mut times_ms, 99.0),
                );
                println!(
                    "{tag:<10} {:<28} nfe={:<3} rmse={:.6}  p50={p50:.3}ms p90={p90:.3}ms p99={p99:.3}ms",
                    rep.sampler, rep.nfe, rep.rmse
                );
                rows.push(bespoke_flow::json::Value::obj(vec![
                    ("family", bespoke_flow::json::Value::Str((*tag).into())),
                    ("solver", bespoke_flow::json::Value::Str(rep.sampler.clone())),
                    ("nfe", bespoke_flow::json::Value::Num(rep.nfe as f64)),
                    ("rmse", bespoke_flow::json::Value::num_or_null(rep.rmse as f64)),
                    ("wall_ms_p50", bespoke_flow::json::Value::Num(p50)),
                    ("wall_ms_p90", bespoke_flow::json::Value::Num(p90)),
                    ("wall_ms_p99", bespoke_flow::json::Value::Num(p99)),
                ]));
            }

            let out_path = args.flags.get("out").cloned().unwrap_or_else(|| {
                format!("{}/../BENCH_6.json", env!("CARGO_MANIFEST_DIR"))
            });
            let doc = bespoke_flow::json::Value::obj(vec![
                ("bench", bespoke_flow::json::Value::Str("families".into())),
                (
                    "threads",
                    bespoke_flow::json::Value::Num(bespoke_flow::util::threads::get() as f64),
                ),
                ("model", bespoke_flow::json::Value::Str(model_name)),
                ("n", bespoke_flow::json::Value::Num(n as f64)),
                ("window", bespoke_flow::json::Value::Num(cfg.train.window as f64)),
                ("iters", bespoke_flow::json::Value::Num(cfg.train.iters as f64)),
                ("seed", bespoke_flow::json::Value::Num(cfg.eval.seed as f64)),
                ("eval_batches", bespoke_flow::json::Value::Num(nb as f64)),
                ("repeats", bespoke_flow::json::Value::Num(repeats as f64)),
                ("results", bespoke_flow::json::Value::Arr(rows)),
            ]);
            std::fs::write(&out_path, doc.to_string_pretty())
                .with_context(|| format!("writing {out_path}"))?;
            println!("wrote {out_path}");
            Ok(())
        }
        "exp" => {
            let cfg = load_config(&args)?;
            let zoo = open_zoo(&args)?;
            let id = args.positional.first().context("usage: repro exp <id>|all")?;
            let mut ctx = ExpContext::new(zoo, cfg)?;
            bench_harness::run(&mut ctx, id)?;
            println!("experiment {id} complete; see out/reports/");
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `repro help`"),
    }
}

/// Send one JSONL command to the running server at `serve.addr` and
/// return the parsed reply (without printing it).
fn query_server(cfg: &Config, line: &str) -> Result<bespoke_flow::json::Value> {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(&cfg.serve.addr)
        .with_context(|| format!("connecting to server at {}", cfg.serve.addr))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    let resp = resp.trim();
    if resp.is_empty() {
        bail!("server closed the connection without a reply");
    }
    bespoke_flow::json::Value::parse(resp)
}

/// [`query_server`], printing the reply line and failing if the server
/// reports an error.
fn send_server_cmd(cfg: &Config, line: &str) -> Result<()> {
    let v = query_server(cfg, line)?;
    println!("{}", v.to_string_compact());
    if !v.get("ok")?.as_bool()? {
        bail!("server reported failure");
    }
    Ok(())
}

/// `repro loadgen --chaos`: byte-digest verification under lifecycle
/// churn (DESIGN.md §12). Two storms, each checked against a golden
/// in-process run: (1) hot config reloads retire every route mid-storm —
/// results must stay byte-identical; (2) a live TCP server drains
/// mid-storm — every request must end in a byte-correct response or a
/// structured `draining` rejection, zero silent drops. Tail-latency
/// percentiles for both storms go to BENCH_7.json.
fn loadgen_chaos(
    args: &Args,
    cfg: &Config,
    zoo: Arc<Zoo>,
    model: &str,
    spec: &loadgen::LoadSpec,
) -> Result<()> {
    let coord = Arc::new(Coordinator::with_registry(
        zoo.clone(),
        cfg.serve.clone(),
        open_registry(cfg)?,
    ));
    for s in &spec.solvers {
        let warm = SampleRequest {
            model: model.to_string(),
            solver: s.clone(),
            n_samples: 1,
            seed: 0,
            return_samples: false,
            budget: None,
        };
        coord.submit(&warm)?;
    }

    // Phase 1 — reload storm: concurrent schedule with background route
    // retirement vs the quiet sequential golden.
    let reloads: usize = args
        .flags
        .get("reloads")
        .map(|s| s.parse())
        .transpose()
        .context("bad --reloads")?
        .unwrap_or(8);
    let phase1_before = loadgen::ServerAccounting::capture(&coord.metrics);
    let quiet = loadgen::run_sequential(&coord, spec)?;
    let reload_run = loadgen::run_with_reloads(&coord, spec, reloads)?;
    let reload_bitwise = reload_run.bitwise_matches(&quiet);
    // Reconcile phase 1 (quiet + reload storm share the coordinator).
    // Route-retirement retries may legitimately re-solve a chunk whose
    // batch-mates already landed, so `rows_used` is a lower-bounded check
    // here rather than an exact one.
    let mut reconcile_errors: Vec<String> = Vec::new();
    let d1 = loadgen::ServerAccounting::capture(&coord.metrics).delta(&phase1_before);
    let p1_requests = (quiet.report.requests + reload_run.report.requests) as u64;
    let p1_rows = (quiet.report.rows + reload_run.report.rows) as u64;
    if d1.requests != p1_requests || d1.samples != p1_rows || d1.rows_used < d1.samples {
        reconcile_errors.push(format!(
            "reload storm: server saw {}/{} requests/rows (solved {}), \
             clients accounted {p1_requests}/{p1_rows}",
            d1.requests, d1.samples, d1.rows_used
        ));
    } else {
        println!(
            "reload reconciliation ok: {} requests, {} rows (server books balance)",
            d1.requests, d1.samples
        );
    }
    println!(
        "reload storm: {} requests, {} reloads, bitwise_match: {reload_bitwise}  \
         p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms",
        reload_run.report.requests,
        reloads,
        reload_run.report.latency_p50_ms,
        reload_run.report.latency_p90_ms,
        reload_run.report.latency_p99_ms
    );

    // Phase 2 — drain storm over TCP: golden digests from the seed-masked
    // plan, then a live server that begins draining mid-storm.
    let plan = loadgen::tcp_schedule(spec);
    let phase2_before = loadgen::ServerAccounting::capture(&coord.metrics);
    let golden = loadgen::run_plan_sequential(&coord, &plan)?;
    let addr = if args.flags.contains_key("addr") {
        cfg.serve.addr.clone()
    } else {
        "127.0.0.1:7399".to_string()
    };
    let state = ServerState::sampling_only(coord.clone());
    let server = {
        let state = state.clone();
        let addr = addr.clone();
        std::thread::spawn(move || serve(state, &addr))
    };
    let drain_after_ms: u64 = args
        .flags
        .get("drain-after-ms")
        .map(|s| s.parse())
        .transpose()
        .context("bad --drain-after-ms")?
        .unwrap_or(100);
    let trigger = {
        let lifecycle = state.lifecycle.clone();
        let metrics = state.coord.metrics.clone();
        let clients = spec.clients as u64;
        std::thread::spawn(move || {
            // Zero-loss needs every storm client accepted before the drain
            // latch stops the accept loop; only then does the knob's delay
            // start counting.
            let deadline =
                std::time::Instant::now() + std::time::Duration::from_secs(10);
            while metrics.event_count("connections") < clients
                && std::time::Instant::now() < deadline
            {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            std::thread::sleep(std::time::Duration::from_millis(drain_after_ms));
            lifecycle.request_drain();
        })
    };
    let drain_report = loadgen::run_tcp(&addr, &plan, &golden)?;
    let _ = trigger.join();
    match server.join() {
        Ok(r) => r?,
        Err(_) => bail!("server thread panicked during drain"),
    }
    // Reconcile phase 2 (golden pass + TCP drain storm share the
    // coordinator). Exact checks only make sense when every non-ok outcome
    // is an explained drain rejection: an `rejected_other` or digest
    // mismatch means the client and server disagree about what happened,
    // and the lossless gate below reports that instead.
    let d2 = loadgen::ServerAccounting::capture(&coord.metrics).delta(&phase2_before);
    if drain_report.rejected_other == 0 && drain_report.digest_mismatches == 0 {
        let p2_requests = golden.report.requests as u64 + drain_report.ok as u64;
        let p2_rows = golden.report.rows as u64 + drain_report.ok_rows as u64;
        if d2.requests != p2_requests
            || d2.samples != p2_rows
            || d2.rows_used < d2.samples
            || d2.rejected_draining != drain_report.rejected_draining as u64
        {
            reconcile_errors.push(format!(
                "drain storm: server saw {}/{} requests/rows and {} drain \
                 rejections, clients accounted {p2_requests}/{p2_rows} and {} \
                 drain rejections",
                d2.requests, d2.samples, d2.rejected_draining, drain_report.rejected_draining
            ));
        } else {
            println!(
                "drain reconciliation ok: {} requests, {} rows, {} drain rejections",
                d2.requests, d2.samples, d2.rejected_draining
            );
        }
    } else {
        println!(
            "drain reconciliation skipped: {} unexplained rejections / {} mismatches",
            drain_report.rejected_other, drain_report.digest_mismatches
        );
    }
    let lossless = drain_report.lossless();
    println!(
        "drain storm:  {} sent / {} ok / {} drained / {} other / {} mismatched / {} dropped  \
         lossless: {lossless}  p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms",
        drain_report.sent,
        drain_report.ok,
        drain_report.rejected_draining,
        drain_report.rejected_other,
        drain_report.digest_mismatches,
        drain_report.no_response,
        drain_report.latency_p50_ms,
        drain_report.latency_p90_ms,
        drain_report.latency_p99_ms
    );

    let out_path = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}/../BENCH_7.json", env!("CARGO_MANIFEST_DIR")));
    let doc = bespoke_flow::json::Value::obj(vec![
        ("bench", bespoke_flow::json::Value::Str("chaos".into())),
        (
            "threads",
            bespoke_flow::json::Value::Num(bespoke_flow::util::threads::get() as f64),
        ),
        ("model", bespoke_flow::json::Value::Str(model.to_string())),
        ("clients", bespoke_flow::json::Value::Num(spec.clients as f64)),
        (
            "requests_per_client",
            bespoke_flow::json::Value::Num(spec.requests_per_client as f64),
        ),
        ("seed", bespoke_flow::json::Value::Num(spec.seed as f64)),
        ("reloads", bespoke_flow::json::Value::Num(reloads as f64)),
        ("drain_after_ms", bespoke_flow::json::Value::Num(drain_after_ms as f64)),
        (
            "results",
            bespoke_flow::json::Value::Arr(vec![
                quiet.report.to_json("chaos/quiet"),
                reload_run.report.to_json("chaos/reload-storm"),
            ]),
        ),
        ("reload_bitwise_match", bespoke_flow::json::Value::Bool(reload_bitwise)),
        ("drain_storm", drain_report.to_json("chaos/drain-storm")),
        (
            "reconciled",
            bespoke_flow::json::Value::Bool(reconcile_errors.is_empty()),
        ),
    ]);
    std::fs::write(&out_path, doc.to_string_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    if !reload_bitwise {
        bail!("reload storm broke byte-identity — route retirement dropped or corrupted rows");
    }
    if !lossless {
        bail!(
            "drain storm was not lossless — {} silent drops, {} digest mismatches",
            drain_report.no_response,
            drain_report.digest_mismatches
        );
    }
    if !reconcile_errors.is_empty() {
        bail!(
            "server-side metrics do not reconcile with client accounting: {}",
            reconcile_errors.join("; ")
        );
    }
    Ok(())
}

/// Nearest-rank percentile over millisecond samples (sorts in place).
fn percentile_ms(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
    let idx = ((p / 100.0) * (samples.len() - 1) as f64).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// `repro registry list|show|gc` — operate on the artifact store without
/// touching the model zoo (works with no compiled HLO artifacts present).
fn registry_cmd(args: &Args, cfg: &Config, registry: &Registry) -> Result<()> {
    match args.positional.first().map(String::as_str).unwrap_or("list") {
        "list" => {
            let records = registry.list();
            println!("registry: {} ({} artifacts)", registry.root().display(), records.len());
            println!(
                "{:<14} {:>4} {:>3} {:<10} {:<10} {:>3} {:>10} {:>9} {:>10}",
                "model", "base", "n", "ablation", "family", "v", "val_rmse", "gt_nfe", "created"
            );
            for r in records {
                println!(
                    "{:<14} {:>4} {:>3} {:<10} {:<10} {:>3} {:>10.5} {:>9} {:>10}",
                    r.key.model,
                    r.key.base.name(),
                    r.key.n,
                    r.key.ablation,
                    r.family.name(),
                    r.version,
                    r.val_rmse,
                    r.gt_nfe,
                    r.created_at
                );
            }
            Ok(())
        }
        "show" => {
            let model = args.flags.get("model").context("--model required")?;
            let n: usize = args.flags.get("n").context("--n required")?.parse()?;
            let base = args
                .flags
                .get("base")
                .map(|b| Base::parse(b))
                .transpose()?;
            let ablation = args.flags.get("ablation").map(String::as_str);
            let family = args.flags.get("family").map(|f| Family::parse(f)).transpose()?;
            let best = registry
                .best(model, n, base, ablation, family)
                .context("no matching artifact registered")?;
            println!("best: v{} (val_rmse {:.5})", best.version, best.val_rmse);
            println!("  theta: {}", registry.theta_path(&best).display());
            println!("  hash:  {}", best.content_hash);
            // Integrity check what serving would load.
            registry.load_theta(&best)?;
            println!("  integrity: ok");
            for r in registry.list() {
                if r.key == best.key {
                    println!(
                        "  v{} val_rmse {:.5} gt_nfe {} wall {:.1}s created {}",
                        r.version, r.val_rmse, r.gt_nfe, r.wall_secs, r.created_at
                    );
                }
            }
            Ok(())
        }
        "gc" => {
            let keep = args
                .flags
                .get("keep")
                .map(|k| k.parse())
                .transpose()
                .context("bad --keep")?
                .unwrap_or(cfg.registry.keep_last_k);
            // Versions the current Pareto frontier serves must survive GC:
            // budget routing would otherwise resolve to a deleted theta.
            let pins = frontier_pins(registry)?;
            let removed = registry.gc_with_pins(keep, &pins)?;
            for r in &removed {
                println!("removed {} v{}", r.key.label(), r.version);
            }
            println!(
                "gc: removed {} artifact(s), keep-last-{keep}, {} frontier-pinned",
                removed.len(),
                pins.len()
            );
            Ok(())
        }
        other => bail!("unknown registry subcommand {other:?} (list|show|gc)"),
    }
}

const HELP: &str = r#"repro — Bespoke Solvers for Generative Flow Models (ICLR 2024 reproduction)

USAGE:
    repro <command> [flags]

COMMANDS:
    list                          show models in the artifact manifest
    sample                        generate samples through the coordinator
        --model M  --solver SPEC  --n N  --seed S  [--out samples.json]
        [--budget B]              budget-aware routing instead of --solver:
                                  nfe_max=N | latency_ms=X | rmse<=X
                                  (resolved against the Pareto frontier)
        [--traj [--every K]]      stream the trajectory step by step
    train-bespoke                 train a Bespoke solver (Algorithm 2)
        --model M  [--base rk1|rk2]  --n STEPS  [--iters I]
        [--ablation full|time-only|scale-only]  [--out theta.json]
        [--family stationary|bns|multistep]   solver family (DESIGN.md §11):
                                  bns = per-step coefficients, multistep =
                                  learned history reuse (closed-form trainer,
                                  no loss-grad artifact needed; multistep
                                  takes [--window W], base rk1, full only)
        [--register]              register the artifact in the registry
                                  (a *.meta.json sidecar is always written)
    eval                          evaluate a solver spec vs the GT solver
        --model M  --solver SPEC  [--samples N]
    eval run                      sweep a solver and register the scorecard
        --model M  --solver SPEC  [--grid 2,4,8]  [--seed S]
                                  (rk/transfer templates sweep n over the
                                   grid; bespoke/dopri5 measure as-is)
    eval frontier --model M       print the model's Pareto frontier JSON
                                  (artifact-free; reads the registry only)
    serve                         start the JSONL sampling + training server
        [--addr HOST:PORT]        (commands: sample, sample_traj, list,
                                   metrics, metrics_prom, trace, ping,
                                   train, job_status, jobs, evaluate,
                                   eval_status, frontier, cancel_job,
                                   profile, alerts, reload, drain —
                                   one JSON object per line)
                                  daemon lifecycle (DESIGN.md §12):
                                  SIGTERM/SIGINT drain gracefully (in-flight
                                  work finishes, interrupted jobs persist
                                  and resume on restart), SIGHUP hot-reloads
                                  --config ([serve]/[quality]/[registry]);
                                  [schedule] tick_ms/refresh_secs/gc enables
                                  periodic scorecard refresh + registry GC;
                                  [schedule] sentinel_secs/sentinel_rows/
                                  sentinel_seed/sentinel_tol adds the
                                  quality-drift sentinel (fixed-seed probe
                                  per served route, alerts on digest drift
                                  or post-hot-swap frontier regression)
    jobs cancel <id>              cancel a queued or running server job
        [--kind train|eval]       (running train jobs checkpoint and resume
                                   bitwise on resubmit; default kind train)
    jobs list                     list the server's jobs over TCP
    server reload|drain|ping      operate a running server over TCP
                                  (reload re-reads --config atomically;
                                   drain begins a graceful shutdown)
    server trace                  fetch request spans from a running server
        [--id N]  [--limit 256]   (--id filters one request and lists its
                                   fusion peers; spans cover accept →
                                   enqueue → fuse_launch → solve → scatter
                                   → respond plus job-plane stages)
    server metrics                fetch live metrics over TCP
        [--format json|prom]      (prom prints the Prometheus text
                                   exposition body to stdout)
    server profile                numerical-plane summary over TCP: probe/
                                  guard flags, quarantine count, kernel
                                  phase timing shares (stack_rng/model_eval/
                                  tensor_ops/scatter), flight-recorder
                                  per-step digest (DESIGN.md §14)
    server alerts [--clear]       structured alert ring over TCP (sentinel
                                  digest drift, frontier regressions,
                                  numeric quarantines); --clear drains the
                                  active list, totals survive
    loadgen                       deterministic multi-client load harness:
        --model M  [--solver S[,S2...]]  [--clients 8]  [--requests 32]
        [--n 8[,1,...]]  [--seed S]  [--smoke]  [--out BENCH_5.json]
                                  replays a seeded schedule with fusion on
                                  and with fuse_max_rows=1, checks the runs
                                  are byte-identical, and records the
                                  throughput/latency comparison + speedup
                                  to BENCH_5.json (works artifact-free on
                                  the fixture zoo: --artifacts
                                  rust/tests/fixtures/zoo)
        [--chaos]                 lifecycle chaos instead: hot reloads and a
        [--reloads 8]             mid-storm TCP drain, digest-verified
        [--drain-after-ms 100]    against a golden run (every request must
                                  end byte-correct or coded `draining`;
                                  zero silent drops) — writes BENCH_7.json
    bench-families                train tiny bns + multistep artifacts and
        --model M  [--n 4]        bench RMSE-at-NFE + wall-time percentiles
        [--repeats 5]  [--iters I]  [--out BENCH_6.json]
                                  vs stationary base-RK and ab baselines
                                  (artifact-free on the fixture zoo)
    bench-obs                     observability-overhead A/B: identical
        --model M  [--solver S]   loadgen storms with the span tracer on vs
        [--clients 8]  [--requests 32]  [--repeats 3]  [--seed S]
        [--smoke]  [--out BENCH_8.json]  [--out9 BENCH_9.json]
                                  off (BENCH_8), then with the numerical
                                  plane (step probe + NaN guard + phase
                                  timers) on vs off (BENCH_9); gates each
                                  plane's overhead <= 3% (best-of-repeats)
                                  and bitwise-identical sample bytes
    registry list                 show registered solver artifacts
    registry show                 inspect one key (integrity-checked)
        --model M  --n STEPS  [--base B]  [--ablation A]
        [--family stationary|bns|multistep]
    registry gc [--keep K]        drop old versions (keeps last K + best +
                                  every version on the Pareto frontier)
    exp <id>|all                  reproduce a paper table/figure (out/reports/)

SOLVER SPECS (typed, strictly parsed — unknown keys are errors):
    rk1:n=10                      fixed-grid Euler, uniform grid
    rk2:n=5   rk4:n=3             midpoint / classic RK4
    rk2:n=5:grid=edm|logsnr|cosine    warped time grids
    rk1-target:n=5:sched=vp       scheduler-transfer (DDIM/DPM/EDM analog)
    rk2-target:n=5:sched=vp|edm|ot|cs
    dopri5:tol=1e-5               adaptive GT solver (tol sets rtol+atol)
    dopri5:rtol=1e-6:atol=1e-8:max_steps=100000   ...or independently
    bespoke:path=out/thetas/theta_checker2-ot_rk2_n8.json
                                  (serves whatever family the checkpoint
                                   declares: stationary, bns or multistep)
    bespoke:model=checker2-ot:n=8 best registered artifact for (model, n),
        [:base=rk1|rk2] [:ablation=A]   any family (hot-swaps as training
                                         jobs finish)
    bns:path=theta.json           BNS per-step-coefficient solver (family-
                                  checked: the checkpoint must be bns)
    bns:model=checker2-ot:n=8     best registered *bns* artifact
        [:base=rk1|rk2] [:ablation=A]
    multistep:path=theta.json     learned-multistep solver (window comes
                                  from the checkpoint; family-checked)
    multistep:model=checker2-ot:n=8  best registered *multistep* artifact
        [:ablation=A]
    ab:n=8                        training-free Adams–Bashforth history
        [:base=rk1|rk2|rk4] [:order=1..4]   reuse (defaults base=rk2,
                                             order=2; base RK warm-up)

GLOBAL FLAGS:
    --config file.json   --artifacts dir
    --registry DIR       artifact registry root (default out/registry;
                         config: [registry] root/max_jobs/keep_last_k/
                         max_pending/retry_max_attempts/retry_base_ms/
                         retry_cap_ms, [quality] grid/eval_batches/
                         max_eval_jobs/max_pending, [serve] idle_timeout_ms/
                         drain_grace_ms, [schedule] tick_ms/refresh_secs/gc/
                         sentinel_secs/sentinel_rows/sentinel_seed/
                         sentinel_tol, [obs] trace/trace_ring/trace_sample_n/
                         event_log/event_log_max_bytes/probe/guard/phases —
                         span tracing + JSONL lifecycle event sink with size
                         rotation; probe = solver flight recorder, guard =
                         NaN/Inf quarantine, phases = kernel phase timers,
                         all default off and bitwise-invisible when off)
    --threads N          compute threads for host kernels (0 = auto;
                         also: BESPOKE_THREADS env, serve.compute_threads)
    --workers N          worker threads per (model, solver) serving route
                         (serve.workers_per_route)
    --fuse-window-us U   cross-request fusion gather window in microseconds
                         (serve.fuse_window_us, default 5000; legacy config
                         alias: max_wait_ms — milliseconds x1000)
    --fuse-max-rows R    max rows fused into one lockstep solve (clamped to
                         max_batch and the model batch; 0 = auto, 1 = off —
                         serve.fuse_max_rows; dopri5 never fuses)
    --backend B          compute backend serving models: auto | hlo |
                         analytic (serve.backend, default auto = compiled
                         HLO when the artifact exists, else the pure-Rust
                         oracle for ideal models with a backend_fallback
                         event; per-model overrides via config
                         [serve] backend_overrides = {"model": "hlo"};
                         resolved backend lands in scorecard rows, the
                         metrics snapshot and `profile` output)
"#;
