//! Host-side f32 tensor: a flat buffer + shape, with the small set of
//! numerics the coordinator needs (elementwise ops, axpy, reductions, batch
//! statistics). This is deliberately not a BLAS — the heavy math runs inside
//! the AOT'd HLO executables; the host side only stitches solver steps
//! together and computes metrics.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} does not match data length {}", shape, data.len());
        }
        Ok(Tensor { data, shape })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Tensor> {
        if rows.is_empty() {
            bail!("from_rows: empty");
        }
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            if r.len() != d {
                bail!("ragged rows");
            }
            data.extend_from_slice(r);
        }
        Tensor::new(data, vec![rows.len(), d])
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D [B, d] tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs a 2-D tensor");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs a 2-D tensor");
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.cols();
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.cols();
        &mut self.data[i * d..(i + 1) * d]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} size mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // ---- elementwise -----------------------------------------------------

    fn check_same_shape(&self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(())
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Ok(Tensor { data, shape: self.shape.clone() })
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Ok(Tensor { data, shape: self.shape.clone() })
    }

    pub fn scale(&self, c: f32) -> Tensor {
        Tensor { data: self.data.iter().map(|a| a * c).collect(), shape: self.shape.clone() }
    }

    /// self += c * other  (the hot per-step update; in-place, no alloc).
    pub fn axpy(&mut self, c: f32, other: &Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += c * b;
        }
        Ok(())
    }

    /// self = a * self + c * other (in-place scaled blend).
    pub fn scale_axpy(&mut self, a: f32, c: f32, other: &Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        for (x, b) in self.data.iter_mut().zip(&other.data) {
            *x = a * *x + c * b;
        }
        Ok(())
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { data: self.data.iter().map(|&x| f(x)).collect(), shape: self.shape.clone() }
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    // ---- reductions ------------------------------------------------------

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Max |x|.
    pub fn linf(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// RMS over all elements: the paper's ||x|| = sqrt(mean_i x_i^2).
    pub fn rms(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / self.data.len() as f64)
            .sqrt() as f32
    }

    /// Per-row RMS for a [B, d] tensor: the per-sample truncation-error norm.
    pub fn row_rms(&self) -> Vec<f32> {
        let (b, d) = (self.rows(), self.cols());
        (0..b)
            .map(|i| {
                let r = self.row(i);
                (r.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / d as f64).sqrt() as f32
            })
            .collect()
    }

    /// Column means of a [B, d] tensor.
    pub fn mean_axis0(&self) -> Vec<f32> {
        let (b, d) = (self.rows(), self.cols());
        let mut out = vec![0.0f64; d];
        for i in 0..b {
            for (j, v) in self.row(i).iter().enumerate() {
                out[j] += *v as f64;
            }
        }
        out.iter().map(|x| (x / b as f64) as f32).collect()
    }

    /// Sample covariance (d x d, row-major) of a [B, d] tensor.
    pub fn covariance(&self) -> Vec<f64> {
        let (b, d) = (self.rows(), self.cols());
        let mu: Vec<f64> = self.mean_axis0().iter().map(|&x| x as f64).collect();
        let mut cov = vec![0.0f64; d * d];
        for i in 0..b {
            let r = self.row(i);
            for p in 0..d {
                let dp = r[p] as f64 - mu[p];
                for q in p..d {
                    let dq = r[q] as f64 - mu[q];
                    cov[p * d + q] += dp * dq;
                }
            }
        }
        let denom = (b.max(2) - 1) as f64;
        for p in 0..d {
            for q in p..d {
                cov[p * d + q] /= denom;
                cov[q * d + p] = cov[p * d + q];
            }
        }
        cov
    }

    /// Concatenate 2-D tensors along axis 0.
    pub fn concat_rows(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("concat_rows: empty");
        }
        let d = parts[0].cols();
        let mut data = Vec::new();
        let mut b = 0;
        for p in parts {
            if p.cols() != d {
                bail!("concat_rows: column mismatch");
            }
            data.extend_from_slice(p.data());
            b += p.rows();
        }
        Tensor::new(data, vec![b, d])
    }

    /// Take a subset of rows.
    pub fn take_rows(&self, idx: &[usize]) -> Tensor {
        let d = self.cols();
        let mut data = Vec::with_capacity(idx.len() * d);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor { data, shape: vec![idx.len(), d] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: &[&[f32]]) -> Tensor {
        Tensor::from_rows(&rows.iter().map(|r| r.to_vec()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn construction_and_shape_checks() {
        assert!(Tensor::new(vec![1.0, 2.0], vec![3]).is_err());
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn elementwise_ops() {
        let a = t2(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = t2(&[&[0.5, 0.5], &[1.0, 1.0]]);
        assert_eq!(a.add(&b).unwrap().data(), &[1.5, 2.5, 4.0, 5.0]);
        assert_eq!(a.sub(&b).unwrap().data(), &[0.5, 1.5, 2.0, 3.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b).unwrap();
        assert_eq!(c.data(), &[2.0, 3.0, 5.0, 6.0]);
        let mut d = a.clone();
        d.scale_axpy(0.5, 1.0, &b).unwrap();
        assert_eq!(d.data(), &[1.0, 1.5, 2.5, 3.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[4]);
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn rms_matches_paper_norm() {
        // ||x|| = sqrt(1/d sum x_i^2): for [3, 4] -> sqrt((9+16)/2)
        let t = Tensor::new(vec![3.0, 4.0], vec![1, 2]).unwrap();
        assert!((t.rms() - (12.5f32).sqrt()).abs() < 1e-6);
        assert_eq!(t.row_rms().len(), 1);
    }

    #[test]
    fn mean_and_covariance() {
        let t = t2(&[&[1.0, 0.0], &[3.0, 0.0], &[2.0, 6.0], &[2.0, -6.0]]);
        assert_eq!(t.mean_axis0(), vec![2.0, 0.0]);
        let cov = t.covariance();
        // var(x) = (1+1+0+0)/3, var(y) = 72/3 = 24, cov = 0
        assert!((cov[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((cov[3] - 24.0).abs() < 1e-9);
        assert!(cov[1].abs() < 1e-9);
    }

    #[test]
    fn concat_and_take_rows() {
        let a = t2(&[&[1.0, 2.0]]);
        let b = t2(&[&[3.0, 4.0], &[5.0, 6.0]]);
        let c = Tensor::concat_rows(&[&a, &b]).unwrap();
        assert_eq!(c.rows(), 3);
        let sub = c.take_rows(&[2, 0]);
        assert_eq!(sub.data(), &[5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[4]);
        assert!(t.clone().reshape(&[2, 2]).is_ok());
        assert!(t.reshape(&[3]).is_err());
    }
}
