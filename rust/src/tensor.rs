//! Host-side f32 tensor: a flat buffer + shape, with the small set of
//! numerics the coordinator needs (elementwise ops, axpy, reductions, batch
//! statistics). This is deliberately not a BLAS — the heavy math runs inside
//! the AOT'd HLO executables; the host side only stitches solver steps
//! together and computes metrics.

use anyhow::{bail, Result};

/// Row granularity of the chunked reductions ([`Tensor::mean_axis0`],
/// [`Tensor::covariance`]). Partial sums are computed per fixed-size chunk
/// and combined in chunk order, so the result is **bitwise identical for
/// every thread count** (including 1) — only wall-clock changes. Inputs of
/// up to this many rows reduce in a single chunk, i.e. plain serial order.
pub const PAR_CHUNK_ROWS: usize = 256;

/// Lane width of the chunked elementwise kernels. Eight f32 lanes is one
/// AVX2 register (f32x8) and two NEON registers; the fixed-trip inner loops
/// below are written so LLVM proves them in-bounds and autovectorizes.
pub const LANES: usize = 8;

// ---- vectorized kernel helpers -----------------------------------------
//
// Every hot elementwise op runs through these chunked loops: the body walks
// `LANES`-wide sub-slices with a fixed-trip, bounds-check-free inner loop
// (the f32x8 shape the autovectorizer wants), and a scalar tail handles
// `len % LANES`. Each output element computes exactly the same expression
// as the scalar spelling, so the chunking is bitwise neutral — elementwise
// kernels have no cross-lane reduction to reorder (DESIGN.md §15).

/// In-place binary kernel: `f(&mut a[i], b[i])` for all i.
#[inline]
fn kernel2_mut(a: &mut [f32], b: &[f32], f: impl Fn(&mut f32, f32) + Copy) {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact_mut(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xs, ys) in ca.by_ref().zip(cb.by_ref()) {
        for i in 0..LANES {
            f(&mut xs[i], ys[i]);
        }
    }
    for (x, &y) in ca.into_remainder().iter_mut().zip(cb.remainder()) {
        f(x, y);
    }
}

/// Out-of-place unary kernel: `out[i] = f(a[i])` for all i.
#[inline]
fn kernel1_into(out: &mut [f32], a: &[f32], f: impl Fn(f32) -> f32 + Copy) {
    debug_assert_eq!(out.len(), a.len());
    let mut co = out.chunks_exact_mut(LANES);
    let mut ca = a.chunks_exact(LANES);
    for (os, xs) in co.by_ref().zip(ca.by_ref()) {
        for i in 0..LANES {
            os[i] = f(xs[i]);
        }
    }
    for (o, &x) in co.into_remainder().iter_mut().zip(ca.remainder()) {
        *o = f(x);
    }
}

/// Out-of-place binary kernel: `out[i] = f(a[i], b[i])` for all i.
#[inline]
fn kernel2_into(out: &mut [f32], a: &[f32], b: &[f32], f: impl Fn(f32, f32) -> f32 + Copy) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let mut co = out.chunks_exact_mut(LANES);
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for ((os, xs), ys) in co.by_ref().zip(ca.by_ref()).zip(cb.by_ref()) {
        for i in 0..LANES {
            os[i] = f(xs[i], ys[i]);
        }
    }
    for ((o, &x), &y) in co.into_remainder().iter_mut().zip(ca.remainder()).zip(cb.remainder()) {
        *o = f(x, y);
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

/// Run `f(chunk_index)` for `nchunks` chunks on up to `nt` threads and
/// return the results in chunk order. Chunks are assigned round-robin
/// (thread `ti` takes chunks `ti, ti + nt, ...`), so scheduling never
/// affects which chunk computes what; callers combine the returned partials
/// in index order, making the reduction deterministic in the thread count.
fn run_chunked<T: Send>(nchunks: usize, nt: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let nt = nt.max(1).min(nchunks.max(1));
    if nt <= 1 {
        return (0..nchunks).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..nchunks).map(|_| None).collect();
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = (0..nt)
            .map(|ti| {
                s.spawn(move || {
                    let mut got = Vec::new();
                    let mut ci = ti;
                    while ci < nchunks {
                        got.push((ci, f(ci)));
                        ci += nt;
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            for (ci, v) in h.join().expect("chunk worker panicked") {
                out[ci] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("chunk not computed")).collect()
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} does not match data length {}", shape, data.len());
        }
        Ok(Tensor { data, shape })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Tensor> {
        if rows.is_empty() {
            bail!("from_rows: empty");
        }
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            if r.len() != d {
                bail!("ragged rows");
            }
            data.extend_from_slice(r);
        }
        Tensor::new(data, vec![rows.len(), d])
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D [B, d] tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs a 2-D tensor");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs a 2-D tensor");
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.cols();
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.cols();
        &mut self.data[i * d..(i + 1) * d]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} size mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // ---- elementwise -----------------------------------------------------

    fn check_same_shape(&self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(())
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Ok(Tensor { data, shape: self.shape.clone() })
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Ok(Tensor { data, shape: self.shape.clone() })
    }

    pub fn scale(&self, c: f32) -> Tensor {
        Tensor { data: self.data.iter().map(|a| a * c).collect(), shape: self.shape.clone() }
    }

    /// self += c * other  (the hot per-step update; in-place, no alloc,
    /// f32x8-chunked — see the kernel helpers above).
    pub fn axpy(&mut self, c: f32, other: &Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        kernel2_mut(&mut self.data, &other.data, |a, b| *a += c * b);
        Ok(())
    }

    /// self = a * self + c * other (in-place scaled blend, f32x8-chunked).
    pub fn scale_axpy(&mut self, a: f32, c: f32, other: &Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        kernel2_mut(&mut self.data, &other.data, |x, b| *x = a * *x + c * b);
        Ok(())
    }

    // ---- allocation-free variants (the solver hot path) ------------------
    //
    // `*_into` ops write into a caller-owned tensor of the same shape and
    // compute element-for-element the same expressions as their allocating
    // counterparts, so swapping one for the other is bitwise neutral.
    // (The solver loops mostly reach for the fused `axpy`/`scale_axpy`/
    // `scale_into` forms; `add_into`/`sub_into` complete the in-place kit
    // for callers whose update is a plain sum/difference.)

    /// out = self + other, without allocating (f32x8-chunked).
    pub fn add_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        self.check_same_shape(out)?;
        kernel2_into(&mut out.data, &self.data, &other.data, |a, b| a + b);
        Ok(())
    }

    /// out = self - other, without allocating (f32x8-chunked).
    pub fn sub_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        self.check_same_shape(out)?;
        kernel2_into(&mut out.data, &self.data, &other.data, |a, b| a - b);
        Ok(())
    }

    /// out = c * self, without allocating (f32x8-chunked).
    pub fn scale_into(&self, c: f32, out: &mut Tensor) -> Result<()> {
        self.check_same_shape(out)?;
        kernel1_into(&mut out.data, &self.data, |a| a * c);
        Ok(())
    }

    /// self = src (elementwise copy; shapes must already match).
    pub fn copy_from(&mut self, src: &Tensor) -> Result<()> {
        self.check_same_shape(src)?;
        self.data.copy_from_slice(&src.data);
        Ok(())
    }

    /// Set every element to `v` (no allocation; `slice::fill` lowers to a
    /// vectorized splat/memset).
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { data: self.data.iter().map(|&x| f(x)).collect(), shape: self.shape.clone() }
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    // ---- reductions ------------------------------------------------------

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Max |x|.
    pub fn linf(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// RMS over all elements: the paper's ||x|| = sqrt(mean_i x_i^2).
    pub fn rms(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / self.data.len() as f64)
            .sqrt() as f32
    }

    /// Per-row RMS for a [B, d] tensor: the per-sample truncation-error norm.
    pub fn row_rms(&self) -> Vec<f32> {
        let (b, d) = (self.rows(), self.cols());
        (0..b)
            .map(|i| {
                let r = self.row(i);
                (r.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / d as f64).sqrt() as f32
            })
            .collect()
    }

    /// Column means of a [B, d] tensor. Parallel over fixed-size row chunks
    /// (see [`PAR_CHUNK_ROWS`]); the result does not depend on the thread
    /// count.
    pub fn mean_axis0(&self) -> Vec<f32> {
        self.mean_axis0_with_threads(crate::util::threads::get())
    }

    /// [`Tensor::mean_axis0`] with an explicit thread count (tests/benches).
    pub fn mean_axis0_with_threads(&self, nt: usize) -> Vec<f32> {
        let b = self.rows();
        let sums = self.chunked_col_sums(nt);
        sums.iter().map(|x| (x / b as f64) as f32).collect()
    }

    /// Per-column f64 sums, reduced over [`PAR_CHUNK_ROWS`]-row chunks in
    /// chunk order — identical for every `nt`.
    fn chunked_col_sums(&self, nt: usize) -> Vec<f64> {
        let (b, d) = (self.rows(), self.cols());
        let nchunks = b.div_ceil(PAR_CHUNK_ROWS).max(1);
        let partials = run_chunked(nchunks, nt, |ci| {
            let lo = ci * PAR_CHUNK_ROWS;
            let hi = (lo + PAR_CHUNK_ROWS).min(b);
            // Column sums are elementwise across j (no cross-column
            // reduction), so the zip loop autovectorizes; the per-column
            // f64 row order is unchanged, keeping the result bitwise
            // stable against the scalar spelling.
            let mut acc = vec![0.0f64; d];
            for i in lo..hi {
                for (a, &v) in acc.iter_mut().zip(self.row(i)) {
                    *a += v as f64;
                }
            }
            acc
        });
        let mut out = vec![0.0f64; d];
        for p in partials {
            for (o, v) in out.iter_mut().zip(p) {
                *o += v;
            }
        }
        out
    }

    /// Sample covariance (d x d, row-major) of a [B, d] tensor. Parallel
    /// over fixed-size row chunks; the result does not depend on the thread
    /// count (partials combine in chunk order).
    pub fn covariance(&self) -> Vec<f64> {
        self.covariance_with_threads(crate::util::threads::get())
    }

    /// [`Tensor::covariance`] with an explicit thread count (tests/benches).
    pub fn covariance_with_threads(&self, nt: usize) -> Vec<f64> {
        let (b, d) = (self.rows(), self.cols());
        let mu: Vec<f64> = self.mean_axis0_with_threads(nt).iter().map(|&x| x as f64).collect();
        let nchunks = b.div_ceil(PAR_CHUNK_ROWS).max(1);
        let mu_ref = &mu;
        let partials = run_chunked(nchunks, nt, |ci| {
            let lo = ci * PAR_CHUNK_ROWS;
            let hi = (lo + PAR_CHUNK_ROWS).min(b);
            // Center each row into an f64 scratch once, then accumulate
            // the upper triangle with contiguous inner loops: for fixed p
            // the q-loop is elementwise over `acc[p*d+p..]`/`c[p..]`, so it
            // autovectorizes. Every acc element still adds the same dp*dq
            // terms in the same row order as the scalar spelling — bitwise
            // identical for every thread count.
            let mut acc = vec![0.0f64; d * d];
            let mut c = vec![0.0f64; d];
            for i in lo..hi {
                for ((cj, &v), &m) in c.iter_mut().zip(self.row(i)).zip(mu_ref.iter()) {
                    *cj = v as f64 - m;
                }
                for p in 0..d {
                    let cp = c[p];
                    let arow = &mut acc[p * d + p..p * d + d];
                    for (a, &cq) in arow.iter_mut().zip(&c[p..]) {
                        *a += cp * cq;
                    }
                }
            }
            acc
        });
        let mut cov = vec![0.0f64; d * d];
        for part in partials {
            for (o, v) in cov.iter_mut().zip(part) {
                *o += v;
            }
        }
        let denom = (b.max(2) - 1) as f64;
        for p in 0..d {
            for q in p..d {
                cov[p * d + q] /= denom;
                cov[q * d + p] = cov[p * d + q];
            }
        }
        cov
    }

    /// Concatenate 2-D tensors along axis 0.
    pub fn concat_rows(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("concat_rows: empty");
        }
        let d = parts[0].cols();
        let mut data = Vec::new();
        let mut b = 0;
        for p in parts {
            if p.cols() != d {
                bail!("concat_rows: column mismatch");
            }
            data.extend_from_slice(p.data());
            b += p.rows();
        }
        Tensor::new(data, vec![b, d])
    }

    /// Take a subset of rows.
    pub fn take_rows(&self, idx: &[usize]) -> Tensor {
        let d = self.cols();
        let mut data = Vec::with_capacity(idx.len() * d);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor { data, shape: vec![idx.len(), d] }
    }

    // ---- row-slice stack/scatter ------------------------------------------
    //
    // The reference spellings of the fusion plane's batch layout
    // (DESIGN.md §10): `stack_rows` reproduces exactly the zero-padded
    // gather the coordinator's `stack_noise` fills in place on the hot
    // path, and `rows_block`/`copy_row_block` are the scatter inverses the
    // equivalence tests slice fused results with.

    /// Stack 2-D tensors along axis 0 into a `[rows, d]` tensor, zero-
    /// padding the tail — each part one request's rows, the padding rows
    /// discarded after a solve. Errors if the parts exceed `rows` or
    /// disagree on columns.
    pub fn stack_rows(parts: &[&Tensor], rows: usize) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack_rows: empty");
        }
        let d = parts[0].cols();
        let mut out = Tensor::zeros(&[rows, d]);
        let mut at = 0usize;
        for p in parts {
            if p.cols() != d {
                bail!("stack_rows: column mismatch ({} vs {d})", p.cols());
            }
            if at + p.rows() > rows {
                bail!(
                    "stack_rows: {} total rows exceed the batch capacity {rows}",
                    at + p.rows()
                );
            }
            out.data[at * d..(at + p.rows()) * d].copy_from_slice(p.data());
            at += p.rows();
        }
        Ok(out)
    }

    /// A contiguous row block `[lo, lo + rows)` as an owned `[rows, d]`
    /// tensor — slices one request's rows back out of a stacked solve.
    pub fn rows_block(&self, lo: usize, rows: usize) -> Result<Tensor> {
        let (b, d) = (self.rows(), self.cols());
        if lo + rows > b {
            bail!("rows_block: [{lo}, {}) out of range for {b} rows", lo + rows);
        }
        Tensor::new(self.data[lo * d..(lo + rows) * d].to_vec(), vec![rows, d])
    }

    /// Copy `rows` rows from `src` (starting at `src_lo`) into `self`
    /// starting at `dst_lo`. Both must be 2-D with equal column counts.
    pub fn copy_row_block(
        &mut self,
        dst_lo: usize,
        src: &Tensor,
        src_lo: usize,
        rows: usize,
    ) -> Result<()> {
        let d = self.cols();
        if src.cols() != d {
            bail!("copy_row_block: column mismatch ({} vs {d})", src.cols());
        }
        if src_lo + rows > src.rows() || dst_lo + rows > self.rows() {
            bail!(
                "copy_row_block: [{src_lo}, {}) -> [{dst_lo}, {}) out of range",
                src_lo + rows,
                dst_lo + rows
            );
        }
        self.data[dst_lo * d..(dst_lo + rows) * d]
            .copy_from_slice(&src.data[src_lo * d..(src_lo + rows) * d]);
        Ok(())
    }
}

/// A scratch-buffer pool keyed by shape: the allocation-free backing store
/// for solver stage tensors. A session pre-fills the pool in `begin()`
/// ([`Workspace::preallocate`]); each step [`Workspace::acquire`]s buffers
/// and [`Workspace::release`]s them back, so the steady-state step loop
/// performs **zero heap allocation** (acquire pops a pooled tensor, release
/// pushes within the Vec's retained capacity). Acquired buffers carry
/// whatever bytes the previous user left — callers must fully overwrite
/// them (`copy_from` / `scale_into` / `fill`).
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Tensor>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { pool: Vec::new() }
    }

    /// A pool pre-filled with `count` zero tensors of `shape` (plus slack
    /// capacity so release() never reallocates the pool itself).
    pub fn preallocate(shape: &[usize], count: usize) -> Workspace {
        let mut pool = Vec::with_capacity(count + 2);
        pool.extend((0..count).map(|_| Tensor::zeros(shape)));
        Workspace { pool }
    }

    /// Pop a pooled tensor of `shape`, or allocate one if none matches.
    pub fn acquire(&mut self, shape: &[usize]) -> Tensor {
        match self.pool.iter().rposition(|t| t.shape() == shape) {
            Some(i) => self.pool.swap_remove(i),
            None => Tensor::zeros(shape),
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn release(&mut self, t: Tensor) {
        self.pool.push(t);
    }

    /// Top the pool up to `count` buffers of `shape`, keeping whatever it
    /// already holds (including buffers of *other* shapes). Sessions call
    /// this from `init()` so re-initializing at a new fused batch width
    /// allocates only the missing buffers — alternating widths after the
    /// first visit to each is allocation-free (DESIGN.md §10).
    pub fn ensure(&mut self, shape: &[usize], count: usize) {
        let have = self.pool.iter().filter(|t| t.shape() == shape).count();
        self.pool.reserve(count.saturating_sub(have) + 2);
        for _ in have..count {
            self.pool.push(Tensor::zeros(shape));
        }
    }

    /// Buffers currently sitting in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: &[&[f32]]) -> Tensor {
        Tensor::from_rows(&rows.iter().map(|r| r.to_vec()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn construction_and_shape_checks() {
        assert!(Tensor::new(vec![1.0, 2.0], vec![3]).is_err());
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn elementwise_ops() {
        let a = t2(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = t2(&[&[0.5, 0.5], &[1.0, 1.0]]);
        assert_eq!(a.add(&b).unwrap().data(), &[1.5, 2.5, 4.0, 5.0]);
        assert_eq!(a.sub(&b).unwrap().data(), &[0.5, 1.5, 2.0, 3.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b).unwrap();
        assert_eq!(c.data(), &[2.0, 3.0, 5.0, 6.0]);
        let mut d = a.clone();
        d.scale_axpy(0.5, 1.0, &b).unwrap();
        assert_eq!(d.data(), &[1.0, 1.5, 2.5, 3.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[4]);
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn rms_matches_paper_norm() {
        // ||x|| = sqrt(1/d sum x_i^2): for [3, 4] -> sqrt((9+16)/2)
        let t = Tensor::new(vec![3.0, 4.0], vec![1, 2]).unwrap();
        assert!((t.rms() - (12.5f32).sqrt()).abs() < 1e-6);
        assert_eq!(t.row_rms().len(), 1);
    }

    #[test]
    fn mean_and_covariance() {
        let t = t2(&[&[1.0, 0.0], &[3.0, 0.0], &[2.0, 6.0], &[2.0, -6.0]]);
        assert_eq!(t.mean_axis0(), vec![2.0, 0.0]);
        let cov = t.covariance();
        // var(x) = (1+1+0+0)/3, var(y) = 72/3 = 24, cov = 0
        assert!((cov[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((cov[3] - 24.0).abs() < 1e-9);
        assert!(cov[1].abs() < 1e-9);
    }

    #[test]
    fn concat_and_take_rows() {
        let a = t2(&[&[1.0, 2.0]]);
        let b = t2(&[&[3.0, 4.0], &[5.0, 6.0]]);
        let c = Tensor::concat_rows(&[&a, &b]).unwrap();
        assert_eq!(c.rows(), 3);
        let sub = c.take_rows(&[2, 0]);
        assert_eq!(sub.data(), &[5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[4]);
        assert!(t.clone().reshape(&[2, 2]).is_ok());
        assert!(t.reshape(&[3]).is_err());
    }

    #[test]
    fn into_variants_match_allocating_ops_bitwise() {
        let a = t2(&[&[1.0, 2.5], &[-3.0, 4.0]]);
        let b = t2(&[&[0.5, -0.5], &[1.25, 1.0]]);
        let mut out = Tensor::zeros(&[2, 2]);
        a.add_into(&b, &mut out).unwrap();
        assert_eq!(out.data(), a.add(&b).unwrap().data());
        a.sub_into(&b, &mut out).unwrap();
        assert_eq!(out.data(), a.sub(&b).unwrap().data());
        a.scale_into(0.3, &mut out).unwrap();
        assert_eq!(out.data(), a.scale(0.3).data());
        out.copy_from(&b).unwrap();
        assert_eq!(out.data(), b.data());
        out.fill(7.0);
        assert_eq!(out.data(), &[7.0; 4]);
        // shape mismatches rejected
        let mut bad = Tensor::zeros(&[4]);
        assert!(a.add_into(&b, &mut bad).is_err());
        assert!(a.scale_into(1.0, &mut bad).is_err());
        assert!(bad.copy_from(&a).is_err());
    }

    #[test]
    fn workspace_pools_by_shape() {
        let mut ws = Workspace::preallocate(&[2, 3], 2);
        assert_eq!(ws.pooled(), 2);
        let a = ws.acquire(&[2, 3]);
        let b = ws.acquire(&[2, 3]);
        assert_eq!(ws.pooled(), 0);
        // mismatched shape falls back to a fresh allocation
        let c = ws.acquire(&[4]);
        assert_eq!(c.shape(), &[4]);
        ws.release(a);
        ws.release(b);
        ws.release(c);
        assert_eq!(ws.pooled(), 3);
        // acquire prefers pooled buffers of the right shape
        assert_eq!(ws.acquire(&[4]).shape(), &[4]);
        assert_eq!(ws.pooled(), 2);
    }

    #[test]
    fn stack_and_scatter_row_blocks() {
        let a = t2(&[&[1.0, 2.0]]);
        let b = t2(&[&[3.0, 4.0], &[5.0, 6.0]]);
        // stack with zero padding to 4 rows
        let s = Tensor::stack_rows(&[&a, &b], 4).unwrap();
        assert_eq!(s.shape(), &[4, 2]);
        assert_eq!(s.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0]);
        // scatter blocks back out
        assert_eq!(s.rows_block(0, 1).unwrap().data(), a.data());
        assert_eq!(s.rows_block(1, 2).unwrap().data(), b.data());
        assert!(s.rows_block(3, 2).is_err());
        // overflow and mismatches are rejected
        assert!(Tensor::stack_rows(&[&a, &b], 2).is_err());
        assert!(Tensor::stack_rows(&[], 2).is_err());
        let c = Tensor::zeros(&[1, 3]);
        assert!(Tensor::stack_rows(&[&a, &c], 4).is_err());
        // copy_row_block writes into place
        let mut dst = Tensor::zeros(&[3, 2]);
        dst.copy_row_block(1, &b, 0, 2).unwrap();
        assert_eq!(dst.data(), &[0.0, 0.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(dst.copy_row_block(2, &b, 0, 2).is_err());
        assert!(dst.copy_row_block(0, &c, 0, 1).is_err());
    }

    #[test]
    fn workspace_ensure_tops_up_per_shape() {
        let mut ws = Workspace::new();
        ws.ensure(&[2, 2], 3);
        assert_eq!(ws.pooled(), 3);
        // same shape again: no growth
        ws.ensure(&[2, 2], 3);
        assert_eq!(ws.pooled(), 3);
        // a second shape adds only its own buffers, keeping the first
        ws.ensure(&[4, 2], 2);
        assert_eq!(ws.pooled(), 5);
        assert_eq!(ws.acquire(&[2, 2]).shape(), &[2, 2]);
        assert_eq!(ws.acquire(&[4, 2]).shape(), &[4, 2]);
    }

    #[test]
    fn chunked_kernels_match_scalar_reference_bitwise() {
        // Length exercises full LANES chunks plus a ragged tail; irregular
        // values would expose any per-element expression change. The scalar
        // references here are the pre-vectorization spellings.
        let n = 5 * LANES + 3;
        let mut rng = crate::util::Rng::new(5);
        let a0 = Tensor::new(rng.normal_vec(n), vec![n]).unwrap();
        let b = Tensor::new(rng.normal_vec(n), vec![n]).unwrap();
        let (c, s) = (0.37f32, -1.25f32);

        let mut got = a0.clone();
        got.axpy(c, &b).unwrap();
        let want: Vec<f32> = a0.data().iter().zip(b.data()).map(|(x, y)| x + c * y).collect();
        assert_eq!(got.data(), &want[..], "axpy");

        let mut got = a0.clone();
        got.scale_axpy(s, c, &b).unwrap();
        let want: Vec<f32> = a0.data().iter().zip(b.data()).map(|(x, y)| s * x + c * y).collect();
        assert_eq!(got.data(), &want[..], "scale_axpy");

        let mut out = Tensor::zeros(&[n]);
        a0.add_into(&b, &mut out).unwrap();
        let want: Vec<f32> = a0.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
        assert_eq!(out.data(), &want[..], "add_into");

        a0.sub_into(&b, &mut out).unwrap();
        let want: Vec<f32> = a0.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
        assert_eq!(out.data(), &want[..], "sub_into");

        a0.scale_into(c, &mut out).unwrap();
        let want: Vec<f32> = a0.data().iter().map(|x| x * c).collect();
        assert_eq!(out.data(), &want[..], "scale_into");
    }

    #[test]
    fn chunked_reductions_are_thread_count_invariant() {
        // b > PAR_CHUNK_ROWS with a ragged final chunk; d = 3
        let b = 2 * PAR_CHUNK_ROWS + 37;
        let mut rng = crate::util::Rng::new(11);
        let t = Tensor::new(rng.normal_vec(b * 3), vec![b, 3]).unwrap();
        let mu1 = t.mean_axis0_with_threads(1);
        let cov1 = t.covariance_with_threads(1);
        for nt in [2usize, 3, 7] {
            assert_eq!(t.mean_axis0_with_threads(nt), mu1, "mean nt={nt}");
            assert_eq!(t.covariance_with_threads(nt), cov1, "cov nt={nt}");
        }
    }
}
