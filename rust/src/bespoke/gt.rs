//! Ground-truth trajectory pool.
//!
//! Algorithm 2 resamples a noise batch and re-solves the GT ODE *every*
//! iteration — the paper notes this naive scheme dominates training cost
//! and suggests pre-processing sampling paths. `GtPool` implements both:
//! `pool_batches = 1, refresh_every = 1` is the paper-naive scheme; larger
//! pools amortize the DOPRI5 solves across iterations (§Perf measures the
//! speedup).

use anyhow::Result;

use crate::models::VelocityModel;
use crate::solvers::dopri5::{DenseSolution, Dopri5};
use crate::tensor::Tensor;
use crate::util::Rng;

pub struct GtEntry {
    pub x0: Tensor,
    pub dense: DenseSolution,
}

pub struct GtPool {
    entries: Vec<GtEntry>,
    solver: Dopri5,
    rng: Rng,
    batch: usize,
    dim: usize,
    /// Total model evaluations spent on GT solves (for %time accounting).
    pub gt_nfe: u64,
}

impl GtPool {
    pub fn new(
        model: &dyn VelocityModel,
        pool_batches: usize,
        tol: f64,
        seed: u64,
    ) -> Result<GtPool> {
        let mut pool = GtPool {
            entries: Vec::with_capacity(pool_batches),
            solver: Dopri5 { rtol: tol, atol: tol, max_steps: 100_000 },
            rng: Rng::new(seed),
            batch: model.batch(),
            dim: model.dim(),
            gt_nfe: 0,
        };
        for _ in 0..pool_batches.max(1) {
            let e = pool.solve_fresh(model)?;
            pool.entries.push(e);
        }
        Ok(pool)
    }

    fn solve_fresh(&mut self, model: &dyn VelocityModel) -> Result<GtEntry> {
        let x0 = Tensor::new(
            self.rng.normal_vec(self.batch * self.dim),
            vec![self.batch, self.dim],
        )?;
        let dense = self.solver.solve_model_dense(model, &x0)?;
        self.gt_nfe += dense.nfe as u64;
        Ok(GtEntry { x0, dense })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Pick a random pool entry.
    pub fn pick(&mut self) -> &GtEntry {
        let i = self.rng.below(self.entries.len());
        &self.entries[i]
    }

    /// Replace the oldest entry with a freshly-solved one.
    pub fn refresh_one(&mut self, model: &dyn VelocityModel) -> Result<()> {
        let e = self.solve_fresh(model)?;
        self.entries.remove(0);
        self.entries.push(e);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![1.0, 0.0], vec![-1.0, 0.0]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.1, 4).unwrap()
    }

    #[test]
    fn pool_builds_and_refreshes() {
        let model = toy();
        let mut pool = GtPool::new(&model, 3, 1e-4, 0).unwrap();
        assert_eq!(pool.len(), 3);
        let nfe_before = pool.gt_nfe;
        assert!(nfe_before > 0);
        let first_x0 = pool.entries[0].x0.clone();
        pool.refresh_one(&model).unwrap();
        assert_eq!(pool.len(), 3);
        assert!(pool.gt_nfe > nfe_before);
        assert_ne!(pool.entries[2].x0.data(), first_x0.data());
        // dense endpoints are the GT samples: finite, right shape
        let e = pool.pick();
        assert_eq!(e.dense.final_state().shape(), &[4, 2]);
        assert!(e.dense.final_state().is_finite());
    }
}
