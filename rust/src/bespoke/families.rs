//! Analytic trainers for the non-stationary solver families (DESIGN.md
//! §11): BNS per-step coefficients and the learned-multistep variant.
//!
//! Both families keep the time grid fixed and uniform, so each step's
//! prediction is *linear* in its coefficients and the GT-matching loss
//!
//! ```text
//! L = (1 / (n B d)) sum_i || pred_i(theta) - x*_{i+1} ||^2
//! ```
//!
//! has an exact closed-form gradient — no AOT'd loss-grad executable is
//! needed (unlike the stationary trainer, whose learned time/scale warp
//! makes the loss nonlinear in theta). Snapshots are teacher-forced from
//! the DOPRI5 dense GT solution exactly like `bespoke::trainer`: x*_i is
//! the trajectory at t_i = i/n and the velocities come from the model
//! (`snap_velocity = "model"`) or the Hermite derivative of the dense
//! interpolant (`"hermite"`, the default — zero extra model launches at
//! O(h^2) snapshot error).

use anyhow::{bail, Result};

use super::adam::Adam;
use super::checkpoint::{TrainCheckpoint, TrainCtl, TrainRun};
use super::gt::GtPool;
use super::trainer::{TrainOutcome, TrainPoint, TrainProgress};
use crate::config::TrainConfig;
use crate::eval::rmse;
use crate::models::VelocityModel;
use crate::solvers::bns::{BnsSolver, MultistepSolver};
use crate::solvers::dopri5::Dopri5;
use crate::solvers::theta::{Base, Family, RawTheta};
use crate::solvers::Sampler;
use crate::tensor::Tensor;
use crate::util::{Rng, Timer};
use crate::{log_debug, log_info};

fn dot(a: &Tensor, b: &Tensor) -> f32 {
    a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum()
}

/// Train a non-stationary solver family against `model`'s GT trajectories.
/// `window` is only read for [`Family::Multistep`].
pub fn train_family(
    model: &dyn VelocityModel,
    family: Family,
    base: Base,
    n: usize,
    window: usize,
    cfg: &TrainConfig,
) -> Result<TrainOutcome> {
    train_family_with_progress(model, family, base, n, window, cfg, &mut |_| {})
}

/// [`train_family`] with a per-iteration progress callback (the hook
/// `TrainJobManager` uses for live `job_status`), mirroring
/// `bespoke::trainer::train_with_progress`.
pub fn train_family_with_progress(
    model: &dyn VelocityModel,
    family: Family,
    base: Base,
    n: usize,
    window: usize,
    cfg: &TrainConfig,
    on_progress: &mut dyn FnMut(&TrainProgress),
) -> Result<TrainOutcome> {
    match train_family_with_ctl(model, family, base, n, window, cfg, &TrainCtl::default(), on_progress)?
    {
        TrainRun::Done(out) => Ok(out),
        TrainRun::Cancelled(_) => bail!("uncancellable run reported cancelled"),
    }
}

/// [`train_family_with_progress`] with lifecycle controls (DESIGN.md §12),
/// mirroring `trainer::train_with_ctl`: the cancel token is checked at
/// every iteration boundary, and resume replays the completed iterations'
/// RNG consumption against the seed-rebuilt pool so the continued run is
/// bitwise-identical to an uninterrupted one.
#[allow(clippy::too_many_arguments)]
pub fn train_family_with_ctl(
    model: &dyn VelocityModel,
    family: Family,
    base: Base,
    n: usize,
    window: usize,
    cfg: &TrainConfig,
    ctl: &TrainCtl,
    on_progress: &mut dyn FnMut(&TrainProgress),
) -> Result<TrainRun> {
    if family == Family::Stationary {
        bail!("stationary bespoke trains via bespoke::train (AOT loss-grad path)");
    }
    if cfg.ablation != "full" {
        bail!(
            "family {} has no time/scale split: only ablation=full is supported (got {:?})",
            family.name(),
            cfg.ablation
        );
    }
    let timer = Timer::start();
    let b = model.batch();
    let d = model.dim();
    let p = RawTheta::n_params_for(family, base, n, window)?;
    let mut theta = RawTheta::identity_for(family, base, n, window)?;

    // Multistep: coefficients for history that does not exist yet (j > i,
    // the warm-up steps) are dead at serving time; mask their grads so
    // they stay at their identity init of 0.
    let mask: Option<Vec<f32>> = match family {
        Family::Multistep => {
            let k = 1 + window;
            let mut m = vec![1.0f32; p];
            for i in 0..n {
                for j in 0..window {
                    if j > i {
                        m[k * i + 1 + j] = 0.0;
                    }
                }
            }
            Some(m)
        }
        _ => None,
    };

    let mut opt = Adam::new(p, cfg.lr);
    let mut pool = GtPool::new(model, cfg.pool_batches, cfg.gt_tol, cfg.seed)?;

    // Validation set: fresh noise batches + their GT solutions (same seed
    // split as the stationary trainer).
    let mut vrng = Rng::new(cfg.seed ^ 0x7a11d);
    let gt_solver = Dopri5 { rtol: cfg.gt_tol, atol: cfg.gt_tol, max_steps: 100_000 };
    let mut val: Vec<(Tensor, Tensor)> = Vec::new();
    for _ in 0..cfg.val_batches {
        let x0 = Tensor::new(vrng.normal_vec(b * d), vec![b, d])?;
        let sol = gt_solver.solve_model_dense(model, &x0)?;
        pool.gt_nfe += sol.nfe as u64;
        val.push((x0, sol.final_state().clone()));
    }

    let h = 1.0f32 / n as f32;
    let norm = 2.0 / (n as f32 * (b * d) as f32);
    let use_model_velocity = cfg.snap_velocity == "model";
    let rk2 = base == Base::Rk2;

    let mut best = theta.clone();
    let mut best_val = f32::INFINITY;
    let mut history = Vec::new();
    let mut start_iter = 1usize;
    let mut base_wall = 0.0f64;

    if let Some(ck) = &ctl.resume {
        if ck.iters_total != cfg.iters {
            bail!(
                "checkpoint is for a {}-iteration run, resubmit asked for {}",
                ck.iters_total,
                cfg.iters
            );
        }
        if ck.theta.family != family
            || ck.theta.base != base
            || ck.theta.n != n
            || ck.theta.window != window
            || ck.theta.raw.len() != p
        {
            bail!("checkpoint theta shape does not match (family, base, n, window)");
        }
        if ck.adam_m.len() != p || ck.adam_v.len() != p {
            bail!("checkpoint optimizer state does not match parameter count");
        }
        for iter in 1..=ck.iters_done {
            if cfg.refresh_every > 0 && iter % cfg.refresh_every == 0 {
                pool.refresh_one(model)?;
            }
            let _ = pool.pick();
        }
        theta = ck.theta.clone();
        best = ck.best.clone();
        best_val = ck.best_val_rmse;
        opt = Adam::from_state(cfg.lr, ck.adam_m.clone(), ck.adam_v.clone(), ck.adam_step);
        history = ck.history.clone();
        start_iter = ck.iters_done + 1;
        base_wall = ck.wall_secs;
        log_info!(
            "[train-{} {}] resuming from checkpoint at iter {}/{}",
            family.name(),
            model.name(),
            ck.iters_done,
            cfg.iters
        );
    }

    for iter in start_iter..=cfg.iters {
        if ctl.cancel.is_cancelled() {
            return Ok(TrainRun::Cancelled(TrainCheckpoint {
                iters_done: iter - 1,
                iters_total: cfg.iters,
                theta,
                best,
                best_val_rmse: best_val,
                adam_m: opt.m().to_vec(),
                adam_v: opt.v().to_vec(),
                adam_step: opt.step_count(),
                history,
                wall_secs: base_wall + timer.elapsed_secs(),
            }));
        }
        if cfg.refresh_every > 0 && iter % cfg.refresh_every == 0 {
            pool.refresh_one(model)?;
        }

        // --- teacher-forced snapshots on the fixed uniform grid ----------
        // x*_i = x(t_i); u*_i the matching velocities; for bns-rk2 also
        // the inner-stage velocity at the Euler midpoint ("hermite"
        // substitutes the trajectory derivative at t_i + h/2, an O(h^2)
        // approximation of u(mid, t_i + h/2) since mid deviates from the
        // trajectory by O(h^2)).
        let (xs, us, u2s) = {
            let entry = pool.pick();
            let mut xs = Vec::with_capacity(n + 1);
            for i in 0..=n {
                xs.push(entry.dense.eval(i as f32 * h));
            }
            let mut us = Vec::with_capacity(n);
            for (i, x) in xs.iter().enumerate().take(n) {
                let t = i as f32 * h;
                if use_model_velocity {
                    us.push(model.eval(x, t)?);
                } else {
                    us.push(entry.dense.eval_deriv(t));
                }
            }
            let mut u2s = Vec::new();
            if family == Family::Bns && rk2 {
                for i in 0..n {
                    let t_mid = (i as f32 + 0.5) * h;
                    if use_model_velocity {
                        let mut mid = xs[i].clone();
                        mid.axpy(0.5 * h, &us[i])?;
                        u2s.push(model.eval(&mid, t_mid)?);
                    } else {
                        u2s.push(entry.dense.eval_deriv(t_mid));
                    }
                }
            }
            (xs, us, u2s)
        };

        // --- closed-form loss + gradient ---------------------------------
        //   r_i      = pred_i(theta) - x*_{i+1}
        //   dL/dcoef = (2 / (n B d)) <r_i, d pred_i / d coef>
        let mut grad = vec![0.0f32; p];
        let mut acc = 0.0f32;
        match family {
            Family::Bns => {
                let k = 1 + base.evals_per_step();
                for i in 0..n {
                    let c = &theta.raw[k * i..k * (i + 1)];
                    let mut r = xs[i].scale(c[0]);
                    r.axpy(h * c[1], &us[i])?;
                    if rk2 {
                        r.axpy(h * c[2], &u2s[i])?;
                    }
                    r.axpy(-1.0, &xs[i + 1])?;
                    acc += dot(&r, &r);
                    grad[k * i] = norm * dot(&r, &xs[i]);
                    grad[k * i + 1] = norm * h * dot(&r, &us[i]);
                    if rk2 {
                        grad[k * i + 2] = norm * h * dot(&r, &u2s[i]);
                    }
                }
            }
            Family::Multistep => {
                let k = 1 + window;
                for i in 0..n {
                    let c = &theta.raw[k * i..k * (i + 1)];
                    let mut r = xs[i].scale(c[0]);
                    for j in 0..=i.min(window - 1) {
                        r.axpy(h * c[1 + j], &us[i - j])?;
                    }
                    r.axpy(-1.0, &xs[i + 1])?;
                    acc += dot(&r, &r);
                    grad[k * i] = norm * dot(&r, &xs[i]);
                    for j in 0..=i.min(window - 1) {
                        grad[k * i + 1 + j] = norm * h * dot(&r, &us[i - j]);
                    }
                }
            }
            Family::Stationary => unreachable!(),
        }
        let loss = acc / (n as f32 * (b * d) as f32);

        opt.update(&mut theta.raw, &grad, mask.as_deref());

        // --- validation ---------------------------------------------------
        let mut val_rmse = f32::NAN;
        if iter % cfg.val_every == 0 || iter == cfg.iters {
            let sampler: Box<dyn Sampler> = match family {
                Family::Bns => Box::new(BnsSolver::new(&theta)?),
                Family::Multistep => Box::new(MultistepSolver::new(&theta)?),
                Family::Stationary => unreachable!(),
            };
            let mut accv = 0.0f32;
            for (x0, gt) in &val {
                let out = sampler.sample(model, x0)?;
                accv += rmse(&out, gt);
            }
            val_rmse = accv / val.len() as f32;
            if val_rmse < best_val {
                best_val = val_rmse;
                best = theta.clone();
            }
            log_info!(
                "[train-{} {} {} n={}] iter {:4} loss {:.5} val_rmse {:.5}",
                family.name(),
                model.name(),
                base.name(),
                n,
                iter,
                loss,
                val_rmse
            );
        } else {
            log_debug!("[train-{}] iter {iter} loss {loss:.5}", family.name());
        }
        history.push(TrainPoint { iter, loss, val_rmse });
        on_progress(&TrainProgress { iter, iters_total: cfg.iters, loss, val_rmse });
    }

    Ok(TrainRun::Done(TrainOutcome {
        best,
        best_val_rmse: best_val,
        last: theta,
        history,
        gt_nfe: pool.gt_nfe,
        wall_secs: base_wall + timer.elapsed_secs(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticModel;
    use crate::schedulers::Scheduler;

    fn toy() -> AnalyticModel {
        let pts = Tensor::from_rows(&[vec![0.9, 0.1], vec![-0.7, -0.5], vec![0.2, 1.1]]).unwrap();
        AnalyticModel::new("toy", pts, Scheduler::CondOt, 0.08, 8).unwrap()
    }

    fn quick_cfg(iters: usize) -> TrainConfig {
        TrainConfig {
            iters,
            lr: 0.02,
            pool_batches: 2,
            val_batches: 1,
            val_every: 25,
            ..TrainConfig::default()
        }
    }

    /// RMSE of a sampler on fresh GT batches (identity baseline metric).
    fn eval_rmse(model: &AnalyticModel, sampler: &dyn Sampler, seed: u64) -> f32 {
        let gt = Dopri5 { rtol: 1e-5, atol: 1e-5, max_steps: 100_000 };
        let mut rng = Rng::new(seed);
        let x0 = Tensor::new(rng.normal_vec(8 * 2), vec![8, 2]).unwrap();
        let sol = gt.solve_model_dense(model, &x0).unwrap();
        let out = sampler.sample(model, &x0).unwrap();
        rmse(&out, sol.final_state())
    }

    #[test]
    fn bns_training_beats_identity() {
        let model = toy();
        for base in [Base::Rk1, Base::Rk2] {
            let out =
                train_family(&model, Family::Bns, base, 4, 0, &quick_cfg(150)).unwrap();
            assert!(out.best_val_rmse.is_finite());
            assert_eq!(out.history.len(), 150);
            let identity = RawTheta::identity_for(Family::Bns, base, 4, 0).unwrap();
            let id_rmse =
                eval_rmse(&model, &BnsSolver::new(&identity).unwrap(), 77);
            let tr_rmse =
                eval_rmse(&model, &BnsSolver::new(&out.best).unwrap(), 77);
            assert!(
                tr_rmse < id_rmse,
                "{base:?}: trained {tr_rmse} not better than identity {id_rmse}"
            );
        }
    }

    #[test]
    fn multistep_training_beats_identity_and_masks_warmup() {
        let model = toy();
        let (n, window) = (4usize, 3usize);
        let out =
            train_family(&model, Family::Multistep, Base::Rk1, n, window, &quick_cfg(150))
                .unwrap();
        let identity = RawTheta::identity_for(Family::Multistep, Base::Rk1, n, window).unwrap();
        let id_rmse = eval_rmse(&model, &MultistepSolver::new(&identity).unwrap(), 78);
        let tr_rmse = eval_rmse(&model, &MultistepSolver::new(&out.best).unwrap(), 78);
        assert!(tr_rmse < id_rmse, "trained {tr_rmse} not better than identity {id_rmse}");
        // warm-up coefficients (j > i) must never move off their 0 init
        let k = 1 + window;
        for i in 0..n {
            for j in 0..window {
                if j > i {
                    assert_eq!(out.last.raw[k * i + 1 + j], 0.0, "step {i} coeff j={j} moved");
                }
            }
        }
    }

    #[test]
    fn cancel_resume_is_bitwise_identical() {
        use crate::json::Value;
        use crate::util::CancelToken;

        let model = toy();
        let cfg = quick_cfg(40);
        let golden = train_family(&model, Family::Bns, Base::Rk2, 4, 0, &cfg).unwrap();

        // Cancel at iteration 17 via the progress hook; the trainer must
        // stop at the next iteration boundary with a checkpoint.
        let cancel = CancelToken::new();
        let hook = cancel.clone();
        let run = train_family_with_ctl(
            &model,
            Family::Bns,
            Base::Rk2,
            4,
            0,
            &cfg,
            &TrainCtl { cancel, resume: None },
            &mut |p| {
                if p.iter == 17 {
                    hook.cancel();
                }
            },
        )
        .unwrap();
        let ck = match run {
            TrainRun::Cancelled(ck) => ck,
            TrainRun::Done(_) => panic!("run was cancelled but completed"),
        };
        assert_eq!(ck.iters_done, 17);

        // Round-trip through the persisted JSON form: resume must work
        // from what lands on disk, not from in-memory state.
        let ck = TrainCheckpoint::from_json(
            &Value::parse(&ck.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        let resumed = match train_family_with_ctl(
            &model,
            Family::Bns,
            Base::Rk2,
            4,
            0,
            &cfg,
            &TrainCtl { cancel: CancelToken::new(), resume: Some(ck) },
            &mut |_| {},
        )
        .unwrap()
        {
            TrainRun::Done(out) => out,
            TrainRun::Cancelled(_) => panic!("resumed run was not cancelled"),
        };
        assert_eq!(resumed.last.raw, golden.last.raw, "last theta must be bitwise-equal");
        assert_eq!(resumed.best.raw, golden.best.raw, "best theta must be bitwise-equal");
        assert_eq!(resumed.best_val_rmse.to_bits(), golden.best_val_rmse.to_bits());
        assert_eq!(resumed.history.len(), golden.history.len());
        assert_eq!(resumed.gt_nfe, golden.gt_nfe, "replay must reproduce GT-path NFE");
    }

    #[test]
    fn rejects_stationary_and_ablations() {
        let model = toy();
        assert!(train_family(&model, Family::Stationary, Base::Rk2, 4, 0, &quick_cfg(1))
            .is_err());
        let cfg = TrainConfig { ablation: "time-only".into(), ..quick_cfg(1) };
        assert!(train_family(&model, Family::Bns, Base::Rk2, 4, 0, &cfg).is_err());
    }
}
