//! Bespoke training (paper §2.3, Algorithm 2) — owned end-to-end by Rust.
//!
//! Per iteration the trainer
//!
//! 1. draws (or re-uses from the GT pool) a noise batch and its DOPRI5
//!    dense solution,
//! 2. decodes the current theta to grid times t_i and extracts the
//!    stop-gradient snapshots x(t_i) (dense interpolation) and
//!    u(x(t_i), t_i) (model HLO evaluations),
//! 3. runs the AOT'd loss-grad executable
//!    `(theta, x_snap, u_snap, t_snap) -> (L_bes, grad)`,
//! 4. applies a masked Adam update (masks implement the paper's Fig. 15
//!    time-only / scale-only ablations).
//!
//! The GT pool implements the paper's suggested "pre-process sampling
//! paths" optimization: DOPRI5 runs once per pool slot instead of once per
//! iteration (`pool_batches`, `refresh_every` in `TrainConfig`).
//!
//! The non-stationary families (BNS per-step coefficients and learned
//! multistep, DESIGN.md §11) train in [`families`] with the same GT pool
//! and teacher-forced snapshots, but the fixed uniform grid makes their
//! loss linear in the coefficients — the gradient is closed-form and no
//! AOT'd loss-grad executable is needed.

pub mod adam;
pub mod checkpoint;
pub mod families;
pub mod gt;
pub mod trainer;

pub use adam::Adam;
pub use checkpoint::{TrainCheckpoint, TrainCtl, TrainRun};
pub use families::{train_family, train_family_with_ctl, train_family_with_progress};
pub use gt::GtPool;
pub use trainer::{
    train, train_with_ctl, train_with_progress, TrainOutcome, TrainPoint, TrainProgress,
};
