//! Bespoke training (paper §2.3, Algorithm 2) — owned end-to-end by Rust.
//!
//! Per iteration the trainer
//!
//! 1. draws (or re-uses from the GT pool) a noise batch and its DOPRI5
//!    dense solution,
//! 2. decodes the current theta to grid times t_i and extracts the
//!    stop-gradient snapshots x(t_i) (dense interpolation) and
//!    u(x(t_i), t_i) (model HLO evaluations),
//! 3. runs the AOT'd loss-grad executable
//!    `(theta, x_snap, u_snap, t_snap) -> (L_bes, grad)`,
//! 4. applies a masked Adam update (masks implement the paper's Fig. 15
//!    time-only / scale-only ablations).
//!
//! The GT pool implements the paper's suggested "pre-process sampling
//! paths" optimization: DOPRI5 runs once per pool slot instead of once per
//! iteration (`pool_batches`, `refresh_every` in `TrainConfig`).

pub mod adam;
pub mod gt;
pub mod trainer;

pub use adam::Adam;
pub use gt::GtPool;
pub use trainer::{train, train_with_progress, TrainOutcome, TrainPoint, TrainProgress};
