//! Trainer checkpoints (DESIGN.md §12): the complete resumable state of an
//! interrupted training run, persisted as NaN-safe JSON.
//!
//! A checkpoint is *bitwise-sufficient*: together with the original
//! `TrainConfig` it reproduces the uninterrupted run exactly. The trainers
//! consume RNG state only through seed-derived streams (the GT pool's
//! `pick()` draws and optional `refresh_one` solves, plus the fully
//! seed-derived validation set), so resume rebuilds pool + validation from
//! the config seed, *replays* the completed iterations' RNG consumption,
//! restores theta / best / Adam moments from the checkpoint, and continues
//! the loop — every subsequent float op sees identical inputs. The crate's
//! JSON writer emits shortest-round-trip f64 (and every f32 is exact in
//! f64), so raw parameter bytes survive the save/load cycle unchanged;
//! non-finite values (`val_rmse` on non-validation iters, an untouched
//! `best_val_rmse`) are written as `null` and mapped back on load.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::trainer::TrainPoint;
use crate::json::Value;
use crate::solvers::theta::RawTheta;
use crate::util::CancelToken;

/// Checkpoint format version (bump on layout change; loaders reject
/// unknown versions rather than misread them).
pub const CHECKPOINT_SCHEMA_VERSION: u64 = 1;

/// Everything the trainer needs to continue an interrupted run.
#[derive(Clone, Debug)]
pub struct TrainCheckpoint {
    /// Completed iterations (the loop resumes at `iters_done + 1`).
    pub iters_done: usize,
    /// Total iterations of the run being resumed — must match the
    /// resubmitted config (a different budget is a different run).
    pub iters_total: usize,
    /// Current (last-updated) theta.
    pub theta: RawTheta,
    /// Best-validation theta so far (depends on past validations, so it
    /// cannot be recomputed from `theta` alone).
    pub best: RawTheta,
    /// +inf until the first validation pass.
    pub best_val_rmse: f32,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_step: u64,
    pub history: Vec<TrainPoint>,
    /// Wall time accumulated across all previous segments.
    pub wall_secs: f64,
}

impl TrainCheckpoint {
    pub fn to_json(&self) -> Value {
        let history: Vec<Value> = self
            .history
            .iter()
            .map(|p| {
                Value::obj(vec![
                    ("iter", Value::Num(p.iter as f64)),
                    ("loss", Value::num_or_null(p.loss as f64)),
                    ("val_rmse", Value::num_or_null(p.val_rmse as f64)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("schema_version", Value::Num(CHECKPOINT_SCHEMA_VERSION as f64)),
            ("iters_done", Value::Num(self.iters_done as f64)),
            ("iters_total", Value::Num(self.iters_total as f64)),
            ("theta", self.theta.to_json()),
            ("best", self.best.to_json()),
            ("best_val_rmse", Value::num_or_null(self.best_val_rmse as f64)),
            ("adam_m", Value::from_f32s(&self.adam_m)),
            ("adam_v", Value::from_f32s(&self.adam_v)),
            ("adam_step", Value::Num(self.adam_step as f64)),
            ("history", Value::Arr(history)),
            ("wall_secs", Value::Num(self.wall_secs)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<TrainCheckpoint> {
        let version = v.get("schema_version")?.as_usize()? as u64;
        if version != CHECKPOINT_SCHEMA_VERSION {
            bail!("unsupported checkpoint schema_version {version}");
        }
        let non_finite_as = |v: &Value, fallback: f32| -> Result<f32> {
            Ok(match v {
                Value::Null => fallback,
                other => other.as_f64()? as f32,
            })
        };
        let mut history = Vec::new();
        for p in v.get("history")?.as_arr()? {
            history.push(TrainPoint {
                iter: p.get("iter")?.as_usize()?,
                loss: non_finite_as(p.get("loss")?, f32::NAN)?,
                val_rmse: non_finite_as(p.get("val_rmse")?, f32::NAN)?,
            });
        }
        Ok(TrainCheckpoint {
            iters_done: v.get("iters_done")?.as_usize()?,
            iters_total: v.get("iters_total")?.as_usize()?,
            theta: RawTheta::from_json(v.get("theta")?)?,
            best: RawTheta::from_json(v.get("best")?)?,
            best_val_rmse: non_finite_as(v.get("best_val_rmse")?, f32::INFINITY)?,
            adam_m: v.get("adam_m")?.as_f32_vec()?,
            adam_v: v.get("adam_v")?.as_f32_vec()?,
            adam_step: v.get("adam_step")?.as_usize()? as u64,
            history,
            wall_secs: v.get("wall_secs")?.as_f64()?,
        })
    }

    /// Atomic write (tmp + rename): a crash mid-save leaves either the old
    /// checkpoint or none, never a truncated one.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create {}", parent.display()))?;
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string_pretty())
            .with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        TrainCheckpoint::from_json(&Value::parse(&text)?)
            .with_context(|| format!("parse checkpoint {}", path.display()))
    }
}

/// Lifecycle controls threaded into a training loop: a cooperative cancel
/// token plus optional resume state. `Default` is a fresh, uncancellable
/// run — the pre-lifecycle behavior.
#[derive(Default)]
pub struct TrainCtl {
    pub cancel: CancelToken,
    pub resume: Option<TrainCheckpoint>,
}

/// How a controlled training run ended: complete, or checkpointed at a
/// cancellation checkpoint (an iteration boundary).
pub enum TrainRun {
    Done(super::trainer::TrainOutcome),
    Cancelled(TrainCheckpoint),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::theta::{Base, Family};

    #[test]
    fn checkpoint_json_round_trips_bitwise() {
        let theta = RawTheta {
            base: Base::Rk2,
            n: 4,
            raw: vec![0.1, -0.25, 1.5e-7, 3.0],
            family: Family::Stationary,
            window: 0,
        };
        let ck = TrainCheckpoint {
            iters_done: 7,
            iters_total: 20,
            theta: theta.clone(),
            best: theta,
            best_val_rmse: f32::INFINITY, // no validation yet
            adam_m: vec![1.0e-8, -2.5],
            adam_v: vec![0.5, 0.125],
            adam_step: 7,
            history: vec![
                TrainPoint { iter: 1, loss: 0.5, val_rmse: f32::NAN },
                TrainPoint { iter: 2, loss: 0.25, val_rmse: 0.125 },
            ],
            wall_secs: 1.5,
        };
        let back =
            TrainCheckpoint::from_json(&Value::parse(&ck.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.iters_done, 7);
        assert_eq!(back.iters_total, 20);
        assert_eq!(back.theta.raw, ck.theta.raw, "theta bytes must survive");
        assert_eq!(back.adam_m, ck.adam_m);
        assert_eq!(back.adam_v, ck.adam_v);
        assert_eq!(back.adam_step, 7);
        assert!(back.best_val_rmse.is_infinite(), "null maps back to +inf");
        assert_eq!(back.history.len(), 2);
        assert!(back.history[0].val_rmse.is_nan(), "null maps back to NaN");
        assert_eq!(back.history[1].val_rmse, 0.125);
        assert_eq!(back.wall_secs, 1.5);
    }

    #[test]
    fn save_load_atomic_and_versioned() {
        let dir = std::env::temp_dir().join(format!("bespoke_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("train/key.ckpt.json");
        let ck = TrainCheckpoint {
            iters_done: 1,
            iters_total: 2,
            theta: RawTheta::identity(Base::Rk1, 2),
            best: RawTheta::identity(Base::Rk1, 2),
            best_val_rmse: 0.5,
            adam_m: vec![0.0; 4],
            adam_v: vec![0.0; 4],
            adam_step: 1,
            history: vec![],
            wall_secs: 0.0,
        };
        ck.save(&path).unwrap();
        let back = TrainCheckpoint::load(&path).unwrap();
        assert_eq!(back.iters_done, 1);
        // a future schema version is rejected, not misread
        let mut v = ck.to_json();
        if let Value::Obj(map) = &mut v {
            map.insert("schema_version".into(), Value::Num(99.0));
        }
        std::fs::write(&path, v.to_string_pretty()).unwrap();
        assert!(TrainCheckpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
