//! Adam (Kingma & Ba) over a flat f32 parameter vector, with an optional
//! elementwise gradient mask (ablation support). Matches the paper's
//! optimizer and learning rate (2e-3, Appendix F).

pub struct Adam {
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl Adam {
    pub fn new(p: usize, lr: f32) -> Adam {
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, m: vec![0.0; p], v: vec![0.0; p], step: 0 }
    }

    /// Rebuild an optimizer mid-run from checkpointed state (lifecycle
    /// resume, DESIGN.md §12). Hyperparameters are re-derived from the
    /// config exactly as [`Adam::new`] does; only the moments and step
    /// counter are state.
    pub fn from_state(lr: f32, m: Vec<f32>, v: Vec<f32>, step: u64) -> Adam {
        assert_eq!(m.len(), v.len());
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, m, v, step }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// First-moment state (checkpointing).
    pub fn m(&self) -> &[f32] {
        &self.m
    }

    /// Second-moment state (checkpointing).
    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// In-place parameter update; `mask` (if given) zeroes selected grads.
    pub fn update(&mut self, params: &mut [f32], grad: &[f32], mask: Option<&[f32]>) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let bc1 = 1.0 - self.b1.powi(self.step as i32);
        let bc2 = 1.0 - self.b2.powi(self.step as i32);
        for i in 0..params.len() {
            let g = grad[i] * mask.map_or(1.0, |m| m[i]);
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g;
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g * g;
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            params[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = sum (x - c)^2
        let c = [3.0f32, -2.0, 0.5];
        let mut x = vec![0.0f32; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let grad: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.update(&mut x, &grad, None);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-3, "{xi} vs {ci}");
        }
    }

    #[test]
    fn mask_freezes_parameters() {
        let mut x = vec![1.0f32, 1.0];
        let mask = vec![1.0f32, 0.0];
        let mut opt = Adam::new(2, 0.1);
        for _ in 0..50 {
            opt.update(&mut x, &[1.0, 1.0], Some(&mask));
        }
        assert!(x[0] < 1.0);
        assert_eq!(x[1], 1.0, "masked param must not move");
    }

    #[test]
    fn from_state_resumes_bitwise() {
        let grads: Vec<Vec<f32>> = (0..10).map(|i| vec![0.3 * i as f32 - 1.0, 0.7]).collect();
        // uninterrupted run
        let mut x_full = vec![1.0f32, -2.0];
        let mut full = Adam::new(2, 0.05);
        for g in &grads {
            full.update(&mut x_full, g, None);
        }
        // interrupted after 5 steps, resumed from checkpointed state
        let mut x = vec![1.0f32, -2.0];
        let mut opt = Adam::new(2, 0.05);
        for g in &grads[..5] {
            opt.update(&mut x, g, None);
        }
        let (m, v, step) = (opt.m().to_vec(), opt.v().to_vec(), opt.step_count());
        let mut resumed = Adam::from_state(0.05, m, v, step);
        for g in &grads[5..] {
            resumed.update(&mut x, g, None);
        }
        assert_eq!(x, x_full, "resume must be bitwise-identical");
        assert_eq!(resumed.step_count(), full.step_count());
    }

    #[test]
    fn step_counter() {
        let mut opt = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        opt.update(&mut x, &[0.0], None);
        opt.update(&mut x, &[0.0], None);
        assert_eq!(opt.step_count(), 2);
    }
}
