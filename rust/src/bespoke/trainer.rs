//! The Bespoke training loop (paper Algorithm 2) over the AOT'd loss-grad
//! executable.

use anyhow::{bail, Context, Result};

use super::adam::Adam;
use super::checkpoint::{TrainCheckpoint, TrainCtl, TrainRun};
use super::gt::GtPool;
use crate::config::TrainConfig;
use crate::eval::rmse;
use crate::models::{HloModel, VelocityModel};
use crate::runtime::Executable;
use crate::solvers::bespoke::BespokeSolver;
use crate::solvers::dopri5::Dopri5;
use crate::solvers::theta::{Base, RawTheta};
use crate::solvers::Sampler;
use crate::tensor::Tensor;
use crate::util::{Rng, Timer};
use crate::{log_debug, log_info};

/// One history point of a training run.
#[derive(Clone, Debug)]
pub struct TrainPoint {
    pub iter: usize,
    pub loss: f32,
    /// Validation RMSE (eq. 6) — NaN for iterations without validation.
    pub val_rmse: f32,
}

pub struct TrainOutcome {
    /// Theta with the best validation RMSE (the paper reports best-iter).
    pub best: RawTheta,
    pub best_val_rmse: f32,
    pub last: RawTheta,
    pub history: Vec<TrainPoint>,
    /// Model evaluations spent: training-loop u evals + loss-grad launches
    /// are counted on the python side of the HLO; this counts GT-path NFE,
    /// the dominant cost (for %time accounting vs "model training cost").
    pub gt_nfe: u64,
    pub wall_secs: f64,
}

/// One progress report from an in-flight training run — the hook the
/// registry's `TrainJobManager` uses to surface live `job_status`.
#[derive(Clone, Copy, Debug)]
pub struct TrainProgress {
    /// 1-based iteration just completed.
    pub iter: usize,
    pub iters_total: usize,
    pub loss: f32,
    /// NaN for iterations without a validation pass.
    pub val_rmse: f32,
}

/// Train a Bespoke solver for `model` (its loss-grad artifact must have been
/// exported for (base, n) — see `python/compile/model.py::MODELS`).
pub fn train(
    model: &HloModel,
    lossgrad_exe: &Executable,
    base: Base,
    n: usize,
    cfg: &TrainConfig,
) -> Result<TrainOutcome> {
    train_with_progress(model, lossgrad_exe, base, n, cfg, &mut |_| {})
}

/// [`train`] with a per-iteration progress callback (invoked after every
/// optimizer step, on the training thread).
pub fn train_with_progress(
    model: &HloModel,
    lossgrad_exe: &Executable,
    base: Base,
    n: usize,
    cfg: &TrainConfig,
    on_progress: &mut dyn FnMut(&TrainProgress),
) -> Result<TrainOutcome> {
    match train_with_ctl(model, lossgrad_exe, base, n, cfg, &TrainCtl::default(), on_progress)? {
        TrainRun::Done(out) => Ok(out),
        TrainRun::Cancelled(_) => bail!("uncancellable run reported cancelled"),
    }
}

/// [`train_with_progress`] with lifecycle controls (DESIGN.md §12): a
/// cooperative [`TrainCtl::cancel`] token checked at every iteration
/// boundary, and optional [`TrainCtl::resume`] state from a previous
/// cancelled segment.
///
/// Resume is bitwise: the pool and validation set are rebuilt from
/// `cfg.seed` and the completed iterations' RNG consumption (`pick()`
/// draws, `refresh_one` solves) is replayed, so the continued run consumes
/// exactly the RNG stream — and therefore produces exactly the floats —
/// of an uninterrupted run with the same config.
pub fn train_with_ctl(
    model: &HloModel,
    lossgrad_exe: &Executable,
    base: Base,
    n: usize,
    cfg: &TrainConfig,
    ctl: &TrainCtl,
    on_progress: &mut dyn FnMut(&TrainProgress),
) -> Result<TrainRun> {
    let timer = Timer::start();
    let b = model.batch();
    let d = model.dim();
    let p = RawTheta::n_params(base, n);
    let mask = RawTheta::ablation_mask(base, n, &cfg.ablation)?;
    let mask = if cfg.ablation == "full" { None } else { Some(mask) };

    let mut theta = RawTheta::identity(base, n);
    let mut opt = Adam::new(p, cfg.lr);
    let mut pool = GtPool::new(model, cfg.pool_batches, cfg.gt_tol, cfg.seed)?;

    // Validation set: fresh noise batches + their GT solutions.
    let mut vrng = Rng::new(cfg.seed ^ 0x7a11d);
    let gt_solver = Dopri5 { rtol: cfg.gt_tol, atol: cfg.gt_tol, max_steps: 100_000 };
    let mut val: Vec<(Tensor, Tensor)> = Vec::new();
    for _ in 0..cfg.val_batches {
        let x0 = Tensor::new(vrng.normal_vec(b * d), vec![b, d])?;
        let sol = gt_solver.solve_model_dense(model, &x0)?;
        pool.gt_nfe += sol.nfe as u64;
        val.push((x0, sol.final_state().clone()));
    }

    let mut best = theta.clone();
    let mut best_val = f32::INFINITY;
    let mut history = Vec::new();
    let mut start_iter = 1usize;
    let mut base_wall = 0.0f64;

    if let Some(ck) = &ctl.resume {
        if ck.iters_total != cfg.iters {
            bail!(
                "checkpoint is for a {}-iteration run, resubmit asked for {}",
                ck.iters_total,
                cfg.iters
            );
        }
        if ck.theta.base != base || ck.theta.n != n || ck.theta.raw.len() != p {
            bail!("checkpoint theta shape does not match (base, n)");
        }
        if ck.adam_m.len() != p || ck.adam_v.len() != p {
            bail!("checkpoint optimizer state does not match parameter count");
        }
        // Replay the completed iterations' RNG consumption so the pool
        // stream continues exactly where the interrupted segment left it.
        for iter in 1..=ck.iters_done {
            if cfg.refresh_every > 0 && iter % cfg.refresh_every == 0 {
                pool.refresh_one(model)?;
            }
            let _ = pool.pick();
        }
        theta = ck.theta.clone();
        best = ck.best.clone();
        best_val = ck.best_val_rmse;
        opt = Adam::from_state(cfg.lr, ck.adam_m.clone(), ck.adam_v.clone(), ck.adam_step);
        history = ck.history.clone();
        start_iter = ck.iters_done + 1;
        base_wall = ck.wall_secs;
        log_info!(
            "[train {}] resuming from checkpoint at iter {}/{}",
            model.name(),
            ck.iters_done,
            cfg.iters
        );
    }

    for iter in start_iter..=cfg.iters {
        if ctl.cancel.is_cancelled() {
            return Ok(TrainRun::Cancelled(TrainCheckpoint {
                iters_done: iter - 1,
                iters_total: cfg.iters,
                theta,
                best,
                best_val_rmse: best_val,
                adam_m: opt.m().to_vec(),
                adam_v: opt.v().to_vec(),
                adam_step: opt.step_count(),
                history,
                wall_secs: base_wall + timer.elapsed_secs(),
            }));
        }
        if cfg.refresh_every > 0 && iter % cfg.refresh_every == 0 {
            pool.refresh_one(model)?;
        }

        // --- snapshots at the *current* theta's integer step times --------
        let dec = theta.decode();
        let step_times = dec.step_times(); // n+1 times
        let (x_snap, u_snap) = {
            let entry = pool.pick();
            let mut xs = Vec::with_capacity(n + 1);
            for &t in &step_times {
                xs.push(entry.dense.eval(t));
            }
            // u(x(t_i), t_i): exact model evaluation or the Hermite
            // derivative of the dense GT solution (§Perf: saves n+1 HLO
            // launches per iteration at O(h^2) snapshot-velocity error).
            let mut us = Vec::with_capacity(n + 1);
            if cfg.snap_velocity == "model" {
                for (x, &t) in xs.iter().zip(&step_times) {
                    us.push(model.eval(x, t)?);
                }
            } else {
                for &t in &step_times {
                    us.push(entry.dense.eval_deriv(t));
                }
            }
            (xs, us)
        };

        // pack snapshots [B, n+1, d]: row-major over (b, i, d)
        let mut x_pack = vec![0.0f32; b * (n + 1) * d];
        let mut u_pack = vec![0.0f32; b * (n + 1) * d];
        for (i, (xs, us)) in x_snap.iter().zip(&u_snap).enumerate() {
            for bi in 0..b {
                let src_x = xs.row(bi);
                let src_u = us.row(bi);
                let dst = (bi * (n + 1) + i) * d;
                x_pack[dst..dst + d].copy_from_slice(src_x);
                u_pack[dst..dst + d].copy_from_slice(src_u);
            }
        }

        // --- loss + grad via the AOT'd executable -------------------------
        let outputs = lossgrad_exe
            .run(&[
                Tensor::new(theta.raw.clone(), vec![p])?,
                Tensor::new(x_pack, vec![b, n + 1, d])?,
                Tensor::new(u_pack, vec![b, n + 1, d])?,
                Tensor::new(step_times.clone(), vec![n + 1])?,
            ])
            .context("loss-grad execution")?;
        let loss = outputs[0].data()[0];
        let grad = outputs[1].data();

        opt.update(&mut theta.raw, grad, mask.as_deref());

        // --- validation ----------------------------------------------------
        let mut val_rmse = f32::NAN;
        if iter % cfg.val_every == 0 || iter == cfg.iters {
            let sampler = BespokeSolver::new(&theta);
            let mut acc = 0.0f32;
            for (x0, gt) in &val {
                let out = sampler.sample(model, x0)?;
                acc += rmse(&out, gt);
            }
            val_rmse = acc / val.len() as f32;
            if val_rmse < best_val {
                best_val = val_rmse;
                best = theta.clone();
            }
            log_info!(
                "[train {} {} n={}] iter {:4} loss {:.5} val_rmse {:.5}",
                model.name(),
                base.name(),
                n,
                iter,
                loss,
                val_rmse
            );
        } else {
            log_debug!("[train] iter {iter} loss {loss:.5}");
        }
        history.push(TrainPoint { iter, loss, val_rmse });
        on_progress(&TrainProgress { iter, iters_total: cfg.iters, loss, val_rmse });
    }

    Ok(TrainRun::Done(TrainOutcome {
        best,
        best_val_rmse: best_val,
        last: theta,
        history,
        gt_nfe: pool.gt_nfe,
        wall_secs: base_wall + timer.elapsed_secs(),
    }))
}
