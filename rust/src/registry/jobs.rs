//! Asynchronous background jobs: submit work through the serving protocol,
//! run it on background worker threads, and publish the outcome into the
//! [`Registry`] — from where live serving picks it up (trained thetas
//! hot-swap into routes, eval scorecards rebuild the Pareto frontier; see
//! DESIGN.md §8–9).
//!
//! The machinery is **generic**: [`JobManager<R>`] owns the queue,
//! coalescing, progress tracking, panic containment and finished-job
//! pruning for any [`JobRunner`]. Two runners exist today:
//!
//! * [`ZooRunner`] — Bespoke training via `bespoke::train` (the
//!   [`TrainJobManager`] alias, `{"cmd":"train"}`),
//! * `quality::EvalRunner` — scorecard sweeps via `eval::evaluate_sampler`
//!   (the `quality::EvalJobManager` alias, `{"cmd":"evaluate"}`).
//!
//! Job lifecycle: `queued -> running -> done | failed`. Duplicate
//! submissions for the same coalescing key while a job is queued or running
//! coalesce onto the existing job (the server would only race itself doing
//! the same work twice). A panicking runner fails the job instead of
//! wedging it in `running` forever.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::meta::ArtifactMeta;
use super::store::{ArtifactKey, ArtifactRecord, Registry};
use crate::bespoke::{train_family_with_progress, train_with_progress, TrainProgress};
use crate::config::TrainConfig;
use crate::coordinator::Metrics;
use crate::log_info;
use crate::models::Zoo;
use crate::runtime::Executable;
use crate::solvers::theta::{Base, Family, RawTheta};

pub type JobId = u64;

/// The universal per-step progress report. Training reports optimizer
/// iterations; eval jobs report scorecard cells (with `loss = NaN`). The
/// trainer's [`TrainProgress`] already carries exactly the fields every job
/// kind needs, so it doubles as the generic type.
pub type JobProgress = TrainProgress;

/// Finished (done/failed) jobs retained for `job_status`/`jobs` queries;
/// older ones are pruned so a long-lived server's job table stays bounded
/// (a pruned job's artifact lives on in the registry).
pub const KEEP_FINISHED_JOBS: usize = 256;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// Pluggable job execution. Implementations describe what a job *is*
/// (spec), how it *runs* (on a worker thread, reporting progress), and how
/// its outcome is *published* into the registry; [`JobManager`] supplies
/// everything else (queueing, coalescing, snapshots, panic containment).
pub trait JobRunner: Send + Sync {
    /// What to do: the submitted job description.
    type Spec: Clone + Send + 'static;
    /// The raw product of a successful run, before publication.
    type Output: Send + 'static;
    /// The published registry record surfaced in job snapshots.
    type Artifact: Clone + Send + 'static;

    /// Job-kind tag: metrics events are named `<kind>_jobs_submitted` /
    /// `_coalesced` / `_done` / `_failed`, and logs are prefixed with it.
    fn kind(&self) -> &'static str;

    /// Coalescing identity: a submission whose key matches a queued or
    /// running job joins that job instead of enqueueing a duplicate.
    fn coalesce_key(&self, spec: &Self::Spec) -> String;

    /// Human-readable job description for logs.
    fn label(&self, spec: &Self::Spec) -> String;

    /// Fail-fast validation at submit time (unknown model, missing
    /// loss-grad artifact, bad spec).
    fn validate(&self, _spec: &Self::Spec) -> Result<()> {
        Ok(())
    }

    /// Run the job, reporting progress through the callback.
    fn run(
        &self,
        spec: &Self::Spec,
        progress: &mut dyn FnMut(&JobProgress),
    ) -> Result<Self::Output>;

    /// Persist a finished run into the registry (register the theta,
    /// write the scorecard, ...). Runs on the worker thread; an error here
    /// fails the job like a run error.
    fn publish(&self, registry: &Registry, out: Self::Output) -> Result<Self::Artifact>;
}

/// What to train. `iters`/`seed` override the server's `TrainConfig` when
/// present; they do not participate in the coalescing key — a duplicate
/// submission joins the in-flight job even if its overrides differ.
#[derive(Clone, Debug)]
pub struct TrainJobSpec {
    pub model: String,
    pub base: Base,
    pub n: usize,
    pub ablation: String,
    /// Solver family (DESIGN.md §11): stationary trains paper Algorithm 2
    /// over the AOT'd loss-grad; bns/multistep train the closed-form
    /// family trainer over the zoo's serving model.
    pub family: Family,
    /// History window for `family = multistep` (`None` -> server default).
    pub window: Option<usize>,
    pub iters: Option<usize>,
    pub seed: Option<u64>,
}

impl TrainJobSpec {
    pub fn key(&self) -> ArtifactKey {
        ArtifactKey::new(&self.model, self.base, self.n, &self.ablation)
    }
}

/// Largest accepted multistep history window — bounds the dead warm-up
/// coefficients (layout keeps `window` slots per step, step i uses
/// `min(i+1, window)`).
pub const MAX_WINDOW: usize = 8;

/// A finished training run, ready for registration.
pub struct TrainedArtifact {
    pub theta: RawTheta,
    pub meta: ArtifactMeta,
}

/// The training-job runner trait object: what [`TrainJobManager`] drives.
pub type TrainRunner =
    dyn JobRunner<Spec = TrainJobSpec, Output = TrainedArtifact, Artifact = ArtifactRecord>;

/// Background training-job manager (the `{"cmd":"train"}` plane).
pub type TrainJobManager = JobManager<TrainRunner>;

/// Snapshot of one training job.
pub type TrainJobSnapshot = JobSnapshot<TrainJobSpec, ArtifactRecord>;

/// The real training runner: loads the model + loss-grad executable from
/// the zoo and runs paper Algorithm 2 via [`train_with_progress`].
pub struct ZooRunner {
    zoo: Arc<Zoo>,
    base_cfg: TrainConfig,
}

impl ZooRunner {
    pub fn new(zoo: Arc<Zoo>, base_cfg: TrainConfig) -> ZooRunner {
        ZooRunner { zoo, base_cfg }
    }

    fn job_cfg(&self, spec: &TrainJobSpec) -> TrainConfig {
        let mut cfg = self.base_cfg.clone();
        cfg.ablation = spec.ablation.clone();
        if let Some(iters) = spec.iters {
            cfg.iters = iters;
        }
        if let Some(seed) = spec.seed {
            cfg.seed = seed;
        }
        cfg
    }
}

impl JobRunner for ZooRunner {
    type Spec = TrainJobSpec;
    type Output = TrainedArtifact;
    type Artifact = ArtifactRecord;

    fn kind(&self) -> &'static str {
        "train"
    }

    fn coalesce_key(&self, spec: &TrainJobSpec) -> String {
        // '|' cannot appear in model/ablation names, so the key is
        // unambiguous even for underscore-heavy model names. Family and
        // window are part of the identity: a bns job must not coalesce
        // onto a stationary one for the same (model, base, n, ablation).
        format!(
            "{}|{}|{}|{}|{}|{}",
            spec.model,
            spec.base.name(),
            spec.n,
            spec.ablation,
            spec.family.name(),
            spec.window.unwrap_or(0)
        )
    }

    fn label(&self, spec: &TrainJobSpec) -> String {
        if spec.family == Family::Stationary {
            spec.key().label()
        } else {
            format!("{} [{}]", spec.key().label(), spec.family.name())
        }
    }

    fn validate(&self, spec: &TrainJobSpec) -> Result<()> {
        match spec.family {
            Family::Stationary => {
                if spec.window.is_some() {
                    anyhow::bail!("window is only valid for family=multistep");
                }
                // model + exported loss-grad artifact must exist...
                self.zoo
                    .manifest()
                    .lossgrad(&spec.model, spec.base.name(), spec.n)?;
                // ...and the ablation name must be one the mask codec knows.
                RawTheta::ablation_mask(spec.base, spec.n, &spec.ablation)?;
            }
            Family::Bns | Family::Multistep => {
                // no AOT'd loss-grad needed: the closed-form trainer only
                // needs a servable model
                self.zoo.serving_model(&spec.model)?;
                if spec.ablation != "full" {
                    anyhow::bail!(
                        "family {} supports only ablation=full (got {:?})",
                        spec.family.name(),
                        spec.ablation
                    );
                }
                if spec.family == Family::Multistep {
                    if spec.base != Base::Rk1 {
                        anyhow::bail!("family multistep requires base=rk1 (1 eval/step)");
                    }
                    let w = spec.window.unwrap_or(self.base_cfg.window);
                    if !(1..=MAX_WINDOW).contains(&w) {
                        anyhow::bail!("window must be in 1..={MAX_WINDOW}, got {w}");
                    }
                } else if spec.window.is_some() {
                    anyhow::bail!("window is only valid for family=multistep");
                }
            }
        }
        Ok(())
    }

    fn run(
        &self,
        spec: &TrainJobSpec,
        progress: &mut dyn FnMut(&JobProgress),
    ) -> Result<TrainedArtifact> {
        let cfg = self.job_cfg(spec);
        let out = match spec.family {
            Family::Stationary => {
                let model = self.zoo.hlo(&spec.model)?;
                let lg = self
                    .zoo
                    .manifest()
                    .lossgrad(&spec.model, spec.base.name(), spec.n)?;
                let exe = Executable::load(&self.zoo.manifest().path(&lg.file))
                    .context("loading loss-grad executable")?;
                train_with_progress(&model, &exe, spec.base, spec.n, &cfg, progress)?
            }
            family => {
                let model = self.zoo.serving_model(&spec.model)?;
                let window = spec.window.unwrap_or(self.base_cfg.window);
                train_family_with_progress(
                    model.as_ref(),
                    family,
                    spec.base,
                    spec.n,
                    window,
                    &cfg,
                    progress,
                )?
            }
        };
        let meta = ArtifactMeta::from_outcome(&spec.model, spec.base, spec.n, &cfg.ablation, &out);
        Ok(TrainedArtifact { theta: out.best, meta })
    }

    fn publish(&self, registry: &Registry, out: TrainedArtifact) -> Result<ArtifactRecord> {
        let rec = registry.register(&out.theta, &out.meta)?;
        log_info!(
            "registered {} v{} val_rmse={:.5}",
            rec.key.label(),
            rec.version,
            rec.val_rmse
        );
        Ok(rec)
    }
}

/// Point-in-time view of a job for `job_status` / `jobs` responses.
#[derive(Clone, Debug)]
pub struct JobSnapshot<S: Clone, A: Clone> {
    pub id: JobId,
    pub spec: S,
    pub state: JobState,
    pub iters_done: usize,
    /// 0 until the first progress report arrives.
    pub iters_total: usize,
    /// NaN until the first progress report.
    pub loss: f32,
    /// NaN until the first validation pass.
    pub val_rmse: f32,
    pub error: Option<String>,
    /// The published registry record, once `Done`.
    pub artifact: Option<A>,
    /// Seconds spent running so far (final once finished; 0 while queued).
    pub wall_secs: f64,
}

struct Slot<S, A> {
    spec: S,
    coalesce_key: String,
    state: JobState,
    iters_done: usize,
    iters_total: usize,
    loss: f32,
    val_rmse: f32,
    error: Option<String>,
    artifact: Option<A>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl<S: Clone, A: Clone> Slot<S, A> {
    fn snapshot(&self, id: JobId) -> JobSnapshot<S, A> {
        let wall_secs = match (self.started, self.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        JobSnapshot {
            id,
            spec: self.spec.clone(),
            state: self.state,
            iters_done: self.iters_done,
            iters_total: self.iters_total,
            loss: self.loss,
            val_rmse: self.val_rmse,
            error: self.error.clone(),
            artifact: self.artifact.clone(),
            wall_secs,
        }
    }
}

struct JobsState<S, A> {
    jobs: BTreeMap<JobId, Slot<S, A>>,
    pending: VecDeque<JobId>,
    next_id: JobId,
    shutdown: bool,
}

struct Inner<S, A> {
    state: Mutex<JobsState<S, A>>,
    ready: Condvar,
}

/// Background job manager: `max_jobs` worker threads drain a FIFO of
/// submitted jobs; completed outcomes are published into the shared
/// [`Registry`] through the runner's `publish` hook.
pub struct JobManager<R: JobRunner + ?Sized> {
    inner: Arc<Inner<R::Spec, R::Artifact>>,
    registry: Arc<Registry>,
    runner: Arc<R>,
    metrics: Option<Arc<Metrics>>,
}

impl<R: JobRunner + ?Sized + 'static> JobManager<R> {
    /// Errors if a worker thread cannot be spawned (resource exhaustion) —
    /// a manager with zero workers would queue jobs forever.
    pub fn new(
        registry: Arc<Registry>,
        runner: Arc<R>,
        max_jobs: usize,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<JobManager<R>> {
        let inner = Arc::new(Inner {
            state: Mutex::new(JobsState {
                jobs: BTreeMap::new(),
                pending: VecDeque::new(),
                next_id: 1,
                shutdown: false,
            }),
            ready: Condvar::new(),
        });
        for wi in 0..max_jobs.max(1) {
            let worker_inner = inner.clone();
            let registry = registry.clone();
            let runner = runner.clone();
            let metrics = metrics.clone();
            // Detached: a worker stuck in a long run outlives the manager
            // and still publishes its outcome (the registry Arc keeps the
            // store alive).
            let spawned = std::thread::Builder::new()
                .name(format!("{}-job-{wi}", runner.kind()))
                .spawn(move || worker_loop(worker_inner, registry, runner, metrics));
            if let Err(e) = spawned {
                // Tell already-spawned workers to exit before bailing.
                inner.state.lock().unwrap().shutdown = true;
                inner.ready.notify_all();
                return Err(anyhow::Error::from(e).context("spawning job worker"));
            }
        }
        Ok(JobManager { inner, registry, runner, metrics })
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Submit a job. Returns `(job_id, coalesced)`: when a job for the same
    /// coalescing key is already queued or running, the existing job id is
    /// returned with `coalesced = true` and nothing new is enqueued.
    pub fn submit(&self, spec: R::Spec) -> Result<(JobId, bool)> {
        self.runner.validate(&spec)?;
        let key = self.runner.coalesce_key(&spec);
        let mut st = self.inner.state.lock().unwrap();
        let in_flight = st.jobs.iter().find(|(_, s)| {
            s.coalesce_key == key && matches!(s.state, JobState::Queued | JobState::Running)
        });
        if let Some((&id, _)) = in_flight {
            self.record("coalesced");
            return Ok((id, true));
        }
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            Slot {
                spec,
                coalesce_key: key,
                state: JobState::Queued,
                iters_done: 0,
                iters_total: 0,
                loss: f32::NAN,
                val_rmse: f32::NAN,
                error: None,
                artifact: None,
                started: None,
                finished: None,
            },
        );
        st.pending.push_back(id);
        drop(st);
        self.inner.ready.notify_one();
        self.record("submitted");
        Ok((id, false))
    }

    pub fn status(&self, id: JobId) -> Option<JobSnapshot<R::Spec, R::Artifact>> {
        let st = self.inner.state.lock().unwrap();
        st.jobs.get(&id).map(|s| s.snapshot(id))
    }

    /// All jobs, oldest first.
    pub fn jobs(&self) -> Vec<JobSnapshot<R::Spec, R::Artifact>> {
        let st = self.inner.state.lock().unwrap();
        st.jobs.iter().map(|(&id, s)| s.snapshot(id)).collect()
    }

    fn record(&self, suffix: &str) {
        if let Some(m) = &self.metrics {
            m.record_event(&format!("{}_jobs_{suffix}", self.runner.kind()));
        }
    }
}

impl<R: JobRunner + ?Sized> Drop for JobManager<R> {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().shutdown = true;
        self.inner.ready.notify_all();
    }
}

fn worker_loop<R: JobRunner + ?Sized>(
    inner: Arc<Inner<R::Spec, R::Artifact>>,
    registry: Arc<Registry>,
    runner: Arc<R>,
    metrics: Option<Arc<Metrics>>,
) {
    let kind = runner.kind();
    loop {
        // Block until a job is pending (or shutdown).
        let (id, spec) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(id) = st.pending.pop_front() {
                    let slot = st.jobs.get_mut(&id).expect("pending id has a slot");
                    slot.state = JobState::Running;
                    slot.started = Some(Instant::now());
                    break (id, slot.spec.clone());
                }
                st = inner.ready.wait(st).unwrap();
            }
        };
        log_info!("[{kind} job {id}] {}", runner.label(&spec));

        // Run + publish outside the lock; a panicking runner fails the job
        // instead of wedging it in `running` forever.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            runner
                .run(&spec, &mut |p: &JobProgress| {
                    let mut st = inner.state.lock().unwrap();
                    if let Some(s) = st.jobs.get_mut(&id) {
                        s.iters_done = p.iter;
                        s.iters_total = p.iters_total;
                        s.loss = p.loss;
                        if !p.val_rmse.is_nan() {
                            s.val_rmse = p.val_rmse;
                        }
                    }
                })
                .and_then(|out| runner.publish(&registry, out))
        }));
        let published = match run {
            Ok(result) => result,
            Err(panic) => Err(anyhow::anyhow!(
                "{kind} job panicked: {}",
                panic_message(&panic)
            )),
        };

        let mut st = inner.state.lock().unwrap();
        prune_finished(&mut st);
        if let Some(slot) = st.jobs.get_mut(&id) {
            slot.finished = Some(Instant::now());
            match published {
                Ok(rec) => {
                    log_info!("[{kind} job {id}] done");
                    slot.state = JobState::Done;
                    slot.artifact = Some(rec);
                    if let Some(m) = &metrics {
                        m.record_event(&format!("{kind}_jobs_done"));
                    }
                }
                Err(e) => {
                    log_info!("[{kind} job {id}] failed: {e:#}");
                    slot.state = JobState::Failed;
                    slot.error = Some(format!("{e:#}"));
                    if let Some(m) = &metrics {
                        m.record_event(&format!("{kind}_jobs_failed"));
                    }
                }
            }
        }
    }
}

/// Drop the oldest finished jobs beyond [`KEEP_FINISHED_JOBS`] (BTreeMap
/// iterates in id order, so the first finished entries are the oldest).
/// In-flight jobs are never pruned; the job about to be finalized by the
/// caller still counts as in-flight here and survives.
fn prune_finished<S, A>(st: &mut JobsState<S, A>) {
    let finished: Vec<JobId> = st
        .jobs
        .iter()
        .filter(|(_, s)| matches!(s.state, JobState::Done | JobState::Failed))
        .map(|(&id, _)| id)
        .collect();
    if finished.len() >= KEEP_FINISHED_JOBS {
        for id in &finished[..=finished.len() - KEEP_FINISHED_JOBS] {
            st.jobs.remove(id);
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}
