//! Asynchronous training jobs: submit Bespoke training through the serving
//! protocol, run it on background worker threads, and register the outcome
//! into the [`Registry`] — from where live serving hot-swaps it in (the
//! coordinator re-resolves `bespoke:model=...` specs per request; see
//! `coordinator::batcher` and DESIGN.md §8).
//!
//! Job lifecycle: `queued -> running -> done | failed`. Duplicate
//! submissions for the same artifact key while a job is queued or running
//! coalesce onto the existing job (the registry would only race itself
//! training the same solver twice).
//!
//! Execution is abstracted behind [`JobRunner`] so the queueing/coalescing/
//! registration machinery is testable without compiled HLO artifacts;
//! [`ZooRunner`] is the real implementation over `bespoke::train`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::meta::ArtifactMeta;
use super::store::{ArtifactKey, ArtifactRecord, Registry};
use crate::bespoke::{train_with_progress, TrainProgress};
use crate::config::TrainConfig;
use crate::coordinator::Metrics;
use crate::log_info;
use crate::models::Zoo;
use crate::runtime::Executable;
use crate::solvers::theta::{Base, RawTheta};

pub type JobId = u64;

/// Finished (done/failed) jobs retained for `job_status`/`jobs` queries;
/// older ones are pruned so a long-lived server's job table stays bounded
/// (a pruned job's artifact lives on in the registry).
pub const KEEP_FINISHED_JOBS: usize = 256;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// What to train. `iters`/`seed` override the server's `TrainConfig` when
/// present; they do not participate in the coalescing key — a duplicate
/// submission joins the in-flight job even if its overrides differ.
#[derive(Clone, Debug)]
pub struct TrainJobSpec {
    pub model: String,
    pub base: Base,
    pub n: usize,
    pub ablation: String,
    pub iters: Option<usize>,
    pub seed: Option<u64>,
}

impl TrainJobSpec {
    pub fn key(&self) -> ArtifactKey {
        ArtifactKey::new(&self.model, self.base, self.n, &self.ablation)
    }
}

/// A finished training run, ready for registration.
pub struct TrainedArtifact {
    pub theta: RawTheta,
    pub meta: ArtifactMeta,
}

/// Pluggable job execution.
pub trait JobRunner: Send + Sync {
    /// Fail-fast validation at submit time (unknown model, missing
    /// loss-grad artifact, bad ablation name).
    fn validate(&self, _spec: &TrainJobSpec) -> Result<()> {
        Ok(())
    }

    /// Run the training job, reporting progress through the callback.
    fn run(
        &self,
        spec: &TrainJobSpec,
        progress: &mut dyn FnMut(&TrainProgress),
    ) -> Result<TrainedArtifact>;
}

/// The real runner: loads the model + loss-grad executable from the zoo and
/// runs paper Algorithm 2 via [`train_with_progress`].
pub struct ZooRunner {
    zoo: Arc<Zoo>,
    base_cfg: TrainConfig,
}

impl ZooRunner {
    pub fn new(zoo: Arc<Zoo>, base_cfg: TrainConfig) -> ZooRunner {
        ZooRunner { zoo, base_cfg }
    }

    fn job_cfg(&self, spec: &TrainJobSpec) -> TrainConfig {
        let mut cfg = self.base_cfg.clone();
        cfg.ablation = spec.ablation.clone();
        if let Some(iters) = spec.iters {
            cfg.iters = iters;
        }
        if let Some(seed) = spec.seed {
            cfg.seed = seed;
        }
        cfg
    }
}

impl JobRunner for ZooRunner {
    fn validate(&self, spec: &TrainJobSpec) -> Result<()> {
        // model + exported loss-grad artifact must exist...
        self.zoo
            .manifest()
            .lossgrad(&spec.model, spec.base.name(), spec.n)?;
        // ...and the ablation name must be one the mask codec knows.
        RawTheta::ablation_mask(spec.base, spec.n, &spec.ablation)?;
        Ok(())
    }

    fn run(
        &self,
        spec: &TrainJobSpec,
        progress: &mut dyn FnMut(&TrainProgress),
    ) -> Result<TrainedArtifact> {
        let model = self.zoo.hlo(&spec.model)?;
        let lg = self
            .zoo
            .manifest()
            .lossgrad(&spec.model, spec.base.name(), spec.n)?;
        let exe = Executable::load(&self.zoo.manifest().path(&lg.file))
            .context("loading loss-grad executable")?;
        let cfg = self.job_cfg(spec);
        let out = train_with_progress(&model, &exe, spec.base, spec.n, &cfg, progress)?;
        let meta = ArtifactMeta::from_outcome(&spec.model, spec.base, spec.n, &cfg.ablation, &out);
        Ok(TrainedArtifact { theta: out.best, meta })
    }
}

/// Point-in-time view of a job for `job_status` / `jobs` responses.
#[derive(Clone, Debug)]
pub struct JobSnapshot {
    pub id: JobId,
    pub spec: TrainJobSpec,
    pub state: JobState,
    pub iters_done: usize,
    /// 0 until the first progress report arrives.
    pub iters_total: usize,
    /// NaN until the first progress report.
    pub loss: f32,
    /// NaN until the first validation pass.
    pub val_rmse: f32,
    pub error: Option<String>,
    /// The registered artifact, once `Done`.
    pub artifact: Option<ArtifactRecord>,
    /// Seconds spent running so far (final once finished; 0 while queued).
    pub wall_secs: f64,
}

struct Slot {
    spec: TrainJobSpec,
    state: JobState,
    iters_done: usize,
    iters_total: usize,
    loss: f32,
    val_rmse: f32,
    error: Option<String>,
    artifact: Option<ArtifactRecord>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Slot {
    fn snapshot(&self, id: JobId) -> JobSnapshot {
        let wall_secs = match (self.started, self.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        JobSnapshot {
            id,
            spec: self.spec.clone(),
            state: self.state,
            iters_done: self.iters_done,
            iters_total: self.iters_total,
            loss: self.loss,
            val_rmse: self.val_rmse,
            error: self.error.clone(),
            artifact: self.artifact.clone(),
            wall_secs,
        }
    }
}

struct JobsState {
    jobs: BTreeMap<JobId, Slot>,
    pending: VecDeque<JobId>,
    next_id: JobId,
    shutdown: bool,
}

struct Inner {
    state: Mutex<JobsState>,
    ready: Condvar,
}

/// Background training-job manager: `max_jobs` worker threads drain a FIFO
/// of submitted jobs; completed artifacts are registered into the shared
/// [`Registry`].
pub struct TrainJobManager {
    inner: Arc<Inner>,
    registry: Arc<Registry>,
    runner: Arc<dyn JobRunner>,
    metrics: Option<Arc<Metrics>>,
}

impl TrainJobManager {
    /// Errors if a worker thread cannot be spawned (resource exhaustion) —
    /// a manager with zero workers would queue jobs forever.
    pub fn new(
        registry: Arc<Registry>,
        runner: Arc<dyn JobRunner>,
        max_jobs: usize,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<TrainJobManager> {
        let inner = Arc::new(Inner {
            state: Mutex::new(JobsState {
                jobs: BTreeMap::new(),
                pending: VecDeque::new(),
                next_id: 1,
                shutdown: false,
            }),
            ready: Condvar::new(),
        });
        for wi in 0..max_jobs.max(1) {
            let worker_inner = inner.clone();
            let registry = registry.clone();
            let runner = runner.clone();
            let metrics = metrics.clone();
            // Detached: a worker stuck in a long training run outlives the
            // manager and still registers its artifact (the registry Arc
            // keeps the store alive).
            let spawned = std::thread::Builder::new()
                .name(format!("train-job-{wi}"))
                .spawn(move || worker_loop(worker_inner, registry, runner, metrics));
            if let Err(e) = spawned {
                // Tell already-spawned workers to exit before bailing.
                inner.state.lock().unwrap().shutdown = true;
                inner.ready.notify_all();
                return Err(anyhow::Error::from(e).context("spawning training-job worker"));
            }
        }
        Ok(TrainJobManager { inner, registry, runner, metrics })
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Submit a job. Returns `(job_id, coalesced)`: when a job for the same
    /// artifact key is already queued or running, the existing job id is
    /// returned with `coalesced = true` and nothing new is enqueued.
    pub fn submit(&self, spec: TrainJobSpec) -> Result<(JobId, bool)> {
        self.runner.validate(&spec)?;
        let key = spec.key();
        let mut st = self.inner.state.lock().unwrap();
        let in_flight = st.jobs.iter().find(|(_, s)| {
            s.spec.key() == key && matches!(s.state, JobState::Queued | JobState::Running)
        });
        if let Some((&id, _)) = in_flight {
            self.record("train_jobs_coalesced");
            return Ok((id, true));
        }
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            Slot {
                spec,
                state: JobState::Queued,
                iters_done: 0,
                iters_total: 0,
                loss: f32::NAN,
                val_rmse: f32::NAN,
                error: None,
                artifact: None,
                started: None,
                finished: None,
            },
        );
        st.pending.push_back(id);
        drop(st);
        self.inner.ready.notify_one();
        self.record("train_jobs_submitted");
        Ok((id, false))
    }

    pub fn status(&self, id: JobId) -> Option<JobSnapshot> {
        let st = self.inner.state.lock().unwrap();
        st.jobs.get(&id).map(|s| s.snapshot(id))
    }

    /// All jobs, oldest first.
    pub fn jobs(&self) -> Vec<JobSnapshot> {
        let st = self.inner.state.lock().unwrap();
        st.jobs.iter().map(|(&id, s)| s.snapshot(id)).collect()
    }

    fn record(&self, event: &str) {
        if let Some(m) = &self.metrics {
            m.record_event(event);
        }
    }
}

impl Drop for TrainJobManager {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().shutdown = true;
        self.inner.ready.notify_all();
    }
}

fn worker_loop(
    inner: Arc<Inner>,
    registry: Arc<Registry>,
    runner: Arc<dyn JobRunner>,
    metrics: Option<Arc<Metrics>>,
) {
    loop {
        // Block until a job is pending (or shutdown).
        let (id, spec) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(id) = st.pending.pop_front() {
                    let slot = st.jobs.get_mut(&id).expect("pending id has a slot");
                    slot.state = JobState::Running;
                    slot.started = Some(Instant::now());
                    break (id, slot.spec.clone());
                }
                st = inner.ready.wait(st).unwrap();
            }
        };
        log_info!("[job {id}] training {}", spec.key().label());

        // Run outside the lock; a panicking runner fails the job instead of
        // wedging it in `running` forever.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            runner.run(&spec, &mut |p: &TrainProgress| {
                let mut st = inner.state.lock().unwrap();
                if let Some(s) = st.jobs.get_mut(&id) {
                    s.iters_done = p.iter;
                    s.iters_total = p.iters_total;
                    s.loss = p.loss;
                    if !p.val_rmse.is_nan() {
                        s.val_rmse = p.val_rmse;
                    }
                }
            })
        }));
        let registered = match run {
            Ok(Ok(out)) => registry.register(&out.theta, &out.meta),
            Ok(Err(e)) => Err(e),
            Err(panic) => Err(anyhow::anyhow!(
                "training job panicked: {}",
                panic_message(&panic)
            )),
        };

        let mut st = inner.state.lock().unwrap();
        prune_finished(&mut st);
        if let Some(slot) = st.jobs.get_mut(&id) {
            slot.finished = Some(Instant::now());
            match registered {
                Ok(rec) => {
                    log_info!(
                        "[job {id}] done: {} v{} val_rmse={:.5}",
                        rec.key.label(),
                        rec.version,
                        rec.val_rmse
                    );
                    slot.state = JobState::Done;
                    slot.artifact = Some(rec);
                    if let Some(m) = &metrics {
                        m.record_event("train_jobs_done");
                    }
                }
                Err(e) => {
                    log_info!("[job {id}] failed: {e:#}");
                    slot.state = JobState::Failed;
                    slot.error = Some(format!("{e:#}"));
                    if let Some(m) = &metrics {
                        m.record_event("train_jobs_failed");
                    }
                }
            }
        }
    }
}

/// Drop the oldest finished jobs beyond [`KEEP_FINISHED_JOBS`] (BTreeMap
/// iterates in id order, so the first finished entries are the oldest).
/// In-flight jobs are never pruned; the job about to be finalized by the
/// caller still counts as in-flight here and survives.
fn prune_finished(st: &mut JobsState) {
    let finished: Vec<JobId> = st
        .jobs
        .iter()
        .filter(|(_, s)| matches!(s.state, JobState::Done | JobState::Failed))
        .map(|(&id, _)| id)
        .collect();
    if finished.len() >= KEEP_FINISHED_JOBS {
        for id in &finished[..=finished.len() - KEEP_FINISHED_JOBS] {
            st.jobs.remove(id);
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}
